"""Benchmark helpers: the paper's progress-latency methodology (§4.1).

A dummy task completes at a preset wall-clock deadline; *progress
latency* is the elapsed time between the deadline and the moment the
engine's poll observes it (the paper's metric, in microseconds).
"""
from __future__ import annotations

import statistics
import time

from repro.core import DONE, NOPROGRESS, ProgressEngine, Stream


class LatencyStats:
    def __init__(self):
        self.samples_us: list[float] = []

    def add(self, seconds: float):
        self.samples_us.append(seconds * 1e6)

    def mean(self) -> float:
        return statistics.fmean(self.samples_us) if self.samples_us else float("nan")

    def p99(self) -> float:
        if not self.samples_us:
            return float("nan")
        s = sorted(self.samples_us)
        return s[min(int(0.99 * len(s)), len(s) - 1)]


def make_dummy_task(duration_s: float, stats: LatencyStats, counter: dict,
                    poll_delay_s: float = 0.0):
    """Paper Listing 1.3 dummy task + latency stat."""
    deadline = time.perf_counter() + duration_s

    def poll(thing):
        now = time.perf_counter()
        if now >= deadline:
            stats.add(now - deadline)
            counter["n"] -= 1
            return DONE
        if poll_delay_s > 0:
            t0 = time.perf_counter()
            while time.perf_counter() - t0 < poll_delay_s:
                pass                      # busy delay (paper Fig 8)
        return NOPROGRESS
    return poll


def run_pending_tasks(engine: ProgressEngine, n_tasks: int,
                      duration_s: float = 0.002,
                      poll_delay_s: float = 0.0,
                      stream: Stream | None = None,
                      repeats: int = 5) -> LatencyStats:
    stats = LatencyStats()
    for _ in range(repeats):
        counter = {"n": n_tasks}
        for _ in range(n_tasks):
            engine.async_start(
                make_dummy_task(duration_s, stats, counter, poll_delay_s),
                None, stream)
        t0 = time.perf_counter()
        while counter["n"] > 0:
            engine.progress(stream)
            if time.perf_counter() - t0 > 30:
                raise TimeoutError
    return stats


def row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.3f},{derived}"
