"""Kernel-substrate benchmarks on CPU: XLA-path (chunked online-softmax /
SSD) wall time + equivalence sanity vs naive formulations.

Interpret-mode Pallas timing is meaningless (Python interpreter), so the
perf rows time the XLA formulations the kernels mirror; the Pallas
kernels themselves are validated for correctness in tests/.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks._util import row
from repro.kernels import ref
from repro.models import layers as L


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    B, S, H, KVH, hd = 1, 1024, 4, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KVH, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KVH, hd), jnp.float32)

    naive = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=True))
    chunked = jax.jit(lambda q, k, v: L.attention(q, k, v, causal=True, chunk=256))
    rows.append(row("attention_naive_1k", _time(naive, q, k, v), "materializes SxS"))
    rows.append(row("attention_chunked_1k", _time(chunked, q, k, v),
                    "flash-equivalent dataflow"))

    # SSD vs attention at long seq (sub-quadratic vs quadratic scaling)
    from repro.models import mamba as M
    from repro.configs import get_config
    from tests.conftest import reduce_cfg
    cfg = reduce_cfg(get_config("mamba2-1.3b"), d_model=64)
    bp = M.init_params(cfg, key)["blocks"]
    bp1 = jax.tree.map(lambda a: a[0], bp)
    for s in (1024, 4096):
        x = jax.random.normal(key, (1, s, 64), jnp.bfloat16)
        f = jax.jit(lambda x: M.block_forward(bp1, cfg, x))
        rows.append(row(f"ssd_block_seq{s}", _time(f, x), "O(S) scan"))
    return rows
