"""Computation/communication-overlap demonstration (paper Fig 4/5).

Host-level, measurable on this container: a JAX computation dispatched
asynchronously overlaps with checkpoint I/O driven by the progress
engine.  Serial = compute then save; overlapped = dispatch compute,
drive engine progress (I/O advances) until the device result is ready.
The saved wall time is the paper's overlap win.
"""
from __future__ import annotations

import os
import tempfile
import time

import jax
import jax.numpy as jnp

from benchmarks._util import row
from repro.core import ProgressEngine, jax_future
from repro.train.checkpoint import AsyncCheckpointer


def run():
    rows = []
    n = 1024
    compute = jax.jit(lambda x: jnp.linalg.matrix_power(x @ x.T, 4).sum())
    x = jax.random.normal(jax.random.PRNGKey(0), (n, n))
    compute(x).block_until_ready()    # warm compile

    tree = {"w": jax.random.normal(jax.random.PRNGKey(1), (512, 4096))}

    with tempfile.TemporaryDirectory() as d:
        eng = ProgressEngine()
        ck = AsyncCheckpointer(d, eng)
        # serial: compute, then save
        t0 = time.perf_counter()
        compute(x).block_until_ready()
        req = ck.save_async(0, tree)
        eng.wait(req, timeout=60)
        serial = time.perf_counter() - t0

        # overlapped: dispatch compute, save advances via progress
        t0 = time.perf_counter()
        y = compute(x)                 # async dispatch
        req = ck.save_async(1, tree)
        fut = jax_future(eng, y)
        while not (fut.is_complete and req.is_complete):
            eng.progress()
        overlapped = time.perf_counter() - t0

    rows.append(row("overlap_serial_compute_plus_ckpt", serial * 1e6, ""))
    rows.append(row("overlap_engine_driven", overlapped * 1e6,
                    f"saved={100 * (1 - overlapped / serial):.0f}%"))
    return rows
