"""Paper Figs 7–12 (+ continuation-delivery rows) progress-engine
microbenchmarks, and the serve-decode latency family (fig-14-style:
user-space serve collectives vs the native-sharded and unsharded decode
paths, in a forced-multi-device child process)."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
import threading
import time

from benchmarks._util import LatencyStats, make_dummy_task, row, run_pending_tasks
from repro.core import (DEFERRED, DONE, INLINE, NOPROGRESS, CompletionWatcher,
                        ContinuationQueue, ProgressEngine, ProgressExecutor,
                        Request, TaskQueue)


def fig7_latency_vs_pending():
    """Latency overhead as #independent pending tasks grows (paper: <0.5µs
    below 32 tasks, then linear growth)."""
    rows = []
    for n in (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024):
        eng = ProgressEngine()
        stats = run_pending_tasks(eng, n, duration_s=0.002, repeats=3)
        rows.append(row(f"fig7_pending_{n}", stats.mean(),
                        f"p99={stats.p99():.1f}us"))
    return rows


def fig8_poll_overhead():
    """Latency vs per-poll busy delay; 10 concurrent tasks (paper Fig 8)."""
    rows = []
    for delay_us in (0, 1, 5, 10, 50, 100):
        eng = ProgressEngine()
        stats = run_pending_tasks(eng, 10, duration_s=0.002,
                                  poll_delay_s=delay_us * 1e-6, repeats=3)
        rows.append(row(f"fig8_polldelay_{delay_us}us", stats.mean(), ""))
    return rows


def fig9_thread_contention():
    """k threads all progressing the SAME (default) stream — the
    MPI_THREAD_MULTIPLE pathology (paper Fig 9)."""
    rows = []
    for k in (1, 2, 4, 8):
        eng = ProgressEngine()
        stats = LatencyStats()
        counter = {"n": 10 * k}
        for _ in range(10 * k):
            eng.async_start(make_dummy_task(0.002, stats, counter))
        stop = threading.Event()

        def spin():
            while not stop.is_set() and counter["n"] > 0:
                eng.progress()

        threads = [threading.Thread(target=spin) for _ in range(k)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        while counter["n"] > 0 and time.perf_counter() - t0 < 30:
            time.sleep(0.0002)
        stop.set()
        for t in threads:
            t.join()
        rows.append(row(f"fig9_threads_shared_{k}", stats.mean(), ""))
    return rows


def fig9_executor_scaling():
    """ProgressExecutor scaling: 1/2/4 workers × 8 streams of dummy tasks
    (the §4.4 fix, productised): per-stream serial contexts let added
    workers reduce progress latency instead of fighting one lock, and the
    executor's stats prove zero cross-stream contention."""
    rows = []
    n_streams, tasks_per_stream = 8, 10
    for workers in (1, 2, 4):
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, workers)
        streams = [ex.stream(f"s{i}") for i in range(n_streams)]
        stats = LatencyStats()
        counters = []
        for s in streams:
            c = {"n": tasks_per_stream}
            counters.append(c)
            for _ in range(tasks_per_stream):
                # per-poll busy delay makes worker parallelism observable
                eng.async_start(make_dummy_task(0.002, stats, c,
                                                poll_delay_s=5e-6), None, s)
        ex.start()
        t0 = time.perf_counter()
        while any(c["n"] > 0 for c in counters):
            time.sleep(0.0002)
            if time.perf_counter() - t0 > 30:
                raise TimeoutError
        ex.shutdown(drain=True, timeout=30)
        wstats = ex.worker_stats()
        contention = sum(s.contention for s in streams)
        rows.append(row(f"fig9_executor_w{workers}_s{n_streams}", stats.mean(),
                        f"steals={sum(w.steals for w in wstats)} "
                        f"contention={contention}"))
    return rows


def fig10_task_class():
    """All tasks behind ONE TaskQueue poll hook, completing in order at
    staggered intervals (paper Listing 1.4): latency flat vs count,
    because each progress call inspects only the queue head."""
    rows = []
    interval = 100e-6
    for n in (1, 8, 64, 512, 2048):
        eng = ProgressEngine()
        q = TaskQueue(eng)
        stats = LatencyStats()
        base = time.perf_counter() + 0.001
        done = {"n": n}

        def mk(i):
            deadline = base + i * interval

            def ready():
                return time.perf_counter() >= deadline

            def on_complete():
                stats.add(time.perf_counter() - deadline)
                done["n"] -= 1
            return ready, on_complete

        for i in range(n):
            r, c = mk(i)
            q.submit(r, c)
        t0 = time.perf_counter()
        while done["n"] > 0:
            eng.progress()
            if time.perf_counter() - t0 > 30:
                raise TimeoutError
        # only the head is checked per sweep: latency independent of n
        rows.append(row(f"fig10_taskclass_{n}", stats.mean(), ""))
    return rows


def fig11_streams():
    """k threads, each with its OWN stream: no contention (paper Fig 11)."""
    rows = []
    for k in (1, 2, 4, 8):
        eng = ProgressEngine()
        stats = LatencyStats()
        errors = []

        def worker():
            try:
                s = eng.stream()
                counter = {"n": 10}
                for _ in range(10):
                    eng.async_start(make_dummy_task(0.002, stats, counter),
                                    None, s)
                t0 = time.perf_counter()
                while counter["n"] > 0:
                    eng.progress(s)
                    if time.perf_counter() - t0 > 30:
                        raise TimeoutError
            except BaseException as e:  # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=worker) for _ in range(k)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        rows.append(row(f"fig11_streams_{k}", stats.mean(), ""))
    return rows


def fig12_request_query():
    """Overhead of the completion-event query loop vs #pending requests
    (paper Fig 12: negligible below ~256)."""
    rows = []
    for n in (1, 16, 64, 256, 1024):
        eng = ProgressEngine()
        w = CompletionWatcher(eng)
        reqs = [Request() for _ in range(n)]
        fired = []
        for r in reqs:
            w.watch(r, lambda rr: fired.append(1))
        # measure pure sweep cost with nothing complete
        t0 = time.perf_counter()
        iters = 200
        for _ in range(iters):
            eng.progress()
        sweep_us = (time.perf_counter() - t0) / iters * 1e6
        for r in reqs:
            r.complete()
        eng.progress()
        assert len(fired) == n
        rows.append(row(f"fig12_query_{n}", sweep_us, "per-progress sweep"))
    return rows


def fig13_continuation_vs_waitset():
    """Completion-delivery latency, callback vs wait-set (the serve-decode
    pattern): N staggered "decode steps" complete on a worker-progressed
    stream; measure deadline → consumer-observes-completion.

    * waitset  — the consumer thread loops ``wait_any`` over the
      outstanding requests and removes each winner (pull).
    * cont_inline   — continuations run ON the progress worker the moment
      the sweep observes completion (push, lowest latency).
    * cont_deferred — continuations queue and the consumer thread drains
      (push + owner-thread execution, the backpressure-bounded mode).
    """
    rows = []
    n, duration = 64, 0.002
    for mode in ("waitset", "cont_inline", "cont_deferred"):
        eng = ProgressEngine()
        ex = ProgressExecutor(eng, 1, steal=False)
        s = ex.stream("decode")
        stats = LatencyStats()
        deadlines = {}
        reqs = []
        for i in range(n):
            r = Request(tag=f"step{i}")
            deadlines[id(r)] = time.perf_counter() + duration * (1 + i % 8)
            reqs.append(r)

        def mk(r):
            def poll(thing):
                if time.perf_counter() >= deadlines[id(r)]:
                    r.complete()
                    return DONE
                return NOPROGRESS
            return poll

        observed = {"n": 0}

        def on_complete(r):
            stats.add(time.perf_counter() - deadlines[id(r)])
            observed["n"] += 1

        q = None
        if mode != "waitset":
            policy = INLINE if mode == "cont_inline" else DEFERRED
            q = ContinuationQueue(eng, s, policy=policy, name=mode)
            for r in reqs:
                q.attach(r, on_complete)
        for r in reqs:
            eng.async_start(mk(r), None, s)
        with ex:
            t0 = time.perf_counter()
            if mode == "waitset":
                outstanding = list(reqs)
                while outstanding:
                    _, winner = eng.wait_any(outstanding, timeout=30)
                    on_complete(winner)
                    outstanding.remove(winner)
            else:
                while observed["n"] < n:
                    if q.policy == DEFERRED:
                        q.drain(8)          # bounded owner drain
                    time.sleep(20e-6)
                    if time.perf_counter() - t0 > 30:
                        raise TimeoutError
            ex.drain(timeout=30)
        rows.append(row(f"fig13_{mode}_{n}", stats.mean(),
                        f"p99={stats.p99():.1f}us"))
    return rows


_SERVE_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import time
import jax, numpy as np
from repro import compat
from repro.configs import get_config
from repro.core import ProgressEngine
from repro.models import registry
from repro.serve.engine import GenRequest, ServeEngine

cfg = get_config("qwen2-0.5b").with_overrides(
    num_layers=2, d_model=64, d_ff=128, vocab_size=256, num_heads=4,
    num_kv_heads=2, head_dim=16, remat_policy="none")
params = registry.init_params(cfg, jax.random.PRNGKey(0))
mesh = compat.make_mesh((2,), ("model",))

def serve_once(mesh, backend, max_new=16, n_req=4):
    eng = ProgressEngine()
    srv = ServeEngine(cfg, params, eng, batch_slots=4, max_seq=128,
                      mesh=mesh, collective_backend=backend)
    # warm THIS engine's programs before timing (a fresh ServeEngine
    # means fresh jit closures: the user gather compiles at
    # construction, but decode — and the native gather — compile on
    # first use, and an unwarmed first step would bill XLA compiles to
    # the timed window, skewing the native-vs-user comparison)
    warm = GenRequest("warm", np.array([1, 2], np.int32), max_new_tokens=2)
    srv.submit(warm)
    srv.run_until_idle(timeout=600)
    warm_steps = srv.steps
    reqs = [GenRequest(f"r{i}", np.array([i + 1, i + 2], np.int32),
                       max_new_tokens=max_new) for i in range(n_req)]
    for r in reqs:
        srv.submit(r)
    t0 = time.perf_counter()
    srv.run_until_idle(timeout=600)
    wall = time.perf_counter() - t0
    steps = srv.steps - warm_steps
    toks = sum(len(r.out_tokens) for r in reqs)
    lat = srv.latency_snapshot()
    srv.close(timeout=60)
    return wall / max(steps, 1) * 1e6, toks, lat

rows = {}
for name, m, backend in (("unsharded", None, "native"),
                         ("native_m2", mesh, "native"),
                         ("user_m2", mesh, "user")):
    us, toks, lat = serve_once(m, backend)
    rows[name] = us
    print(f"serve_decode_{name},{us:.3f},per fused decode step; "
          f"{toks} tokens, ttft_p50={lat.ttft_ms_p50:.1f}ms")
print(f"serve_gain_user_vs_native_m2,{rows['native_m2'] / rows['user_m2']:.3f},"
      f"user {rows['user_m2']:.0f}us vs native in-program gather "
      f"{rows['native_m2']:.0f}us per step")
"""


_SERVE_CB_SNIPPET = """
import time
import jax, numpy as np
from repro.configs import get_config
from repro.core import ProgressEngine
from repro.models import registry
from repro.serve.engine import GenRequest, ServeEngine

cfg = get_config("qwen2-0.5b").with_overrides(
    num_layers=2, d_model=64, d_ff=128, vocab_size=256, num_heads=4,
    num_kv_heads=2, head_dim=16, remat_policy="none")
params = registry.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.RandomState(0)
N, MAX_SEQ = 64, 64
prompts = [rng.randint(1, 255, size=rng.randint(2, 17)).astype(np.int32)
           for _ in range(N)]
gaps = rng.exponential(0.002, size=N)        # Poisson arrivals, ~500 req/s

def trace(**kw):
    eng = ProgressEngine()
    srv = ServeEngine(cfg, params, eng, max_seq=MAX_SEQ, **kw)
    warm = GenRequest("warm", np.array([1, 2], np.int32), max_new_tokens=2)
    srv.submit(warm)
    srv.run_until_idle(timeout=600)          # compile outside the trace
    reqs = [GenRequest(f"r{i}", p, max_new_tokens=8)
            for i, p in enumerate(prompts)]
    t0 = time.perf_counter()
    due = 0.0
    for i, r in enumerate(reqs):
        due += gaps[i]
        while time.perf_counter() - t0 < due:
            eng.progress()
        srv.submit(r)
    srv.run_until_idle(timeout=600)
    lat = srv.latency_snapshot()
    sched = srv.scheduler_snapshot()
    srv.close(timeout=60)
    return [list(r.out_tokens) for r in reqs], lat, sched

# 4-lane baseline FIRST: if the wide sweep dies, these rows are
# salvaged by the parent (see serve_continuous_batching).  Same paged
# pool as the wide run (32+1 blocks of 8) capped at 4 decode lanes —
# the shape the retired fixed-slot engine used to serve
lane_toks, lane_lat, _ = trace(batch_slots=4, kv_block_size=8,
                               kv_blocks=33)
print(f"serve_cb_ttft_lane4,{lane_lat.ttft_ms_p50 * 1e3:.3f},"
      f"p50 TTFT; concurrency cap 4 lanes, p99 latency "
      f"{lane_lat.latency_ms_p99:.1f}ms")
print(f"serve_cb_p99_lane4,{lane_lat.latency_ms_p99 * 1e3:.3f},"
      f"p99 request latency at a 4-lane cap")

# wide: SAME cache bytes but 12 decode lanes — block granularity is
# what buys the concurrency, and per-stream tokens must not change
paged_toks, paged_lat, sched = trace(
    batch_slots=12, kv_block_size=8, kv_blocks=33)
assert paged_toks == lane_toks, "wide-pool trace diverged from 4-lane"
print(f"serve_cb_ttft_paged,{paged_lat.ttft_ms_p50 * 1e3:.3f},"
      f"p50 TTFT; peak {sched.peak_resident} resident on the same "
      f"bytes, {sched.preemptions} preemptions")
print(f"serve_cb_p99_paged,{paged_lat.latency_ms_p99 * 1e3:.3f},"
      f"p99 request latency, paged pool (32 blocks of 8)")
print(f"cb_gain_concurrency,{sched.peak_resident / 4:.3f},"
      f"peak resident {sched.peak_resident} vs the 4-lane cap at "
      f"equal cache bytes (ratio row: untracked by the trend gate)")
"""


_RECOVERY_SNIPPET = """
import time
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.collectives.nonblocking import MembershipEpoch
from repro.core import ProgressEngine
from repro.models import registry
from repro.serve.engine import GenRequest, ServeEngine

cfg = get_config("qwen2-0.5b").with_overrides(
    num_layers=2, d_model=64, d_ff=128, vocab_size=256, num_heads=4,
    num_kv_heads=2, head_dim=16, remat_policy="none")
params = registry.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.RandomState(0)
prompts = [rng.randint(1, 255, size=rng.randint(2, 9)).astype(np.int32)
           for _ in range(8)]

def recover(**kw):
    # invalidate mid-decode; time invalidate -> drained, remeshed,
    # re-admitted and idle (the full membership-change recovery path,
    # including the rebuilt decode program's compile)
    eng = ProgressEngine()
    epoch = MembershipEpoch()
    srv = ServeEngine(cfg, params, eng, batch_slots=4, max_seq=64,
                      epoch=epoch, **kw)
    reqs = [GenRequest(f"r{i}", p, max_new_tokens=8)
            for i, p in enumerate(prompts)]
    for r in reqs:
        srv.submit(r)
    t0 = time.perf_counter()
    while sum(len(r.out_tokens) for r in reqs) < 8 \\
            and time.perf_counter() - t0 < 300:
        eng.progress()
    t0 = time.perf_counter()
    epoch.invalidate(survivors=1, reason="bench")
    srv.run_until_idle(timeout=600)
    dt = time.perf_counter() - t0
    lat = srv.latency_snapshot()
    assert lat.failed == 0 and srv.remeshes == 1, (lat.failed, srv.remeshes)
    srv.close(timeout=60)
    return dt

# serve row FIRST so a trainer-section crash still salvages it
dt = recover(kv_block_size=8)
print(f"recovery_serve_paged,{dt * 1e6:.0f},invalidate -> drained+"
      f"remeshed+re-admitted+idle with per-lane KV checkpoint/restore "
      f"migration, 8 reqs, paged pool")

# trainer: remesh-and-retry step (catches MembershipError, rebuilds the
# split step on the survivors, retries the same batch)
from jax.sharding import PartitionSpec as P, NamedSharding
from repro import compat
from repro.collectives.overlap import EngineGradReducer
from repro.data.pipeline import SyntheticLM
from repro.distributed import elastic
from repro.train import optimizer as opt_mod
from repro.train.train_loop import Trainer, TrainLoopConfig, \\
    UserCollectiveStep

tcfg = get_config("smollm-360m").with_overrides(
    num_layers=2, d_model=64, d_ff=128, vocab_size=256, num_heads=4,
    num_kv_heads=2, head_dim=16, remat_policy="none")
src = SyntheticLM(tcfg.vocab_size, 16, 4, seed=1)
it = iter(src)
batches = [{k: jnp.asarray(v) for k, v in next(it).items()}
           for _ in range(8)]

class ListPipe:
    def __init__(self, bs):
        self.bs = list(bs)
    def next_batch(self):
        return self.bs.pop(0)
    def close(self):
        pass

ocfg = opt_mod.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=8)

def local_grad(p, batch):
    (loss, mets), g = jax.value_and_grad(
        registry.loss_fn, has_aux=True)(p, tcfg, batch)
    return (jax.tree.map(lambda v: v[None], dict(mets, loss=loss)),
            jax.tree.map(lambda v: v[None].astype(jnp.float32), g))

def make_grad_fn(mesh_):
    return jax.jit(compat.shard_map(local_grad, mesh=mesh_,
                                    in_specs=(P(), P("data")),
                                    out_specs=P("data")))

@jax.jit
def apply_fn(p, o, g, sm):
    p, o, om = opt_mod.apply(ocfg, o, p, g)
    return p, o, dict({k: jnp.mean(v) for k, v in sm.items()}, **om)

eng = ProgressEngine()
mesh = elastic.remesh(1, prefer_model=1)
epoch = MembershipEpoch()
red = EngineGradReducer(mesh, "data", engine=eng, chunks=2, mean=True,
                        epoch=epoch)
split = UserCollectiveStep(make_grad_fn(mesh), apply_fn, red)

def remesh_fn(exc, p, o):
    new_mesh = elastic.remesh(exc.survivors, prefer_model=1)
    red.remesh(new_mesh, "data")
    p = jax.device_put(p, NamedSharding(new_mesh, P()))
    o = jax.device_put(o, NamedSharding(new_mesh, P()))
    return UserCollectiveStep(make_grad_fn(new_mesh), apply_fn, red), p, o

step_times, fired = {}, []

def hook(s, m):
    step_times[s] = m["step_time_s"]
    if s == 3 and not fired:
        fired.append(s)
        epoch.invalidate(survivors=1, reason="bench")

params_t = registry.init_params(tcfg, jax.random.PRNGKey(0))
tr = Trainer(None, params_t, opt_mod.init(params_t), ListPipe(batches),
             TrainLoopConfig(total_steps=8, checkpoint_every=10**6,
                             checkpoint_dir="/tmp/bench_recovery_ckpt",
                             log_every=1, resume=False,
                             collective_backend="user"),
             engine=eng, split_step=split, epoch=epoch,
             remesh_fn=remesh_fn, hooks=[hook])
tr.run()
red.close()
assert tr.recoveries == 1, tr.recoveries
warm = min(step_times[s] for s in step_times if s not in (0, 4))
print(f"recovery_train_step,{step_times[4] * 1e6:.0f},remesh+retry "
      f"step wall time (warm step {warm * 1e6:.0f}us)")
"""


_FSDP_SNIPPET = """
import os, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.collectives.nonblocking import CollectiveSpec
from repro.collectives.overlap import FsdpLayout, FsdpReducer
from repro.core import ProgressEngine
from repro.data.pipeline import SyntheticLM
from repro.launch.train import build_fsdp_programs
from repro.models import registry
from repro.train import optimizer as opt_mod
from repro.train.train_loop import FsdpStep, Trainer, TrainLoopConfig

cfg = get_config("smollm-360m").with_overrides(
    num_layers=2, d_model=64, d_ff=128, vocab_size=256, num_heads=4,
    num_kv_heads=2, head_dim=16, remat_policy="none")
STEPS = 12
ocfg = opt_mod.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=STEPS)
mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
axis, n = "data", 2

src = SyntheticLM(cfg.vocab_size, 16, 4, seed=7)
it = iter(src)
batches = [{k: jnp.asarray(v) for k, v in next(it).items()}
           for _ in range(STEPS)]

def timed(fn, reps=3):
    fn()                                   # warmup / compile
    t0 = time.monotonic()
    for _ in range(reps):
        fn()
    return (time.monotonic() - t0) / reps

# unsharded baseline FIRST: a crash in the FSDP sweep must still
# salvage this row (same discipline as the serve families)
params = registry.init_params(cfg, jax.random.PRNGKey(0))

@jax.jit
def base_step(p, o, batch):
    (loss, mets), g = jax.value_and_grad(
        registry.loss_fn, has_aux=True)(p, cfg, batch)
    p, o, om = opt_mod.apply(ocfg, o, p, g)
    return p, o, loss

t_base = timed(lambda: jax.block_until_ready(
    base_step(params, opt_mod.init(params), batches[0])))
print(f"fsdp_unsharded_step,{t_base * 1e6:.0f},replicated jitted "
      f"grad+AdamW baseline, no sharding (2x2-device child)",
      flush=True)

# shared FSDP scaffolding: flat per-dtype bucket shards [n, W/n] over
# the data axis; the SAME jitted grad/apply programs serve both
# backends, only the byte movement differs
layout = FsdpLayout(params, n, 1 << 22)
sharding = NamedSharding(mesh, P(axis))

def fresh_state():
    shards = layout.shard_params(params, mesh, axis)
    return shards, opt_mod.AdamWState(
        jnp.zeros((), jnp.int32),
        [jax.device_put(jnp.zeros_like(s), sharding) for s in shards],
        [jax.device_put(jnp.zeros_like(s), sharding) for s in shards])

grad_fn, apply_fn, ag_fn, rs_fn = build_fsdp_programs(
    cfg, ocfg, mesh, layout, axis=axis)

def native_step(sh, st, batch):
    flats = ag_fn(sh)
    smets, flat_grads = grad_fn(flats, batch)
    gshards = rs_fn(flat_grads)
    return apply_fn(sh, st, gshards, smets)

sh_n, st_n = fresh_state()
t_native = timed(lambda: jax.block_until_ready(
    native_step(sh_n, st_n, batches[0])))
print(f"fsdp_native_step,{t_native * 1e6:.0f},in-program "
      f"all_gather/psum_scatter FSDP step, data={n} model=2",
      flush=True)

# user backend: persistent engine handles, next step's gathers chained
# off the optimizer's compute futures (measured via the Trainer so the
# cross-step prefetch chain is real)
class ListPipe:
    def __init__(self, bs):
        self.bs = list(bs)
    def next_batch(self):
        return self.bs.pop(0)
    def close(self):
        pass

eng = ProgressEngine()
spec = CollectiveSpec(backend="user", chunks=2)
reducer = FsdpReducer(mesh, axis, engine=eng, spec=spec,
                      bucket_bytes=1 << 22)
split = FsdpStep(grad_fn, apply_fn, reducer, spec=spec)
step_times = {}
sh_u, st_u = fresh_state()
tr = Trainer(None, sh_u, st_u, ListPipe(batches),
             TrainLoopConfig(total_steps=STEPS, checkpoint_every=10**6,
                             checkpoint_dir="/tmp/bench_fsdp_ckpt",
                             log_every=1, resume=False,
                             collective_spec=spec),
             engine=eng, split_step=split,
             hooks=[lambda s, m: step_times.__setitem__(
                 s, m["step_time_s"])])
tr.run()
overlap, gathers = reducer.prefetch_overlap, reducer.gathers
reducer.close()
warm = sorted(step_times[s] for s in step_times if s > 0)
t_user = warm[len(warm) // 2]
print(f"fsdp_user_step,{t_user * 1e6:.0f},persistent engine "
      f"reduce-scatter/all-gather FSDP step, median of "
      f"{len(warm)} warm steps", flush=True)
assert overlap > 0.0, overlap
print(f"fsdp_prefetch_overlap,{overlap:.3f},fraction of the gather "
      f"window hidden behind compute ({gathers} chained gathers; "
      f"HIGHER is better — a drop shows as 'improved' in the gate)",
      flush=True)
"""


def fsdp_training():
    """ZeRO-style FSDP step family (fsdp_* rows, 2x2 host devices in a
    child): the replicated unsharded baseline, the native in-program
    all_gather/psum_scatter step, the user-backend step on persistent
    engine handles, and the measured prefetch-overlap fraction of the
    continuation-chained gathers.  Baseline prints before the FSDP
    sweep so a crash in the new path still salvages it (same
    discipline as serve_collectives)."""
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    try:
        proc = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(_FSDP_SNIPPET)],
            capture_output=True, text=True, timeout=1200, env=env)
        stdout, rc, err = proc.stdout, proc.returncode, proc.stderr or ""
    except subprocess.TimeoutExpired as e:
        stdout, rc, err = e.stdout or "", -1, "timeout after 1200s"
    rows = [l for l in stdout.splitlines() if l.startswith("fsdp_")]
    if rc != 0:
        rows.append(f"fsdp,nan,FAILED(rc={rc}): {err[-200:]}")
    return rows


_DEBUG_OVERHEAD_SNIPPET = """
import os, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp

from repro import compat
from repro.collectives import nonblocking as NB
from repro.core import ProgressEngine, debug


def step_time(reps=50):
    # fresh stack per measurement: make_lock picks plain Lock vs
    # OrderedLock at construction, so the debug run must build its own
    mesh = compat.make_mesh((4,), ("x",))
    eng = ProgressEngine()
    coll = NB.UserCollectives(eng)
    x = jnp.ones((4, 4096), jnp.float32)
    h = coll.allreduce_init(x, mesh, "x")
    for _ in range(5):
        h.start(x).wait(timeout=120)            # warm: compiled + cached
    t0 = time.monotonic()
    for _ in range(reps):
        h.start(x).wait(timeout=120)
    us = (time.monotonic() - t0) / reps * 1e6
    h.close()
    coll.close()
    return us


off = step_time()
prev = debug.set_debug(True)
on = step_time()
debug.set_debug(prev)
tax = (on - off) / off * 100.0
print(f"debug_overhead_off,{off:.2f},warmed persistent allreduce step")
print(f"debug_overhead_on,{on:.2f},REPRO_DEBUG tax {tax:+.1f}% (target <5)")
"""


def debug_overhead():
    """REPRO_DEBUG=1 tax on a warmed persistent-allreduce step
    (debug_overhead_* rows, 4 host devices in a child): same step timed
    with the checkers dormant and armed — the lifecycle hooks and
    ordered locks must stay under the ~5%% budget that makes running
    tier-1 under REPRO_DEBUG=1 in CI viable."""
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_DEBUG", None)      # the child toggles it itself
    try:
        proc = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(_DEBUG_OVERHEAD_SNIPPET)],
            capture_output=True, text=True, timeout=1200, env=env)
        stdout, rc, err = proc.stdout, proc.returncode, proc.stderr or ""
    except subprocess.TimeoutExpired as e:
        stdout, rc, err = e.stdout or "", -1, "timeout after 1200s"
    rows = [l for l in stdout.splitlines() if l.startswith("debug_overhead")]
    if rc != 0:
        rows.append(f"debug_overhead,nan,FAILED(rc={rc}): {err[-200:]}")
    return rows


_PIPELINE_SNIPPET = """
import os, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import ProgressEngine, ProgressExecutor
from repro.distributed import pipeline as pl

S, M, d, h, mb = 4, 8, 32, 64, 8
mesh = Mesh(np.array(jax.devices()[:S]), ("stage",))

def stage_fn(p, x):
    return x + jnp.tanh(x @ p["w1"]) @ p["w2"]

def loss_fn(y, t):
    return jnp.mean((y - t) ** 2)

k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(0), 4)
params = {"w1": jax.random.normal(k1, (S, d, h)) * 0.1,
          "w2": jax.random.normal(k2, (S, h, d)) * 0.1}
xs = jax.random.normal(k3, (M, mb, d))
ts = jax.random.normal(k4, (M, mb, d))

def timed(fn, reps=3):
    fn()                                   # warmup / compile
    t0 = time.monotonic()
    for _ in range(reps):
        fn()
    return (time.monotonic() - t0) / reps

# baseline rows FIRST: a crash in the DAG sweep must still salvage them
def seq_step(params, xs, ts):
    scale = jnp.float32(1.0 / M)
    acc = jax.tree.map(jnp.zeros_like, params)
    losses = []
    for m in range(M):
        def head(p, x=xs[m], t=ts[m]):
            y = x
            for s in range(S):
                y = stage_fn(jax.tree.map(lambda a: a[s], p), y)
            return loss_fn(y, t)
        lm, pull = jax.vjp(head, params)
        acc = jax.tree.map(jnp.add, acc, pull(scale)[0])
        losses.append(lm)
    return sum(losses) * scale, acc

seq_jit = jax.jit(seq_step)
t_seq = timed(lambda: jax.block_until_ready(seq_jit(params, xs, ts)))
print(f"pipeline_seq_step,{t_seq * 1e6:.0f},single-device jitted "
      f"microbatch-accumulation baseline (S={S},M={M})", flush=True)

gmesh = mesh
pparams = jax.device_put(params, NamedSharding(gmesh, P("stage")))
gp = pl.gpipe(stage_fn, gmesh, "stage", S)

def gp_loss(p, xs, ts):
    ys = gp(p, xs)
    return jnp.mean(jnp.stack([loss_fn(ys[m], ts[m]) for m in range(M)]))

gp_jit = jax.jit(jax.value_and_grad(gp_loss))
t_gp = timed(lambda: jax.block_until_ready(gp_jit(pparams, xs, ts)))
print(f"pipeline_gpipe_step,{t_gp * 1e6:.0f},monolithic lax.scan "
      f"fwd+bwd reference (S={S},M={M})", flush=True)

engine = ProgressEngine()
ex = ProgressExecutor(engine, num_workers=2).start()
engine.attach_executor(ex)
sched = pl.PipelineSchedule(stage_fn, mesh, "stage", S, loss_fn=loss_fn,
                            engine=engine, executor=ex)
t_1f1b = timed(lambda: sched.step(params, xs, ts, timeout=600))
print(f"pipeline_1f1b_step,{t_1f1b * 1e6:.0f},event-driven continuation-"
      f"DAG step, persistent p2p handoffs (S={S},M={M})", flush=True)

# measured bubble, two ways.  Tick-based: idle slots of the DAG the run
# actually executed (cells retired per stage vs the realized tick span)
# — schedule-correctness, exact on any host.  Wall-based: per-stage
# stream idle from the cell spans — only meaningful with >= S cores
# (this container timeshares one), so it is reported, not asserted.
tm = sched.last_step_timing
assert tm is not None, "no step timing recorded"
cells = sum(tm["cells"])
tick_bubble = 1.0 - cells / (S * tm["grid_ticks"])
analytic = pl.bubble_fraction(S, M, "1f1b")
wall_bubble = tm.get("bubble", float("nan"))
idle_us = sum(tm.get("idle_s", [0])) / max(len(tm.get("idle_s", [1])), 1)
print(f"pipeline_1f1b_bubble,{idle_us * 1e6:.0f},measured={tick_bubble:.4f}"
      f" analytic={analytic:.4f} wall={wall_bubble:.3f} (S={S},M={M})",
      flush=True)
st = sched.stats()
assert st["p2p_stream_completions"] > 0, st
assert abs(tick_bubble - analytic) <= 0.02, (tick_bubble, analytic)
sched.close()
ex.shutdown(drain=True, timeout=120)
"""


def pipeline_parallelism():
    """Pipeline-parallel step family (pipeline_* rows, 4 host devices
    in a child): sequential microbatch accumulation, the monolithic
    GPipe scan, and the event-driven 1F1B continuation-DAG schedule,
    plus the measured-vs-analytic bubble row.  Baseline rows print
    before the 1F1B sweep so a crash in the new path still salvages
    them (same discipline as serve_collectives)."""
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    try:
        proc = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(_PIPELINE_SNIPPET)],
            capture_output=True, text=True, timeout=1200, env=env)
        stdout, rc, err = proc.stdout, proc.returncode, proc.stderr or ""
    except subprocess.TimeoutExpired as e:
        stdout, rc, err = e.stdout or "", -1, "timeout after 1200s"
    rows = [l for l in stdout.splitlines() if l.startswith("pipeline_")]
    if rc != 0:
        rows.append(f"pipeline,nan,FAILED(rc={rc}): {err[-200:]}")
    return rows


def recovery():
    """Membership-change recovery path (recovery_* rows, single-device
    child): serve drain/remesh/re-admit to idle on the paged pool
    (including per-lane KV checkpoint/restore migration), and the
    trainer's remesh-and-retry step.  The serve row prints first so a
    crash mid-sweep salvages it (same discipline as the serve
    families)."""
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    try:
        proc = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(_RECOVERY_SNIPPET)],
            capture_output=True, text=True, timeout=1200, env=env)
        stdout, rc, err = proc.stdout, proc.returncode, proc.stderr or ""
    except subprocess.TimeoutExpired as e:
        stdout, rc, err = e.stdout or "", -1, "timeout after 1200s"
    rows = [l for l in stdout.splitlines() if l.startswith("recovery_")]
    if rc != 0:
        rows.append(f"recovery,nan,FAILED(rc={rc}): {err[-200:]}")
    return rows


def serve_continuous_batching():
    """Continuous-batching arrival trace (serve_cb rows): one Poisson
    trace served by the paged engine capped at 4 decode lanes and by
    the same pool opened wide, at equal cache memory.  The child prints
    the 4-lane rows before starting the wide sweep, so a timeout or
    crash mid-sweep still salvages the baseline rows (same discipline
    as serve_collectives)."""
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    try:
        proc = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(_SERVE_CB_SNIPPET)],
            capture_output=True, text=True, timeout=1200, env=env)
        stdout, rc, err = proc.stdout, proc.returncode, proc.stderr or ""
    except subprocess.TimeoutExpired as e:
        stdout, rc, err = e.stdout or "", -1, "timeout after 1200s"
    rows = [l for l in stdout.splitlines()
            if l.startswith(("serve_cb", "cb_gain"))]
    if rc != 0:
        rows.append(f"serve_cb,nan,FAILED(rc={rc}): {err[-200:]}")
    return rows


def serve_collectives():
    """Serve-decode latency family (fig-14 style, 2 host devices in a
    child): per-step latency of the fused decode chain — unsharded,
    model-axis-sharded with the native in-program all-gather, and with
    the persistent user-space all-gather on the serve-collective
    stream.  ``serve_gain_*`` holds the user/native ratio (excluded
    from the trend gate by prefix)."""
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    try:
        proc = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(_SERVE_SNIPPET)],
            capture_output=True, text=True, timeout=1200, env=env)
        stdout, rc, err = proc.stdout, proc.returncode, proc.stderr or ""
    except subprocess.TimeoutExpired as e:
        stdout, rc, err = e.stdout or "", -1, "timeout after 1200s"
    # salvage completed rows: a dead sweep must not hide earlier rows
    rows = [l for l in stdout.splitlines() if l.startswith("serve_")]
    if rc != 0:
        rows.append(f"serve_decode,nan,FAILED(rc={rc}): {err[-200:]}")
    return rows


def run():
    rows = []
    rows += fig7_latency_vs_pending()
    rows += fig8_poll_overhead()
    rows += fig9_thread_contention()
    rows += fig9_executor_scaling()
    rows += fig10_task_class()
    rows += fig11_streams()
    rows += fig12_request_query()
    rows += fig13_continuation_vs_waitset()
    rows += serve_collectives()
    rows += serve_continuous_batching()
    rows += pipeline_parallelism()
    rows += fsdp_training()
    rows += recovery()
    rows += debug_overhead()
    return rows
