"""Paper Fig 13: user-level allreduce vs the native collective.

Runs in a subprocess with 8 host devices (the main process stays
single-device).  Measures wall time of a jitted single-int allreduce:
native ``psum`` vs the user-level schedules — the paper's result is that
the specialized user-level implementation is competitive (it beats
MPICH's Iallreduce in the paper thanks to context shortcuts).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.collectives import schedules as S

mesh = compat.make_mesh((8,), ("x",))
x = jnp.arange(8, dtype=jnp.float32).reshape(8, 1)   # one scalar per rank

def native(v):
    return jax.lax.psum(v, "x")

fns = {"native_psum": native}
fns.update({k: (lambda f: lambda v: f(v, "x"))(f) for k, f in S.ALGORITHMS.items()})

for name, fn in fns.items():
    jitted = jax.jit(compat.shard_map(fn, mesh=mesh, in_specs=P("x"), out_specs=P("x")))
    out = jitted(x); out.block_until_ready()          # compile
    iters = 300
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jitted(x)
    out.block_until_ready()
    us = (time.perf_counter() - t0) / iters * 1e6
    print(f"fig13_allreduce_1int_{name},{us:.3f},8 host devices")
"""


def run():
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(SNIPPET)],
                          capture_output=True, text=True, timeout=600, env=env)
    if proc.returncode != 0:
        return [f"fig13_allreduce,nan,FAILED: {proc.stderr[-200:]}"]
    return [l for l in proc.stdout.splitlines() if l.startswith("fig13")]
