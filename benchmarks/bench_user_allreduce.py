"""Paper Fig 13/14: user-level allreduce vs the native collective.

Runs in a subprocess with 8 host devices (the main process stays
single-device).  Fig 13: wall time of a jitted single-int allreduce,
native ``psum`` vs the user-level schedules — the paper's result is
that the specialized user-level implementation is competitive (it
beats MPICH's Iallreduce in the paper thanks to context shortcuts).
Fig 14: the *nonblocking* engine-driven ``iallreduce`` (chunk-pipelined
round schedules, see ``collectives/nonblocking.py``) vs native ``psum``
at 128KB / 4MB / 64MB / 256MB, two ways:

* **one-shot per-round** (``round_batch=1``, the PR-3 baseline rows —
  names unchanged so the CI trend report tracks them): every round of
  every chunk is its own dispatch + engine round trip;
* **persistent + round batching** (``allreduce_init``/``start`` with the
  auto batch factor): the plan and fused round programs are built once,
  each ``start`` re-binds the payload.  Small payloads collapse to 1–2
  dispatches (with multi-chunk payloads stacked through one program);
  large payloads keep per-round dispatch for chunk pipelining.

``fig14_persistent_gain_*`` rows record the per-config speedup of the
persistent path over the one-shot per-round baseline — the small-payload
amortization win the trend gate must never lose.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.collectives import schedules as S

mesh = compat.make_mesh((8,), ("x",))
x = jnp.arange(8, dtype=jnp.float32).reshape(8, 1)   # one scalar per rank

def native(v):
    return jax.lax.psum(v, "x")

fns = {"native_psum": native}
fns.update({k: (lambda f: lambda v: f(v, "x"))(f) for k, f in S.ALGORITHMS.items()})

for name, fn in fns.items():
    jitted = jax.jit(compat.shard_map(fn, mesh=mesh, in_specs=P("x"), out_specs=P("x")))
    out = jitted(x); out.block_until_ready()          # compile
    iters = 300
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jitted(x)
    out.block_until_ready()
    us = (time.perf_counter() - t0) / iters * 1e6
    print(f"fig13_allreduce_1int_{name},{us:.3f},8 host devices")

# ---- Fig 14: nonblocking engine-driven iallreduce vs native, by size ----
from repro.core import ProgressEngine
from repro.collectives import nonblocking as NB

eng = ProgressEngine()
coll = NB.UserCollectives(eng)
native_jit = jax.jit(compat.shard_map(native, mesh=mesh, in_specs=P("x"),
                                      out_specs=P("x")))

def timed(issue, iters):
    out = issue()                             # compile / warm everything
    t0 = time.perf_counter()
    for _ in range(iters):
        out = issue()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6

# payload rows: 128KB + 4MB (latency regime: persistent + round batching
# collapse each start to 1-2 dispatches), 64MB, 256MB (bandwidth regime:
# per-round dispatch keeps chunks pipelining; recursive doubling with
# 2-way chunk pipelining lands within ~1.4x of the native psum).
for D, iters in ((4096, 30), (131072, 20), (2097152, 8), (8388608, 4)):
    xs = jnp.ones((8, D), jnp.float32)
    nbytes = xs.size * 4
    out = native_jit(xs); out.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = native_jit(xs)
    out.block_until_ready()
    nat_us = (time.perf_counter() - t0) / iters * 1e6
    print(f"fig14_native_psum_{nbytes}B,{nat_us:.3f},"
          f"bw={nbytes / nat_us / 1e3:.2f}GB/s")
    for alg in ("ring", "recursive_doubling"):
        for K in (1, 2, 4):
            # one-shot, one dispatch per round: the PR-3 baseline row
            # (same name across PRs — the trend report tracks it)
            us = timed(lambda: coll.iallreduce(
                xs, mesh, "x", algorithm=alg, chunks=K,
                round_batch=1).wait(timeout=600), iters)
            print(f"fig14_user_iallreduce_{nbytes}B_{alg}_c{K},{us:.3f},"
                  f"bw={nbytes / us / 1e3:.2f}GB/s vs native "
                  f"x{us / nat_us:.2f}")
            # persistent handle + auto round batching: *_init once,
            # start() per iteration re-binds the payload
            h = coll.allreduce_init(xs, mesh, "x", algorithm=alg, chunks=K)
            pus = timed(lambda: h.start(xs).wait(timeout=600), iters)
            print(f"fig14_user_iallreduce_persistent_{nbytes}B_{alg}_c{K},"
                  f"{pus:.3f},rb={h.round_batch} "
                  f"bw={nbytes / pus / 1e3:.2f}GB/s vs native "
                  f"x{pus / nat_us:.2f}")
            # value field IS the speedup ratio (trend.py excludes these
            # rows from the latency gate by prefix)
            print(f"fig14_persistent_gain_{nbytes}B_{alg}_c{K},"
                  f"{us / pus:.3f},persistent {pus:.1f}us vs one-shot "
                  f"per-round {us:.1f}us")
            h.close()
coll.close()
"""


def run():
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    try:
        proc = subprocess.run([sys.executable, "-c", textwrap.dedent(SNIPPET)],
                              capture_output=True, text=True, timeout=1500,
                              env=env)
        stdout, rc, err = proc.stdout, proc.returncode, proc.stderr or ""
    except subprocess.TimeoutExpired as e:
        stdout, rc, err = e.stdout or "", -1, "timeout after 1500s"
    # salvage whatever rows completed: a slow/dead fig14 sweep must not
    # throw away the fig13 rows already printed before it
    rows = [l for l in stdout.splitlines() if l.startswith("fig1")]
    if rc != 0:
        rows.append(f"fig13_14_allreduce,nan,FAILED(rc={rc}): {err[-200:]}")
    return rows
