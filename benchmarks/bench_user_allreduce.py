"""Paper Fig 13/14: user-level allreduce vs the native collective.

Runs in a subprocess with 8 host devices (the main process stays
single-device).  Fig 13: wall time of a jitted single-int allreduce,
native ``psum`` vs the user-level schedules — the paper's result is
that the specialized user-level implementation is competitive (it
beats MPICH's Iallreduce in the paper thanks to context shortcuts).
Fig 14: the *nonblocking* engine-driven ``iallreduce`` (chunk-pipelined
round schedules, see ``collectives/nonblocking.py``) vs native ``psum``
at several payload sizes and chunk counts, with achieved bandwidth —
the user schedule is expected within 2× of native at the largest size.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.collectives import schedules as S

mesh = compat.make_mesh((8,), ("x",))
x = jnp.arange(8, dtype=jnp.float32).reshape(8, 1)   # one scalar per rank

def native(v):
    return jax.lax.psum(v, "x")

fns = {"native_psum": native}
fns.update({k: (lambda f: lambda v: f(v, "x"))(f) for k, f in S.ALGORITHMS.items()})

for name, fn in fns.items():
    jitted = jax.jit(compat.shard_map(fn, mesh=mesh, in_specs=P("x"), out_specs=P("x")))
    out = jitted(x); out.block_until_ready()          # compile
    iters = 300
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jitted(x)
    out.block_until_ready()
    us = (time.perf_counter() - t0) / iters * 1e6
    print(f"fig13_allreduce_1int_{name},{us:.3f},8 host devices")

# ---- Fig 14: nonblocking engine-driven iallreduce vs native, by size ----
from repro.core import ProgressEngine
from repro.collectives import nonblocking as NB

eng = ProgressEngine()
coll = NB.UserCollectives(eng)
native_jit = jax.jit(compat.shard_map(native, mesh=mesh, in_specs=P("x"),
                                      out_specs=P("x")))

# payload rows: 128KB (latency regime), 64MB, 256MB (bandwidth regime).
# On CPU hosts the per-round dispatch+sync cost dominates small sizes;
# at the largest size recursive doubling (3 rounds) with 2-way chunk
# pipelining lands within 2x of the native psum — the acceptance bar.
for D, iters in ((4096, 30), (2097152, 8), (8388608, 4)):
    xs = jnp.ones((8, D), jnp.float32)
    nbytes = xs.size * 4
    out = native_jit(xs); out.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = native_jit(xs)
    out.block_until_ready()
    nat_us = (time.perf_counter() - t0) / iters * 1e6
    print(f"fig14_native_psum_{nbytes}B,{nat_us:.3f},"
          f"bw={nbytes / nat_us / 1e3:.2f}GB/s")
    for alg in ("ring", "recursive_doubling"):
        for K in (1, 2, 4):
            req = coll.iallreduce(xs, mesh, "x", algorithm=alg, chunks=K)
            req.wait(timeout=600)                 # compile all rounds
            t0 = time.perf_counter()
            for _ in range(iters):
                req = coll.iallreduce(xs, mesh, "x", algorithm=alg, chunks=K)
                out = req.wait(timeout=600)
            jax.block_until_ready(out)
            us = (time.perf_counter() - t0) / iters * 1e6
            print(f"fig14_user_iallreduce_{nbytes}B_{alg}_c{K},{us:.3f},"
                  f"bw={nbytes / us / 1e3:.2f}GB/s vs native "
                  f"x{us / nat_us:.2f}")
coll.close()
"""


def run():
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    try:
        proc = subprocess.run([sys.executable, "-c", textwrap.dedent(SNIPPET)],
                              capture_output=True, text=True, timeout=900,
                              env=env)
        stdout, rc, err = proc.stdout, proc.returncode, proc.stderr or ""
    except subprocess.TimeoutExpired as e:
        stdout, rc, err = e.stdout or "", -1, "timeout after 900s"
    # salvage whatever rows completed: a slow/dead fig14 sweep must not
    # throw away the fig13 rows already printed before it
    rows = [l for l in stdout.splitlines() if l.startswith("fig1")]
    if rc != 0:
        rows.append(f"fig13_14_allreduce,nan,FAILED(rc={rc}): {err[-200:]}")
    return rows
