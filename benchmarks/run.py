"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Sections:
  figs 7-12  progress-engine microbenchmarks (paper §4.2-§4.5)
  fig 13/14  user-level (i)allreduce vs native (paper §4.7; 8-dev child)
  overlap    computation/communication overlap (paper §2.3 thesis)
  kernels    substrate formulation timings
Roofline tables (the TPU-target performance report) are produced by the
dry-run: ``python -m repro.launch.dryrun`` + EXPERIMENTS.md.

``--json PATH`` (default ``BENCH_progress.json``) additionally writes a
machine-readable summary — the CI uploads it as an artifact so the perf
trajectory accumulates across commits.  ``--sections a,b`` filters.
"""
from __future__ import annotations

import argparse
import json
import math
import platform
import subprocess
import sys
import time
import traceback


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, timeout=10).stdout.strip() or "unknown"
    except Exception:  # noqa: BLE001
        return "unknown"


def _parse_row(section: str, line: str) -> dict:
    name, us, derived = (line.split(",", 2) + ["", ""])[:3]
    try:
        us_val = float(us)
    except ValueError:
        us_val = None
    if us_val is not None and not math.isfinite(us_val):
        us_val = None       # 'nan' failure rows must stay strict-JSON
    return {"section": section, "name": name, "us_per_call": us_val,
            "derived": derived}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_progress.json",
                    help="write a JSON summary here ('' disables)")
    ap.add_argument("--sections", default="",
                    help="comma-separated filter, e.g. 'progress,allreduce'")
    args = ap.parse_args(argv)

    from benchmarks import bench_progress, bench_user_allreduce, bench_overlap, \
        bench_kernels

    print("name,us_per_call,derived")
    sections = [
        ("progress (figs 7-12)", bench_progress.run),
        ("user allreduce (figs 13-14)", bench_user_allreduce.run),
        ("overlap", bench_overlap.run),
        ("kernels", bench_kernels.run),
    ]
    if args.sections:
        wanted = [w.strip() for w in args.sections.split(",") if w.strip()]
        names = [n for n, _ in sections]
        unknown = [w for w in wanted if not any(w in n for n in names)]
        if unknown:
            # an unmatched filter must error, not silently run nothing —
            # a typo'd --sections in CI would otherwise produce an empty
            # (but green-looking) BENCH_progress.json
            sys.exit(f"run.py: unknown section filter(s) {unknown}; "
                     f"available sections: {names}")
        sections = [(n, f) for n, f in sections
                    if any(w in n for w in wanted)]

    records: list[dict] = []
    failed: list[str] = []
    t_start = time.time()
    for name, fn in sections:
        print(f"# --- {name} ---")
        try:
            for r in fn():
                print(r, flush=True)
                records.append(_parse_row(name, r))
        except Exception:  # noqa: BLE001
            failed.append(name)
            print(f"# SECTION FAILED: {name}", flush=True)
            traceback.print_exc()

    if args.json:
        summary = {
            "schema": "repro-bench-v1",
            "git_rev": _git_rev(),
            "python": platform.python_version(),
            "platform": platform.platform(),
            "wall_s": round(time.time() - t_start, 3),
            "failed_sections": failed,
            "rows": records,
        }
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2)
        print(f"# wrote {args.json}: {len(records)} rows, "
              f"{len(failed)} failed sections")

    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
