"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Sections:
  figs 7-12  progress-engine microbenchmarks (paper §4.2-§4.5)
  fig 13     user-level allreduce vs native (paper §4.7; 8-device child)
  overlap    computation/communication overlap (paper §2.3 thesis)
  kernels    substrate formulation timings
Roofline tables (the TPU-target performance report) are produced by the
dry-run: ``python -m repro.launch.dryrun`` + EXPERIMENTS.md.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import bench_progress, bench_user_allreduce, bench_overlap, \
        bench_kernels

    print("name,us_per_call,derived")
    sections = [
        ("progress (figs 7-12)", bench_progress.run),
        ("user allreduce (fig 13)", bench_user_allreduce.run),
        ("overlap", bench_overlap.run),
        ("kernels", bench_kernels.run),
    ]
    failed = 0
    for name, fn in sections:
        print(f"# --- {name} ---")
        try:
            for r in fn():
                print(r, flush=True)
        except Exception:  # noqa: BLE001
            failed += 1
            print(f"# SECTION FAILED: {name}", flush=True)
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
