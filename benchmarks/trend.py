"""Perf-trend gate: diff the current ``BENCH_progress.json`` against the
previous run's artifact and flag regressions.

The CI ``bench`` job accumulates ``BENCH_progress.json`` (schema
``repro-bench-v1``) as an artifact per commit; this tool compares the
fig7 / fig13 / fig14 rows of the current run against the artifact
downloaded from the last successful main run, writes a markdown table to
``$GITHUB_STEP_SUMMARY`` (and stdout), and exits non-zero when any
tracked row slowed down by more than ``--threshold`` (default 20%) — the
job stays non-blocking (``continue-on-error``), so a regression
*annotates* the run instead of failing the PR, but it can never slip by
silently.

Usage:
    python -m benchmarks.trend --current BENCH_progress.json \
        --previous prev/BENCH_progress.json [--threshold 0.2]

Missing previous artifact (first run, expired retention, forked PR
without artifact access) is not an error: the report says so and exits 0.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# Row-name prefixes tracked by the gate: the progress-engine
# microbenchmarks (fig7), callback-vs-waitset delivery (fig13), the
# user-collective sweep (fig14), the serve-decode latency family
# (serve_decode — unsharded / native-sharded / user-collective rows)
# and the continuous-batching arrival-trace family (serve_cb —
# TTFT/p99 under a paged KV cache vs the fixed-slot baseline; the
# existing fig* names are untouched so artifact history stays
# comparable across runs).  fig14_persistent_gain, serve_gain and
# cb_gain rows hold a ratio, not a latency — their names deliberately
# fall outside the tracked prefixes.  recovery rows time the
# membership-change path (epoch invalidation -> drained, remeshed,
# re-admitted and idle; trainer remesh-and-retry step) so a fault-
# tolerance regression shows up in the same gate as a hot-path one.
# pipeline rows time one pipeline-parallel train step (sequential /
# GPipe-scan / event-driven 1F1B) plus the measured bubble — the
# measured-vs-analytic check itself lives in the bench child, the gate
# only tracks the step times drifting.  fsdp rows time the ZeRO-style
# sharded step (unsharded baseline / native in-program collectives /
# user-backend persistent handles) plus the prefetch-overlap fraction
# of the continuation-chained gathers; overlap is a fraction where
# HIGHER is better, so a drop renders as 'improved' — read the note.
# debug_overhead rows time a warmed persistent-allreduce step with
# the REPRO_DEBUG checkers dormant (off) and armed (on); gating both
# keeps the debug tax itself from silently growing past the <5%
# budget that makes REPRO_DEBUG=1 CI runs viable.
DEFAULT_PREFIXES = ("fig7", "fig13", "fig14_native", "fig14_user",
                    "serve_decode", "serve_cb", "recovery", "pipeline",
                    "fsdp", "debug_overhead")
DEFAULT_THRESHOLD = 0.20


def load_rows(path: str, prefixes) -> dict[str, float]:
    """name -> us_per_call for tracked rows with a measured value."""
    with open(path) as f:
        summary = json.load(f)
    rows = {}
    for row in summary.get("rows", []):
        name, us = row.get("name", ""), row.get("us_per_call")
        if us is None or not name.startswith(tuple(prefixes)):
            continue
        rows[name] = float(us)
    return rows


def compare(prev: dict[str, float], cur: dict[str, float],
            threshold: float) -> list[dict]:
    """One entry per union row, flagged regressed/improved/ok/new/gone."""
    entries = []
    for name in sorted(set(prev) | set(cur)):
        p, c = prev.get(name), cur.get(name)
        if p is None:
            entries.append({"name": name, "prev": None, "cur": c,
                            "ratio": None, "status": "new"})
        elif c is None:
            entries.append({"name": name, "prev": p, "cur": None,
                            "ratio": None, "status": "gone"})
        else:
            ratio = c / p if p > 0 else float("inf")
            if ratio > 1.0 + threshold:
                status = "regressed"
            elif ratio < 1.0 - threshold:
                status = "improved"
            else:
                status = "ok"
            entries.append({"name": name, "prev": p, "cur": c,
                            "ratio": ratio, "status": status})
    return entries


_ICON = {"regressed": "🔴 regressed", "improved": "🟢 improved",
         "ok": "·", "new": "new", "gone": "gone"}


def _fmt_us(v) -> str:
    return f"{v:,.1f}" if v is not None else "—"


def format_markdown(entries: list[dict], threshold: float,
                    prev_rev: str = "?", cur_rev: str = "?") -> str:
    regressed = [e for e in entries if e["status"] == "regressed"]
    improved = [e for e in entries if e["status"] == "improved"]
    lines = [
        "## Perf trend: BENCH_progress",
        "",
        f"Comparing `{cur_rev}` (current) against `{prev_rev}` (last "
        f"successful main run); threshold ±{threshold:.0%}.",
        f"**{len(regressed)} regressed**, {len(improved)} improved, "
        f"{len(entries)} rows tracked.",
        "",
        "| row | prev µs | cur µs | Δ | |",
        "|---|---:|---:|---:|---|",
    ]
    for e in entries:
        delta = (f"{(e['ratio'] - 1.0) * 100:+.1f}%"
                 if e["ratio"] is not None else "—")
        lines.append(f"| `{e['name']}` | {_fmt_us(e['prev'])} | "
                     f"{_fmt_us(e['cur'])} | {delta} | "
                     f"{_ICON[e['status']]} |")
    return "\n".join(lines) + "\n"


def _emit(report: str, summary_path: str | None) -> None:
    print(report)
    if summary_path:
        with open(summary_path, "a") as f:
            f.write(report + "\n")


def _git_rev(path: str) -> str:
    try:
        with open(path) as f:
            return json.load(f).get("git_rev", "?")
    except Exception:  # noqa: BLE001
        return "?"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", default="BENCH_progress.json")
    ap.add_argument("--previous", default="prev/BENCH_progress.json")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="relative slowdown that counts as a regression")
    ap.add_argument("--prefixes", default=",".join(DEFAULT_PREFIXES),
                    help="comma-separated row-name prefixes to track")
    ap.add_argument("--summary", default=os.environ.get(
        "GITHUB_STEP_SUMMARY", ""),
        help="markdown file to append the report to "
             "(default: $GITHUB_STEP_SUMMARY)")
    args = ap.parse_args(argv)
    prefixes = tuple(p.strip() for p in args.prefixes.split(",") if p.strip())

    if not os.path.exists(args.current):
        _emit(f"## Perf trend: BENCH_progress\n\nno current summary at "
              f"`{args.current}` — bench harness produced nothing to "
              f"compare.", args.summary or None)
        return 2
    if not os.path.exists(args.previous):
        cur = load_rows(args.current, prefixes)
        _emit(f"## Perf trend: BENCH_progress\n\nno previous artifact at "
              f"`{args.previous}` — nothing to compare against "
              f"({len(cur)} rows recorded for the next run).",
              args.summary or None)
        return 0

    prev = load_rows(args.previous, prefixes)
    cur = load_rows(args.current, prefixes)
    entries = compare(prev, cur, args.threshold)
    report = format_markdown(entries, args.threshold,
                             prev_rev=_git_rev(args.previous),
                             cur_rev=_git_rev(args.current))
    _emit(report, args.summary or None)
    regressed = [e for e in entries if e["status"] == "regressed"]
    if regressed:
        print(f"TREND: {len(regressed)} row(s) regressed >"
              f"{args.threshold:.0%}: "
              + ", ".join(e["name"] for e in regressed), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
