"""Tour of every paper listing (1.1–1.7) mapped onto repro.core.

    PYTHONPATH=src python examples/progress_engine_tour.py
"""
import threading
import time

from repro.core import (DEFERRED, DONE, INLINE, NOPROGRESS, CompletionWatcher,
                        ContinuationQueue, EventQueue, GeneralizedRequest,
                        ProgressEngine, ProgressExecutor, Request, TaskQueue,
                        stats)


def listing_1_1_collated_subsystems(eng):
    """MPICH's internal progress function as engine subsystems."""
    calls = []
    eng.register_subsystem("datatype", lambda: (calls.append("dt"), False)[1],
                           cheap=True, priority=0)
    eng.register_subsystem("collective", lambda: (calls.append("coll"), False)[1],
                           cheap=True, priority=1)
    eng.register_subsystem("shmem", lambda: (calls.append("shm"), False)[1],
                           cheap=True, priority=2)
    eng.register_subsystem("netmod", lambda: (calls.append("net"), True)[1],
                           cheap=False, priority=3)
    eng.progress()
    print(f"1.1 collated order: {calls} (netmod last, skipped when earlier "
          f"subsystems made progress)")


def listing_1_2_1_3_dummy_tasks(eng):
    lat = []
    counter = {"n": 10}
    for _ in range(10):
        deadline = time.perf_counter() + 0.01

        def poll(thing, deadline=deadline):
            now = time.perf_counter()
            if now >= deadline:
                lat.append((now - deadline) * 1e6)
                counter["n"] -= 1
                return DONE
            return NOPROGRESS

        eng.async_start(poll)
    while counter["n"] > 0:                 # the Listing 1.3 wait loop
        eng.progress()
    print(f"1.2/1.3 ten dummy tasks done; mean progress latency "
          f"{sum(lat) / len(lat):.1f} µs")


def listing_1_4_task_class(eng):
    q = TaskQueue(eng)
    t0 = time.perf_counter()
    reqs = [q.submit(lambda i=i: time.perf_counter() >= t0 + 0.002 * (i + 1))
            for i in range(5)]
    while not all(r.is_complete for r in reqs):
        eng.progress()
    print("1.4 task class: 5 in-order tasks via ONE poll hook (O(1)/progress)")


def listing_1_5_streams():
    eng = ProgressEngine()
    done = []

    def worker(tid):
        stream = eng.stream(f"t{tid}")
        counter = {"n": 5}
        deadline = time.perf_counter() + 0.005
        for _ in range(5):
            eng.async_start(
                lambda t: (DONE if time.perf_counter() >= deadline
                           and not counter.__setitem__("n", counter["n"] - 1)
                           else NOPROGRESS), None, stream)
        while counter["n"] > 0:
            eng.progress(stream)            # no cross-thread lock contention
        done.append(tid)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    print(f"1.5 streams: 4 threads × own stream, all drained: {sorted(done)}")


def listing_1_6_completion_events(eng):
    w = CompletionWatcher(eng)
    evq = EventQueue()
    reqs = [Request(tag=f"r{i}") for i in range(3)]
    for r in reqs:
        w.watch(r, lambda rr: evq.emit(f"{rr.tag} complete"))
    for r in reqs:
        r.complete()
    eng.progress()
    print(f"1.6 events: {evq.drain()} (handlers deferred out of poll path)")


def listing_1_7_generalized_request(eng):
    greq = GeneralizedRequest(query_fn=lambda st: "status",
                              free_fn=lambda st: None)
    deadline = time.perf_counter() + 0.01
    eng.async_start(lambda t: (greq.complete(), DONE)[1]
                    if time.perf_counter() >= deadline else NOPROGRESS)
    value = eng.wait(greq, timeout=5)       # MPI_Wait on the grequest
    greq.free()
    print(f"1.7 generalized request completed via async progress: {value!r}")


def progress_workers():
    """Progress workers (§4.4): instead of every thread hand-rolling its
    own ``while: engine.progress(stream)`` loop (Listing 1.5), hand the
    streams to a ProgressExecutor — N background threads own disjoint
    stream sets (work-stealing rebalances them) and the application just
    *waits* on requests: ``wait``/``wait_any``/``wait_some`` yield to the
    workers instead of polling."""
    eng = ProgressEngine()
    ex = ProgressExecutor(eng, num_workers=2)
    s1, s2 = ex.stream("even"), ex.stream("odd")
    reqs = [Request(tag=f"r{i}") for i in range(6)]
    for i, r in enumerate(reqs):
        deadline = time.perf_counter() + 0.002 * (i + 1)

        def poll(thing, r=r, deadline=deadline):
            if time.perf_counter() >= deadline:
                r.complete(r.tag)
                return DONE
            return NOPROGRESS

        eng.async_start(poll, None, s1 if i % 2 == 0 else s2)
    with ex:                                    # start; drain+join on exit
        first_idx, first = eng.wait_any(reqs, timeout=5)
        some = eng.wait_some(reqs, min_count=4, timeout=5)
        eng.wait_all(reqs, timeout=5)
        snap = stats.collect(eng, ex)
    assert snap.total_contention == 0           # disjoint streams: Fig 11
    print(f"workers: first={first.tag}, completion order {some}..., "
          f"contention={snap.total_contention} "
          f"(2 workers, 2 streams, zero shared-lock collisions)")


def continuations_post_attach_drain():
    """Continuations (§4.6 / the MPI Continuations papers): post work,
    attach a callback, drain — completion *pushes* into the application
    instead of being pulled by wait loops.

    Listing-style walkthrough:

        1. post        — async_start a task completing a Request
        2. attach      — queue.attach(request, callback[, on_error])
        3. drain       — DEFERRED policy: the owner thread executes ready
                         callbacks outside the progress path (bounded);
                         INLINE runs them on the progress thread instead
        4. chain       — then/when_all/node turn DAG dependencies into
                         completion-driven scheduling (no polling)
    """
    eng = ProgressEngine()

    # -- deferred: detection on progress, execution on the owner ----------
    q = ContinuationQueue(eng, policy=DEFERRED, name="tour")
    got = []
    req = Request(tag="post")
    deadline = time.perf_counter() + 0.002
    eng.async_start(lambda t: (req.complete("payload"), DONE)[1]
                    if time.perf_counter() >= deadline else NOPROGRESS)
    q.attach(req, lambda r: got.append(r.value()),
             on_error=lambda r: got.append(r.exception))
    while q.ready == 0:                 # progress detects the completion…
        eng.progress()
    n = q.drain(max_items=8)            # …the owner drains it (bounded)
    print(f"continuations: deferred drain ran {n} callback(s): {got}")

    # -- chaining: a diamond DAG with no polled dependencies --------------
    qi = ContinuationQueue(eng, policy=INLINE, name="tour-chain")
    a = qi.node(lambda: 2)
    b = qi.then(a, lambda v: v * 10)
    c = qi.then(a, lambda v: v + 1)
    d = qi.node(lambda bv, cv: bv + cv, deps=[b, c])
    for _ in range(6):
        eng.progress()
    print(f"continuations: diamond DAG via node/then -> {d.value()} "
          f"(fired {qi.executed} continuations, 0 polls by consumers)")


def nonblocking_collectives():
    """User-space collectives on the engine (paper §4.7): the schedules
    of ``collectives/schedules.py`` compiled into chunk-pipelined,
    continuation-chained round programs returning Request handles.

        1. issue    — coll.iallreduce(x, mesh, axis, algorithm=, chunks=)
                      returns a CollectiveRequest immediately (the rounds
                      have only been *scheduled* on the collective stream)
        2. overlap  — the application computes; any engine.progress /
                      executor worker drives round r, whose completion
                      continuation dispatches round r+1 per chunk
        3. wait     — req.wait() (or engine.wait(req, stream=req.stream))
                      drives the stream to completion; the result matches
                      the native psum bit for bit

    Runs on however many host devices this process has (1 is fine — the
    schedule degenerates but the machinery is identical)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.collectives import nonblocking as NB

    n = len(jax.devices())
    mesh = compat.make_mesh((n,), ("x",))
    eng = ProgressEngine()
    coll = NB.UserCollectives(eng)
    x = jnp.arange(n * 8, dtype=jnp.float32).reshape(n, 8)
    native = jax.jit(compat.shard_map(lambda v: jax.lax.psum(v, "x"),
                                      mesh=mesh, in_specs=P("x"),
                                      out_specs=P("x")))(x)
    req = coll.iallreduce(x, mesh, "x", algorithm="ring", chunks=2)
    issued_complete = req.is_complete       # False: rounds still queued
    out = req.wait(timeout=60)
    np.testing.assert_allclose(np.asarray(out), np.asarray(native),
                               atol=1e-5)
    print(f"nonblocking collectives: iallreduce({req.algorithm}, "
          f"chunks={req.num_chunks}) complete_at_issue={issued_complete}, "
          f"{req.rounds_done} rounds driven by the engine, matches psum")

    # -- persistent collectives (MPI *_init / MPI_Start semantics) -----
    # allreduce_init fixes the plan (validation, chunk layout, join) and
    # compiles every fused round program ONCE; start(payload) re-binds a
    # new payload to the same schedule, paying only split + dispatch.
    # Round batching (auto from payload size) fuses consecutive rounds
    # into one jitted dispatch — small payloads collapse to a single
    # program per start, with multi-chunk payloads stacked through it.
    # The handle allows one outstanding start (MPI semantics), supports
    # cancel(), and a failed or cancelled start is restartable.
    handle = coll.allreduce_init(x, mesh, "x", algorithm="ring", chunks=2)
    for mul in (2.0, 3.0):
        out = handle.start(x * mul).wait(timeout=60)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(native) * mul, atol=1e-4)
    print(f"persistent collectives: {handle.starts} starts re-bound one "
          f"schedule (round_batch={handle.round_batch}, "
          f"{handle.dispatches_per_start} dispatch(es)/start), "
          f"each matching psum")
    handle.close()
    coll.close()


def serve_collectives():
    """Serve-side persistent collectives + executor-driven starts.

    Decode is the ideal persistent-collective consumer: fixed shapes,
    one step per token.  With a model mesh axis the ServeEngine splits
    decode into a shared partial-logits program (decode_hidden + each
    rank's vocab-slice unembed) and gathers the full logits either
    in-program (native) or by re-binding ONE persistent user-space
    all-gather per step:

        1. init  — ServeEngine(..., mesh=mesh, collective_backend="user")
                   builds allgather_init((n, slots, V/n)) once; the plan
                   and fused round programs compile at construction
        2. step  — each fused decode step does handle.start(partial);
                   the gather rounds run on the serve-collective stream
                   while the host admits/prefills concurrent arrivals
        3. chain — the gather's completion (a continuation) feeds the
                   SAME detokenize stage as the native path, which
                   launches the next step

    With a ProgressExecutor the start itself is executor-driven: the
    caller enqueues a one-shot issue task and the worker owning the
    collective stream dispatches round 0 (start() is O(µs)).  Greedy
    token streams are identical across unsharded / native / user paths
    (both sharded paths consume the same partial-logits program)."""
    import jax
    import numpy as np

    from repro import compat
    from repro.configs import get_config
    from repro.models import registry
    from repro.serve.engine import GenRequest, ServeEngine

    cfg = get_config("qwen2-0.5b").with_overrides(
        num_layers=2, d_model=32, d_ff=64, vocab_size=64, num_heads=4,
        num_kv_heads=2, head_dim=16, remat_policy="none")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    n = len(jax.devices())
    mesh = compat.make_mesh((n,), ("model",))

    def serve(backend):
        eng = ProgressEngine()
        srv = ServeEngine(cfg, params, eng, batch_slots=2, max_seq=32,
                          mesh=mesh, collective_backend=backend)
        done = srv.submit(GenRequest("tour", np.array([3, 4], np.int32),
                                     max_new_tokens=4))
        srv.run_until_idle(timeout=240)
        toks = done.value()
        starts = srv._ag_handle.starts if srv._ag_handle is not None else 0
        lat = srv.latency_snapshot()
        srv.close(timeout=60)
        return toks, starts, lat

    nat, _, _ = serve("native")
    usr, starts, lat = serve("user")
    assert nat == usr, (nat, usr)
    print(f"serve collectives: {len(usr)} tokens over a {n}-way model "
          f"axis, user == native stream, {starts} persistent all-gather "
          f"start(s); {lat.format()}")


def continuous_batching():
    """Continuous batching on a paged KV cache.

    A fixed-slot cache reserves ``max_seq`` positions per lane for the
    whole residency of a request — short requests pay for space they
    never touch.  The serve engine therefore allocates from a pool of
    fixed-size KV blocks (the fixed-slot mode is retired) and runs as a
    continuous-batching scheduler:

        1. admit    — arrivals land in a length-bucketed backlog; a
                      request is admitted when a lane AND enough blocks
                      for its prompt are free (claimed atomically)
        2. prefill  — admitted prompts replay in fused chunks that
                      interleave with decode steps of already-resident
                      requests (the ``fed`` mask isolates recurrent
                      state in SSM/hybrid families)
        3. decode   — one fused step per token over the block tables;
                      a lane that outgrows its blocks extends lazily
        4. preempt  — under block pressure the YOUNGEST resident is
                      evicted (blocks freed, request re-queued with its
                      generated prefix); the oldest resident is never
                      preempted, so progress is guaranteed and greedy
                      streams are invariant to the pool shape

    At equal cache bytes the paged pool sustains strictly more resident
    requests because blocks are granted per-position, not per-max_seq."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import registry
    from repro.serve.engine import GenRequest, ServeEngine

    cfg = get_config("qwen2-0.5b").with_overrides(
        num_layers=2, d_model=32, d_ff=64, vocab_size=64, num_heads=4,
        num_kv_heads=2, head_dim=16, remat_policy="none")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, 63, size=rng.randint(2, 10)).astype(np.int32)
               for _ in range(12)]

    def serve(**kw):
        eng = ProgressEngine()
        srv = ServeEngine(cfg, params, eng, max_seq=32, **kw)
        reqs = [GenRequest(f"cb{i}", p, max_new_tokens=6)
                for i, p in enumerate(prompts)]
        for r in reqs:
            srv.submit(r)
        srv.run_until_idle(timeout=240)
        lat, sched = srv.latency_snapshot(), srv.scheduler_snapshot()
        srv.close(timeout=60)
        return [list(r.out_tokens) for r in reqs], lat, sched

    lane3_toks, _, _ = serve(batch_slots=3)   # roomy pool, 3-lane cap
    # same cache bytes as 3 lanes x 32 positions: 24 blocks of 4 — but
    # 8 lanes, and a pool tight enough to exercise preemption
    wide_toks, lat, sched = serve(batch_slots=8,
                                  kv_block_size=4, kv_blocks=25,
                                  prefill_chunk=4)
    assert wide_toks == lane3_toks      # scheduling is invisible in output
    print(f"continuous batching: 12 requests, wide pool == 3-lane cap "
          f"bit-exact; {sched.format()}; "
          f"queued ms p50 {lat.queued_ms_p50:.1f}")


def fault_tolerance():
    """Membership-aware fault tolerance, end to end.

    One ``MembershipEpoch`` ties the monitors to every persistent
    collective handle and to the engines consuming them:

        1. detect     — ``HeartbeatMonitor`` (dead peer) or
                        ``StepWatchdog`` (hung step) call
                        ``epoch.invalidate(survivors=...)`` from their
                        subsystem poll on the collated progress loop
        2. fail fast  — every registered handle's in-flight start fails
                        exactly once with a retryable ``MembershipError``
                        and the handle goes stale (further starts raise
                        until ``rebuild(mesh)``)
        3. drain      — listeners only *record* the change (heavy work in
                        a subsystem poll would deadlock the poller); the
                        serve engine then checkpoints each decoding
                        lane's KV prefix to host memory
                        (``PagedKVCache.checkpoint_lane``) and re-queues
                        every resident with its replay tokens
        4. remesh     — ``elastic.plan_mesh`` picks a survivors' mesh,
                        plans/slots/params placement and the fused decode
                        programs are rebuilt, and re-admission restores
                        checkpointed lanes instead of replaying their
                        whole prefix
        5. resume     — greedy decode is per-lane deterministic, so the
                        recovered streams are bit-identical to an
                        undisturbed run; the trainer retries the failed
                        step's batch on the survivors, matching a
                        from-checkpoint restart bit-for-bit

    Here: serve 8 requests, kill a simulated device mid-decode, and
    check nothing is lost."""
    import jax
    import numpy as np

    from repro.collectives.nonblocking import MembershipEpoch
    from repro.configs import get_config
    from repro.models import registry
    from repro.serve.engine import GenRequest, ServeEngine

    cfg = get_config("qwen2-0.5b").with_overrides(
        num_layers=2, d_model=32, d_ff=64, vocab_size=64, num_heads=4,
        num_kv_heads=2, head_dim=16, remat_policy="none")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    prompts = [rng.randint(1, 63, size=rng.randint(2, 10)).astype(np.int32)
               for _ in range(8)]

    def serve(epoch=None, kill=False):
        eng = ProgressEngine()
        srv = ServeEngine(cfg, params, eng, batch_slots=3, max_seq=32,
                          cache_mode="paged", kv_block_size=4, epoch=epoch)
        reqs = [GenRequest(f"ft{i}", p, max_new_tokens=6)
                for i, p in enumerate(prompts)]
        for r in reqs:
            srv.submit(r)
        if kill:
            t0 = time.monotonic()
            while sum(len(r.out_tokens) for r in reqs) < 4 \
                    and time.monotonic() - t0 < 120:
                eng.progress()
            epoch.invalidate(survivors=1, reason="tour: simulated loss")
        srv.run_until_idle(timeout=240)
        lat, rm = srv.latency_snapshot(), srv.remeshes
        srv.close(timeout=60)
        return [list(r.out_tokens) for r in reqs], lat, rm

    ref, _, _ = serve()
    epoch = MembershipEpoch()
    got, lat, remeshes = serve(epoch=epoch, kill=True)
    assert got == ref and lat.failed == 0 and remeshes == 1
    print(f"fault tolerance: killed a device mid-decode; {remeshes} "
          f"remesh, {lat.completed} requests completed, streams "
          f"bit-identical to the undisturbed run")


def fsdp_sharded_training():
    """ZeRO-style FSDP training on user-space collectives — the 2-D-mesh
    step behind one :class:`CollectiveSpec`.

        1. layout   — ``FsdpLayout`` flattens the param tree into flat
                      per-dtype buckets padded to the data-axis size;
                      rank r owns row r of each ``[n, W/n]`` shard
                      stack, and the AdamW moments shard the same way
        2. step     — all-gather the full flat buckets for fwd/bwd,
                      reduce-scatter the grad buckets so each rank
                      receives ONLY the block it applies (half the
                      allreduce wire bytes), then the sharded optimizer
                      step; other mesh axes (``model``) just replicate
        3. prefetch — the NEXT step's all-gathers are chained as
                      continuations off compute futures over the
                      updated shards (``FsdpGather`` with ``after=``):
                      gather rounds ride the collective stream while
                      XLA still runs, and the overlap fraction is
                      *measured* from blocked-wait vs window time
        4. equality — the user backend runs THE SAME jitted grad/apply
                      programs as the native all_gather/psum_scatter
                      path; only the byte movement differs, so the loss
                      trajectory matches bit for bit

    Runs on however many host devices this process has (1 device -> a
    degenerate data axis: identity collectives, same machinery)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.collectives.nonblocking import CollectiveSpec
    from repro.collectives.overlap import FsdpLayout, FsdpReducer
    from repro.configs import get_config
    from repro.data.pipeline import SyntheticLM
    from repro.launch.train import build_fsdp_programs
    from repro.models import registry
    from repro.train import optimizer as opt_mod

    n = len(jax.devices())
    mesh = Mesh(np.array(jax.devices()).reshape(n, 1), ("data", "model"))
    cfg = get_config("smollm-360m").with_overrides(
        num_layers=2, d_model=64, d_ff=128, vocab_size=256, num_heads=4,
        num_kv_heads=2, head_dim=16, remat_policy="none")
    STEPS = 4
    ocfg = opt_mod.AdamWConfig(lr=3e-3, warmup_steps=1, total_steps=STEPS)
    src = SyntheticLM(cfg.vocab_size, 16, max(n, 2), seed=9)
    it = iter(src)
    batches = [{k: jnp.asarray(v) for k, v in next(it).items()}
               for _ in range(STEPS)]

    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    layout = FsdpLayout(params, n, 1 << 22)
    sharding = NamedSharding(mesh, P("data"))

    def fresh_state():
        shards = layout.shard_params(params, mesh, "data")
        return shards, opt_mod.AdamWState(
            jnp.zeros((), jnp.int32),
            [jax.device_put(jnp.zeros_like(s), sharding) for s in shards],
            [jax.device_put(jnp.zeros_like(s), sharding) for s in shards])

    grad_fn, apply_fn, ag_fn, rs_fn = build_fsdp_programs(
        cfg, ocfg, mesh, layout, axis="data")

    # native reference: same programs, in-program byte movement
    sh, st = fresh_state()
    native_losses = []
    for b in batches:
        smets, flat_g = grad_fn(ag_fn(sh), b)
        sh, st, mets = apply_fn(sh, st, rs_fn(flat_g), smets)
        native_losses.append(float(np.float32(mets["loss"])))

    # user backend: persistent engine handles + chained prefetch
    eng = ProgressEngine()
    spec = CollectiveSpec(backend="user", chunks=2)
    red = FsdpReducer(mesh, "data", engine=eng, spec=spec,
                      bucket_bytes=1 << 22)
    sh, st = fresh_state()
    user_losses = []
    gather = red.igather(sh)                 # step 0: self-chained train
    for b in batches:
        flats = gather.wait(timeout=300)     # params for THIS step
        red._note_gather(gather)
        smets, flat_g = grad_fn(flats, b)
        gshards = red.ireduce_scatter(flat_g).wait(timeout=300)
        sh, st, mets = apply_fn(sh, st, gshards, smets)
        # chain the NEXT step's gathers off the updated shards' compute
        # futures: each bucket's all-gather starts the moment its shard
        # materializes, behind whatever XLA is still running
        gather = red.igather(sh, after=[red.future(s) for s in sh])
        user_losses.append(float(np.float32(mets["loss"])))
    gather.wait(timeout=300)                 # drain the last prefetch
    overlap = red.prefetch_overlap
    red.close()

    assert user_losses == native_losses, (user_losses, native_losses)
    print(f"fsdp: {layout.num_buckets} bucket(s) sharded over data={n}, "
          f"{STEPS} steps bit-identical to the native "
          f"all_gather/psum_scatter path (loss {user_losses[-1]:.6f}); "
          f"prefetch overlap {overlap:.3f} across {red.gathers} chained "
          f"gathers")


def pipeline_1f1b():
    """Event-driven 1F1B pipeline parallelism (paper §4.6, the
    task-based-runtime integration, applied to the pipeline axis).

        1. transport — ``collectives/p2p.py``: user-space nonblocking
                       isend/irecv returning CollectiveRequest handles
                       (posted-receive / unexpected-message matching
                       queues, non-overtaking per tag), plus
                       ``send_init``/``recv_init`` persistent channels —
                       a stage boundary is one channel per direction,
                       started once per microbatch hop
        2. schedule  — ``PipelineSchedule`` lays the 1F1B grid out as a
                       continuation DAG: a forward cell is
                       ``when_all(recv activation, params resident)``, a
                       backward cell ``when_all(recv grad, stashed
                       activation)``; warmup / steady 1F1B / cooldown are
                       *emergent* from readiness — nothing polls, no
                       phase barriers, each stage's cells retire on its
                       own engine stream
        3. measure   — the executed grid realizes exactly ``2(M+S-1)``
                       ticks, bubble ``(S-1)/(M+S-1)`` (same warmup cost
                       as GPipe; the win is peak activation stash
                       ``min(S, M)`` instead of ``M``), and the result is
                       bit-identical to sequential per-microbatch
                       accumulation

    Runs on however many host devices this process has (1 device -> a
    1-stage pipeline: no hops, but the same DAG machinery)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro import compat
    from repro.collectives.p2p import P2P
    from repro.distributed import pipeline as pl

    S = min(len(jax.devices()), 4)
    M = 4
    mesh = compat.make_mesh((S,), ("stage",))
    eng = ProgressEngine()

    # the transport on its own: a single forward ring hop
    p2p = P2P(eng)
    x = jnp.arange(S * 4, dtype=jnp.float32).reshape(S, 4)
    p2p.isend(x, mesh, "stage")
    got = p2p.irecv(x, mesh, "stage").wait(timeout=60)
    assert np.array_equal(np.asarray(got),
                          np.roll(np.asarray(x), 1, axis=0))
    p2p.close()

    d, h, mb = 8, 16, 2

    def stage_fn(p, xx):
        return xx + jnp.tanh(xx @ p["w1"]) @ p["w2"]

    def loss_fn(y, t):
        return jnp.mean((y - t) ** 2)

    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    params = {"w1": jax.random.normal(ks[0], (S, d, h)) * 0.1,
              "w2": jax.random.normal(ks[1], (S, h, d)) * 0.1}
    xs = jax.random.normal(ks[2], (M, mb, d))
    ts = jax.random.normal(ks[3], (M, mb, d))

    sched = pl.PipelineSchedule(stage_fn, mesh, "stage", S,
                                loss_fn=loss_fn, engine=eng,
                                name="tour-pipe")
    # forward path is bit-identical to the in-program GPipe scan
    ys = sched.apply(params, xs, timeout=300)
    gp = pl.gpipe(stage_fn, mesh, "stage", S)
    gys = gp(jax.device_put(params, NamedSharding(mesh, P("stage"))), xs)
    assert np.array_equal(np.asarray(ys), np.asarray(gys))

    loss, grads = sched.step(params, xs, ts, timeout=300)
    tm = sched.last_step_timing
    st = sched.stats()
    cells = sum(tm["cells"])
    measured = 1.0 - cells / (S * tm["grid_ticks"])
    analytic = pl.bubble_fraction(S, M, "1f1b")
    assert abs(measured - analytic) < 1e-12
    print(f"1F1B pipeline: S={S} x M={M} -> {tm['grid_ticks']} ticks "
          f"(=2(M+S-1)), bubble measured={measured:.3f} == "
          f"analytic={analytic:.3f}, peak stash "
          f"{pl.peak_activation_microbatches(S, M, '1f1b')} microbatches; "
          f"loss={float(loss):.4f}, forward bit-identical to GPipe; "
          f"hops={st['hop_starts']}, blocking_waits={st['blocking_waits']} "
          f"(only the callers' — the DAG itself never polls)")
    sched.close()


def progress_safety_rules():
    """Progress-safety rules (PR 10): the static analyzer
    (``repro.analysis.progress_lint``) and the ``REPRO_DEBUG=1`` runtime
    checkers (``repro.core.debug``) enforce four rule families.  One
    deliberate violation per rule, each caught by the tooling:

        PL001  blocking call reachable from a continuation body
        PL002  persistent-handle lifecycle (the MPI *_init/start machine)
        PL003  lock-order inversion across function bodies
        PL004  donated buffer reused after the donating jit call
    """
    import textwrap

    from repro.analysis import progress_lint
    from repro.core.debug import (HandleTracker, LifecycleError,
                                  LockOrderError, LockOrderGraph,
                                  OrderedLock)

    def demo(rule, src):
        fs = progress_lint.lint_source(textwrap.dedent(src))
        assert [f.rule for f in fs] == [rule], fs
        print(f"  {rule} caught: {fs[0].message}")

    # PL001 — a continuation that blocks stalls the progress thread
    demo("PL001", """
        def setup(q, req):
            q.attach(req, lambda r: r.wait())
    """)
    # PL002 — double-start on a persistent handle (MPI forbids it)
    demo("PL002", """
        def f(coll, mesh, x):
            h = coll.allreduce_init(x, mesh, "i")
            h.start(x)
            h.start(x)
    """)
    # PL003 — two call paths nest the same locks in opposite orders
    demo("PL003", """
        class E:
            def a(self, q):
                with self._lock:
                    with q._qlock: pass
            def b(self, q):
                with q._qlock:
                    with self._lock: pass
    """)
    # PL004 — a jit-donated buffer is dead after the call
    demo("PL004", """
        import jax
        def step(carry):
            f = jax.jit(lambda c: c + 1, donate_argnums=(0,))
            out = f(carry)
            return carry + out
    """)

    # runtime halves: the same rules where only execution shows the order.
    # Lock order — the BA attempt raises on sight, no deadlock needed:
    g = LockOrderGraph()
    a, b = OrderedLock("A", g), OrderedLock("B", g)
    with a:
        with b:
            pass
    try:
        with b:
            a.acquire()
    except LockOrderError as e:
        print(f"  runtime lock-order: {str(e).split('.')[0]}")
    # Handle lifecycle — the tracker enforces the same declared machine
    # the lint loads (single source of truth in repro.core.debug):
    t = HandleTracker()
    h = type("H", (), {})()
    t.track(h, "DemoHandle")
    t.event(h, "close")
    try:
        t.event(h, "start")
    except LifecycleError as e:
        print(f"  runtime lifecycle: {e}")


if __name__ == "__main__":
    eng = ProgressEngine()
    listing_1_1_collated_subsystems(eng)
    listing_1_2_1_3_dummy_tasks(eng)
    listing_1_4_task_class(eng)
    listing_1_5_streams()
    listing_1_6_completion_events(eng)
    listing_1_7_generalized_request(eng)
    progress_workers()
    continuations_post_attach_drain()
    nonblocking_collectives()
    serve_collectives()
    continuous_batching()
    fault_tolerance()
    pipeline_1f1b()
    fsdp_sharded_training()
    progress_safety_rules()
    print("tour OK")
