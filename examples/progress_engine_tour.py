"""Tour of every paper listing (1.1–1.7) mapped onto repro.core.

    PYTHONPATH=src python examples/progress_engine_tour.py
"""
import threading
import time

from repro.core import (DONE, NOPROGRESS, CompletionWatcher, EventQueue,
                        GeneralizedRequest, ProgressEngine, Request,
                        TaskQueue)


def listing_1_1_collated_subsystems(eng):
    """MPICH's internal progress function as engine subsystems."""
    calls = []
    eng.register_subsystem("datatype", lambda: (calls.append("dt"), False)[1],
                           cheap=True, priority=0)
    eng.register_subsystem("collective", lambda: (calls.append("coll"), False)[1],
                           cheap=True, priority=1)
    eng.register_subsystem("shmem", lambda: (calls.append("shm"), False)[1],
                           cheap=True, priority=2)
    eng.register_subsystem("netmod", lambda: (calls.append("net"), True)[1],
                           cheap=False, priority=3)
    eng.progress()
    print(f"1.1 collated order: {calls} (netmod last, skipped when earlier "
          f"subsystems made progress)")


def listing_1_2_1_3_dummy_tasks(eng):
    lat = []
    counter = {"n": 10}
    for _ in range(10):
        deadline = time.perf_counter() + 0.01

        def poll(thing, deadline=deadline):
            now = time.perf_counter()
            if now >= deadline:
                lat.append((now - deadline) * 1e6)
                counter["n"] -= 1
                return DONE
            return NOPROGRESS

        eng.async_start(poll)
    while counter["n"] > 0:                 # the Listing 1.3 wait loop
        eng.progress()
    print(f"1.2/1.3 ten dummy tasks done; mean progress latency "
          f"{sum(lat) / len(lat):.1f} µs")


def listing_1_4_task_class(eng):
    q = TaskQueue(eng)
    t0 = time.perf_counter()
    reqs = [q.submit(lambda i=i: time.perf_counter() >= t0 + 0.002 * (i + 1))
            for i in range(5)]
    while not all(r.is_complete for r in reqs):
        eng.progress()
    print("1.4 task class: 5 in-order tasks via ONE poll hook (O(1)/progress)")


def listing_1_5_streams():
    eng = ProgressEngine()
    done = []

    def worker(tid):
        stream = eng.stream(f"t{tid}")
        counter = {"n": 5}
        deadline = time.perf_counter() + 0.005
        for _ in range(5):
            eng.async_start(
                lambda t: (DONE if time.perf_counter() >= deadline
                           and not counter.__setitem__("n", counter["n"] - 1)
                           else NOPROGRESS), None, stream)
        while counter["n"] > 0:
            eng.progress(stream)            # no cross-thread lock contention
        done.append(tid)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    print(f"1.5 streams: 4 threads × own stream, all drained: {sorted(done)}")


def listing_1_6_completion_events(eng):
    w = CompletionWatcher(eng)
    evq = EventQueue()
    reqs = [Request(tag=f"r{i}") for i in range(3)]
    for r in reqs:
        w.watch(r, lambda rr: evq.emit(f"{rr.tag} complete"))
    for r in reqs:
        r.complete()
    eng.progress()
    print(f"1.6 events: {evq.drain()} (handlers deferred out of poll path)")


def listing_1_7_generalized_request(eng):
    greq = GeneralizedRequest(query_fn=lambda st: "status",
                              free_fn=lambda st: None)
    deadline = time.perf_counter() + 0.01
    eng.async_start(lambda t: (greq.complete(), DONE)[1]
                    if time.perf_counter() >= deadline else NOPROGRESS)
    value = eng.wait(greq, timeout=5)       # MPI_Wait on the grequest
    greq.free()
    print(f"1.7 generalized request completed via async progress: {value!r}")


if __name__ == "__main__":
    eng = ProgressEngine()
    listing_1_1_collated_subsystems(eng)
    listing_1_2_1_3_dummy_tasks(eng)
    listing_1_4_task_class(eng)
    listing_1_5_streams()
    listing_1_6_completion_events(eng)
    listing_1_7_generalized_request(eng)
    print("tour OK")
