"""Quickstart: the progress engine in five minutes.

Walks the paper's core API (streams, async tasks, requests, collated
subsystems) and trains a tiny LM for a few steps with every async
subsystem (data prefetch, checkpointing) driven by ONE engine.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import DONE, NOPROGRESS, ProgressEngine, Request, jax_future
from repro.data.pipeline import PrefetchPipeline, SyntheticLM
from repro.models import registry
from repro.train import optimizer as opt_mod
from repro.train.train_loop import Trainer, TrainLoopConfig


def demo_engine():
    print("== 1. MPIX-style async tasks ==")
    eng = ProgressEngine()
    deadline = time.monotonic() + 0.05

    def poll(thing):                     # paper Listing 1.2
        if time.monotonic() >= deadline:
            print(f"   task done (state={thing.state})")
            return DONE
        return NOPROGRESS

    eng.async_start(poll, {"job": 42})
    req = Request()
    eng.async_start(lambda t: (req.complete("hello"), DONE)[1])
    while not req.is_complete:           # MPIX_Request_is_complete
        eng.progress()                   # MPIX_Stream_progress
    eng.drain(timeout=5)
    print(f"   request value: {req.value()}")

    print("== 2. streams isolate contexts ==")
    s1, s2 = eng.stream("io"), eng.stream("net")
    eng.async_start(lambda t: DONE, None, s1)
    eng.progress(s2)                     # does NOT advance s1
    assert s1.pending == 1
    eng.progress(s1)
    assert s1.pending == 0
    print("   progress(s2) left s1 untouched — no cross-stream contention")


def demo_train():
    print("== 3. tiny LM training with one collated engine ==")
    cfg = get_config("smollm-360m").with_overrides(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, remat_policy="none")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    ocfg = opt_mod.AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=50)
    opt_state = opt_mod.init(params)

    @jax.jit
    def step_fn(params, opt_state, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        (loss, aux), grads = jax.value_and_grad(
            lambda p: registry.loss_fn(p, cfg, batch), has_aux=True)(params)
        params, opt_state, om = opt_mod.apply(ocfg, opt_state, params, grads)
        return params, opt_state, dict(loss=loss, **om)

    eng = ProgressEngine()
    pipe = PrefetchPipeline(SyntheticLM(512, 32, 8, seed=7), eng, depth=2)
    with tempfile.TemporaryDirectory() as ckdir:
        trainer = Trainer(step_fn, params, opt_state, pipe,
                          TrainLoopConfig(total_steps=10, checkpoint_every=5,
                                          checkpoint_dir=ckdir, log_every=2),
                          engine=eng,
                          hooks=[lambda s, m: print(
                              f"   step {s}: loss={m['loss']:.3f} "
                              f"({m['step_time_s'] * 1e3:.0f} ms)")])
        log = trainer.run()
    assert log[-1]["loss"] < log[0]["loss"]
    print(f"   loss {log[0]['loss']:.3f} -> {log[-1]['loss']:.3f}; "
          f"data stalls: {pipe.stalls}")
    pipe.close()


if __name__ == "__main__":
    demo_engine()
    demo_train()
    print("quickstart OK")
