"""Event-driven continuous-batching server demo (paper §2.7 applied).

Requests arrive while the engine runs; admission, prefill, fused decode
and completion events are all async tasks on ONE progress engine — no
per-request threads, no blocking waits.

    PYTHONPATH=src python examples/serve_lm.py --requests 6 --slots 3
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import ProgressEngine
from repro.models import registry
from repro.serve.engine import GenRequest, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config("qwen2-0.5b").with_overrides(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, remat_policy="none")
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    eng = ProgressEngine()
    srv = ServeEngine(cfg, params, eng, batch_slots=args.slots, max_seq=64)

    rng = np.random.RandomState(0)
    reqs = []
    for i in range(args.requests):
        prompt = rng.randint(1, 250, size=rng.randint(2, 6)).astype(np.int32)
        r = GenRequest(f"req{i}", prompt, max_new_tokens=args.max_new)
        srv.submit(r)
        reqs.append(r)
        # interleave arrivals with progress (requests land mid-flight)
        for _ in range(20):
            eng.progress()

    srv.run_until_idle(timeout=300)
    print(f"{'request':8s} {'prompt':>7s} {'out tokens':32s} "
          f"{'ttft(ms)':>9s} {'total(ms)':>9s}")
    for r in reqs:
        ttft = (r.first_token_at - r.submitted_at) * 1e3
        total = (r.finished_at - r.submitted_at) * 1e3
        print(f"{r.request_id:8s} {len(r.prompt):7d} "
              f"{str(r.out_tokens):32s} {ttft:9.1f} {total:9.1f}")
    print(f"decode steps (fused over slots): {srv.steps} "
          f"for {sum(len(r.out_tokens) for r in reqs)} generated tokens "
          f"-> continuous batching factor "
          f"{sum(len(r.out_tokens) for r in reqs) / max(srv.steps, 1):.2f}x")


if __name__ == "__main__":
    main()
