"""End-to-end training driver with checkpoint/restart.

Any assigned architecture is selectable; ``--scale tiny|small|full``
shrinks the config for CPU demonstration (full configs target TPU pods
via ``repro.launch.train``).  Demonstrates: engine-driven prefetch +
async checkpointing, fault-tolerant restart (rerun the same command — it
resumes from the last committed step), straggler stats.

    PYTHONPATH=src python examples/train_lm.py --arch smollm-360m \
        --scale tiny --steps 30
"""
import argparse
import os

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_configs
from repro.core import ProgressEngine
from repro.data.pipeline import PrefetchPipeline, SyntheticLM
from repro.models import registry
from repro.train import optimizer as opt_mod
from repro.train.train_loop import Trainer, TrainLoopConfig

SCALES = {
    # ~1M params: fast CPU demo
    "tiny": dict(num_layers=2, d_model=64, d_ff=128, vocab_size=512,
                 num_heads=4, num_kv_heads=2, head_dim=16, remat_policy="none"),
    # ~25M params: slower but meaningful loss curves on CPU
    "small": dict(num_layers=4, d_model=256, d_ff=1024, vocab_size=4096,
                  num_heads=8, num_kv_heads=4, head_dim=32, remat_policy="none"),
    "full": {},
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=list_configs())
    ap.add_argument("--scale", default="tiny", choices=list(SCALES))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    overrides = dict(SCALES[args.scale])
    cfg = get_config(args.arch)
    if overrides:
        if cfg.moe:
            overrides["moe"] = cfg.moe.__class__(
                num_experts=4, top_k=2, expert_d_ff=overrides["d_ff"] // 2,
                group_size=64)
            overrides["d_ff"] = overrides["d_ff"] // 2
        if cfg.ssm:
            overrides["ssm"] = cfg.ssm.__class__(
                d_state=16, expand=2, head_dim=16, chunk_size=16)
        if cfg.shared_attn_every:
            overrides.update(num_layers=5, shared_attn_every=2,
                             shared_attn_lora_rank=8)
        if cfg.is_encoder_decoder:
            overrides.update(num_encoder_layers=2, encoder_frames=16,
                             max_position_embeddings=256)
        cfg = cfg.with_overrides(**overrides)

    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={args.arch} scale={args.scale} params={n / 1e6:.1f}M")

    ocfg = opt_mod.AdamWConfig(lr=args.lr, warmup_steps=5,
                               total_steps=max(args.steps, 10))
    opt_state = opt_mod.init(params)

    def make_batch(b):
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.is_encoder_decoder:
            batch["encoder_embeds"] = jnp.ones(
                (batch["tokens"].shape[0], cfg.encoder_frames, cfg.d_model),
                jnp.bfloat16)
        return batch

    @jax.jit
    def step_fn(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: registry.loss_fn(p, cfg, batch), has_aux=True)(params)
        params, opt_state, om = opt_mod.apply(ocfg, opt_state, params, grads)
        return params, opt_state, dict(loss=loss, **om)

    eng = ProgressEngine()
    src = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=11)
    pipe = PrefetchPipeline(map(make_batch, iter(src)), eng, depth=3)

    trainer = Trainer(
        step_fn, params, opt_state, pipe,
        TrainLoopConfig(total_steps=args.steps, checkpoint_every=10,
                        checkpoint_dir=os.path.join(args.ckpt_dir, args.arch),
                        log_every=5, resume=True),
        engine=eng,
        hooks=[lambda s, m: print(
            f"step {s:4d} loss={m['loss']:.4f} gnorm={m['grad_norm']:.2f} "
            f"lr={m['lr']:.2e} {m['step_time_s'] * 1e3:.0f}ms")])
    if trainer.ckpt.latest_step() is not None:
        print(f"resuming from committed step {trainer.ckpt.latest_step()}")
    log = trainer.run()
    pipe.close()
    print(f"done: loss {log[0]['loss']:.4f} -> {log[-1]['loss']:.4f}; "
          f"stragglers flagged: {dict(trainer.straggler.flagged)}")


if __name__ == "__main__":
    main()
