"""Paper §4.7: user-level collectives vs the native implementation.

Runs in-process with 8 forced host devices (run it directly, NOT from a
JAX-initialized parent).  Shows the recursive-doubling allreduce of the
paper's Listing 1.8 as a ppermute schedule, validates all schedules
against ``psum``, and times a single-int allreduce (the paper's Fig 13).

    PYTHONPATH=src python examples/user_collectives.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402

from repro.collectives import schedules as S  # noqa: E402
from repro.collectives.overlap import collective_matmul_ag  # noqa: E402


def main():
    mesh = compat.make_mesh((8,), ("x",))
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 4, 64))
    native = jax.jit(compat.shard_map(lambda v: jax.lax.psum(v, "x"),
                                   mesh=mesh, in_specs=P("x"),
                                   out_specs=P("x")))

    print("== correctness vs native psum ==")
    expected = np.asarray(native(x))
    for name in S.ALGORITHMS:
        out = jax.jit(lambda v, a=name: S.allreduce_under_shard_map(
            v, mesh, "x", a))(x)
        err = float(jnp.max(jnp.abs(out - expected)))
        print(f"   {name:22s} max err {err:.2e}")

    print("== Fig 13: single-int allreduce latency ==")
    one = jnp.arange(8, dtype=jnp.float32).reshape(8, 1)

    def bench(fn):
        jitted = jax.jit(compat.shard_map(fn, mesh=mesh, in_specs=P("x"),
                                       out_specs=P("x")))
        jitted(one).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(200):
            out = jitted(one)
        out.block_until_ready()
        return (time.perf_counter() - t0) / 200 * 1e6

    print(f"   native psum            {bench(lambda v: jax.lax.psum(v, 'x')):8.1f} µs")
    for name, fn in S.ALGORITHMS.items():
        print(f"   {name:22s} {bench(lambda v, f=fn: f(v, 'x')):8.1f} µs")

    print("== nonblocking engine-driven iallreduce (chunk-pipelined) ==")
    from repro.core import ProgressEngine
    from repro.collectives import nonblocking as NB

    eng = ProgressEngine()
    coll = NB.UserCollectives(eng)
    big = jax.random.normal(jax.random.PRNGKey(3), (8, 4096))
    want = np.asarray(big).sum(0)
    for alg, K in (("ring", 1), ("ring", 4), ("recursive_doubling", 2)):
        req = coll.iallreduce(big, mesh, "x", algorithm=alg, chunks=K)
        state = "pending" if not req.is_complete else "complete"
        t0 = time.perf_counter()
        out = req.wait(timeout=120)
        ms = (time.perf_counter() - t0) * 1e3
        err = float(jnp.max(jnp.abs(out[0] - want)))
        print(f"   {alg:22s} chunks={K} at issue: {state}; "
              f"{req.rounds_done} rounds in {ms:6.1f} ms, max err {err:.2e}")
    print("== persistent schedules + round batching (MPI *_init/Start) ==")
    # plan + fused round programs built once; start() re-binds payloads.
    # Auto round batching collapses this small payload to one dispatch
    # per start (multi-chunk payloads stack through a single program).
    h = coll.allreduce_init(big, mesh, "x", algorithm="ring", chunks=4)
    t0 = time.perf_counter()
    for seed in (5, 6, 7):
        p = jax.random.normal(jax.random.PRNGKey(seed), big.shape)
        out = h.start(p).wait(timeout=120)
        err = float(jnp.max(jnp.abs(out[0] - np.asarray(p).sum(0))))
        assert err < 1e-3, err
    ms = (time.perf_counter() - t0) / 3 * 1e3
    print(f"   ring chunks=4 persistent: round_batch={h.round_batch}, "
          f"{h.dispatches_per_start} dispatch(es)/start, "
          f"{h.starts} rebinds at {ms:6.1f} ms each")
    h.close()
    coll.close()

    print("== collective matmul (overlapped all-gather GEMM) ==")
    xm = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    w = jax.random.normal(jax.random.PRNGKey(2), (32, 128))
    out = jax.jit(compat.shard_map(
        lambda xs, ws: collective_matmul_ag(xs, ws, "x"),
        mesh=mesh, in_specs=(P("x"), P(None, "x")),
        out_specs=P(None, "x")))(xm, w)
    err = float(jnp.max(jnp.abs(out - xm @ w)))
    print(f"   AG-matmul rolled loop max err {err:.2e} "
          f"(each step's GEMM overlaps the next chunk's ppermute)")


if __name__ == "__main__":
    main()
