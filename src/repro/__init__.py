"""repro — "MPI Progress For All" (Zhou et al., 2024) as a JAX/TPU framework.

The package provides:

* ``repro.core``        — the paper's explicit, collated, interoperable
  progress engine (MPIX_Stream / MPIX_Async / MPIX_Request_is_complete
  analogues) driving every host-side async subsystem.
* ``repro.collectives`` — user-level collective schedules (paper §4.7)
  expressed as shard_map + ppermute state machines, plus overlapped and
  compressed gradient reduction.
* ``repro.models``      — the ten assigned architectures.
* ``repro.kernels``     — Pallas TPU kernels for the compute hot spots.
* ``repro.launch``      — production mesh, multi-pod dry-run, train/serve
  drivers.
"""

__version__ = "1.0.0"
