"""Post-optimization HLO text analyzer.

``compiled.cost_analysis()`` visits a ``while`` body ONCE (verified on
this JAX/XLA build), so scanned-layer models under-report FLOPs/bytes by
a factor of the layer count.  This parser walks the compiled module text,
computes per-computation costs, and multiplies ``while`` bodies by their
``backend_config known_trip_count`` — giving corrected:

* ``flops``            — dot (2·M·N·K) + elementwise + reduce
* ``bytes``            — HBM-traffic proxy: operand+result bytes of
  top-level (unfused) ops; fusion bodies are *not* double counted
* ``collective_bytes`` — per collective type: operand bytes of every
  all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute (async ``-start`` counted once), with trip-count
  multipliers applied

All values are PER DEVICE (post-SPMD HLO is the per-device program).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALLED_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "tanh", "negate", "abs", "log", "rsqrt", "sqrt", "select",
    "compare", "and", "or", "xor", "not", "floor", "ceil", "sign", "cosine",
    "sine", "atan2", "remainder", "exponential-minus-one", "log-plus-one",
    "logistic", "clamp", "convert",
}


def _parse_shapes(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    """Parse 'f32[4,16]{1,0}' or '(s32[], f32[4,16])' into [(dtype, dims)]."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dt, shape))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _nelems(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class OpInfo:
    name: str
    opcode: str
    result: list               # [(dtype, shape)]
    operands: list[str]
    line: str


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    coll_bytes: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    dynamic_while: bool = False

    def scaled(self, k: float) -> "CompCost":
        c = CompCost(self.flops * k, self.bytes * k, self.transcendentals * k)
        c.coll_bytes = defaultdict(float, {t: v * k for t, v in self.coll_bytes.items()})
        c.dynamic_while = self.dynamic_while
        return c

    def add(self, other: "CompCost"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.transcendentals += other.transcendentals
        for t, v in other.coll_bytes.items():
            self.coll_bytes[t] += v
        self.dynamic_while |= other.dynamic_while


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur_name, cur_lines = None, []
    entry_name = None
    for line in text.splitlines():
        stripped = line.strip()
        m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$", stripped)
        if m and not stripped.startswith("//"):
            cur_name = m.group(2)
            cur_lines = []
            comps[cur_name] = cur_lines
            if m.group(1):
                entry_name = cur_name
            continue
        if stripped.startswith("}"):
            cur_name = None
            continue
        if cur_name is not None and stripped:
            cur_lines.append(stripped)
    comps["__entry__"] = comps.get(entry_name, [])
    if entry_name:
        comps["__entry_name__"] = [entry_name]  # type: ignore
    return comps


def _opcode_of(rhs: str) -> str:
    # rhs like: 'f32[4,16]{1,0} dot(%a, %b), lhs_contracting_dims=...'
    m = re.match(r"^(?:\([^)]*\)|[\w\[\]{},\d/ *]+?)\s+([\w\-]+)\(", rhs)
    if m:
        return m.group(1)
    return ""


def _parse_ops(lines: list[str]) -> list[OpInfo]:
    ops = []
    for line in lines:
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        opcode = _opcode_of(rhs)
        if not opcode:
            continue
        # result type = text before the opcode token
        idx = rhs.find(f" {opcode}(")
        type_str = rhs[:idx] if idx > 0 else rhs
        result = _parse_shapes(type_str)
        # operands: %refs inside the first (...) after opcode
        paren = rhs[rhs.find(opcode + "(") + len(opcode):]
        depth, end = 0, 0
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        arglist = paren[1:end] if end else ""
        operands = _OPERAND_RE.findall(arglist)
        ops.append(OpInfo(name, opcode, result, operands, line))
    return ops


def _dot_flops(op: OpInfo, symtab) -> float:
    out_elems = _nelems(op.result)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    if not m or not op.operands:
        return 2.0 * out_elems  # unknown: degenerate
    lhs = symtab.get(op.operands[0])
    if not lhs:
        return 2.0 * out_elems
    lhs_shape = lhs[0][1]
    k = 1
    for d in m.group(1).split(","):
        if d and int(d) < len(lhs_shape):
            k *= lhs_shape[int(d)]
    return 2.0 * out_elems * k


def analyze(text: str) -> dict:
    comps = _split_computations(text)
    entry_name = comps.get("__entry_name__", [None])[0]
    cache: dict[str, CompCost] = {}

    def comp_cost(name: str, stack=()) -> CompCost:
        if name in cache:
            return cache[name]
        if name in stack or name not in comps:
            return CompCost()
        lines = comps[name]
        ops = _parse_ops(lines)
        symtab = {op.name: op.result for op in ops}
        cost = CompCost()
        for op in ops:
            oc = op.opcode
            if oc == "while":
                body_m = _CALLED_RE.search(op.line)
                cond_m = _COND_RE.search(op.line)
                trip_m = _TRIP_RE.search(op.line)
                trips = int(trip_m.group(1)) if trip_m else 1
                if not trip_m:
                    cost.dynamic_while = True
                if body_m:
                    cost.add(comp_cost(body_m.group(1), stack + (name,)).scaled(trips))
                if cond_m:
                    cost.add(comp_cost(cond_m.group(1), stack + (name,)).scaled(trips))
                cost.bytes += _nbytes(op.result)
                continue
            if oc in ("fusion", "call"):
                cm = _CALLED_RE.search(op.line)
                if cm:
                    sub = comp_cost(cm.group(1), stack + (name,))
                    # FLOPs recurse; bytes = fusion boundary traffic only
                    cost.flops += sub.flops
                    cost.transcendentals += sub.transcendentals
                    for t, v in sub.coll_bytes.items():
                        cost.coll_bytes[t] += v
                op_bytes = _nbytes(op.result) + sum(
                    _nbytes(symtab.get(o, [])) for o in op.operands)
                cost.bytes += op_bytes
                continue
            if oc == "conditional":
                branches = re.findall(r"(?:true_computation|false_computation|branch_computations)=\{?%?([\w.\-,% ]+)\}?", op.line)
                subcosts = []
                for b in branches:
                    for nm in re.findall(r"[\w.\-]+", b):
                        subcosts.append(comp_cost(nm, stack + (name,)))
                if subcosts:
                    worst = max(subcosts, key=lambda c: c.flops)
                    cost.add(worst)
                continue
            is_coll = None
            for c in COLLECTIVES:
                if oc == c or oc == c + "-start":
                    is_coll = c
                    break
                if oc == c + "-done":
                    is_coll = "skip"
                    break
            if is_coll == "skip":
                continue
            if is_coll:
                operand_bytes = sum(_nbytes(symtab.get(o, [])) for o in op.operands)
                if operand_bytes == 0:
                    operand_bytes = _nbytes(op.result)
                cost.coll_bytes[is_coll] += operand_bytes
                cost.bytes += operand_bytes + _nbytes(op.result)
                continue
            if oc in ("parameter", "constant", "tuple", "get-tuple-element",
                      "bitcast", "after-all", "partition-id", "replica-id",
                      "iota"):
                continue
            if oc == "dot":
                cost.flops += _dot_flops(op, symtab)
                cost.bytes += _nbytes(op.result) + sum(
                    _nbytes(symtab.get(o, [])) for o in op.operands)
                continue
            if oc == "convolution":
                # 2 * out_elems * kernel_elems / out_channels (approx)
                out_elems = _nelems(op.result)
                k_elems = _nelems(symtab.get(op.operands[1], [])) if len(op.operands) > 1 else 1
                out_ch = op.result[0][1][-1] if op.result and op.result[0][1] else 1
                cost.flops += 2.0 * out_elems * max(k_elems // max(out_ch, 1), 1)
                cost.bytes += _nbytes(op.result) + sum(
                    _nbytes(symtab.get(o, [])) for o in op.operands)
                continue
            # default: elementwise-ish / data movement
            out_elems = _nelems(op.result)
            if oc in _ELEMENTWISE:
                cost.flops += out_elems
                if oc in ("exponential", "tanh", "log", "logistic", "power",
                          "rsqrt", "sqrt", "cosine", "sine"):
                    cost.transcendentals += out_elems
            elif oc in ("reduce", "reduce-window"):
                in_elems = sum(_nelems(symtab.get(o, [])) for o in op.operands[:1])
                cost.flops += in_elems
            cost.bytes += _nbytes(op.result) + sum(
                _nbytes(symtab.get(o, [])) for o in op.operands)
        cache[name] = cost
        return cost

    total = comp_cost(entry_name) if entry_name else CompCost()
    return {
        "flops": total.flops,
        "bytes": total.bytes,
        "transcendentals": total.transcendentals,
        "collective_bytes": dict(total.coll_bytes),
        "collective_bytes_total": float(sum(total.coll_bytes.values())),
        "dynamic_while": total.dynamic_while,
    }
