"""Progress-safety static analyzer for the repro engine (stdlib ``ast``).

``python -m repro.analysis.progress_lint [--strict] [paths...]`` walks
``src/repro`` and enforces the progress rules the papers state but a
test suite can only catch probabilistically:

* **PL001 — blocking call in a continuation.**  The Continuations paper
  (Schuchart et al.) forbids blocking MPI calls inside continuation
  callbacks: a callback runs on a progress/executor thread, so blocking
  there stalls the very machinery that would complete the thing being
  waited on.  Any callable handed to ``attach``/``attach_counter``/
  ``then``/``node``/``subscribe``/``register_subsystem``/``async_start``
  is treated as a continuation entry point; the rule flags
  ``wait*()``/``.result()``/``.join()``/``.acquire()``/``time.sleep``/
  ``block_until_ready``/``run_until_idle`` reachable from it through
  intra-module calls (``self.helper()`` chains included).

* **PL002 — persistent-handle lifecycle.**  Handles built by ``*_init``
  factories walk the MPI persistent-request machine declared once in
  ``repro.core.debug`` (``LIFECYCLE_TRANSITIONS``); where call order is
  visible in a straight-line function body the rule flags double-start,
  start-after-invalidate-without-rebuild, wait-without-start and
  use-after-close.  The runtime half of the same machine lives in
  ``repro.core.debug.HandleTracker`` (``REPRO_DEBUG=1``).

* **PL003 — lock-order cycles.**  Lexically nested ``with x._lock:``
  acquisitions across the whole tree form an order graph; a cycle means
  two call paths disagree about acquisition order — a deadlock waiting
  for its interleaving.  (Cross-function nesting is the runtime
  ``OrderedLock``'s job.)

* **PL004 — donated carry reused.**  A buffer passed in a donated
  position of a ``jax.jit(..., donate_argnums=...)``/``_jit_smap``
  program is dead after the call (XLA aliases it); referencing it again
  in the enclosing builder reads freed memory.

Deliberate exceptions live in ``progress_lint_allowlist.py``; every
entry carries a justification string and matches by rule + path +
enclosing symbol, so findings survive line churn.  The module imports
nothing beyond the stdlib (the lifecycle table is loaded from
``core/debug.py`` by file path), so the CI lint job needs no JAX.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import importlib.util
import os
import sys

RULES = {
    "PL001": "blocking call reachable from a continuation callback",
    "PL002": "persistent-handle lifecycle violation",
    "PL003": "inconsistent lock acquisition order (cycle)",
    "PL004": "donated carry referenced after a donating call",
}

_HERE = os.path.dirname(os.path.abspath(__file__))
_PKG_ROOT = os.path.dirname(_HERE)               # .../src/repro
_SRC_ROOT = os.path.dirname(_PKG_ROOT)           # .../src


def _load_by_path(modname: str, path: str):
    spec = importlib.util.spec_from_file_location(modname, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _lifecycle_tables():
    """The declared handle state machine, shared with the runtime
    checker — loaded by file path so the linter never imports the
    package (and its JAX dependency)."""
    mod = _load_by_path("_repro_lint_debug_tables",
                        os.path.join(_PKG_ROOT, "core", "debug.py"))
    return mod.LIFECYCLE_TRANSITIONS, mod.LIFECYCLE_VIOLATIONS


TRANSITIONS, VIOLATIONS = _lifecycle_tables()
IDLE, ACTIVE, STALE, CLOSED = "idle", "active", "stale", "closed"


@dataclasses.dataclass
class Finding:
    rule: str
    path: str          # repo-relative (posix separators)
    line: int
    qual: str          # enclosing Class.method / function
    message: str
    allowed: bool = False
    why: str = ""

    def location(self) -> str:
        return f"{self.path}:{self.line}"


# ---------------------------------------------------------------------------
# Module model
# ---------------------------------------------------------------------------

class ModuleIndex:
    """Top-level functions, classes (with bases) and methods of one file."""

    def __init__(self, path: str, relpath: str, tree: ast.Module):
        self.path = path
        self.relpath = relpath
        self.tree = tree
        self.functions: dict[str, ast.FunctionDef] = {}
        self.classes: dict[str, dict[str, ast.FunctionDef]] = {}
        self.bases: dict[str, list[str]] = {}
        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions[node.name] = node
            elif isinstance(node, ast.ClassDef):
                methods = {}
                for sub in node.body:
                    if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                        methods[sub.name] = sub
                self.classes[node.name] = methods
                self.bases[node.name] = [b.id for b in node.bases
                                         if isinstance(b, ast.Name)]

    def method(self, cls: str | None, name: str):
        """Resolve ``self.name`` in class ``cls`` (single-module MRO)."""
        seen = set()
        while cls is not None and cls not in seen:
            seen.add(cls)
            node = self.classes.get(cls, {}).get(name)
            if node is not None:
                return cls, node
            parents = self.bases.get(cls, [])
            cls = parents[0] if parents else None
        return None, None

    def iter_functions(self):
        """Yield (class_name_or_None, qualname, node) for every def."""
        for name, node in self.functions.items():
            yield None, name, node
        for cls, methods in self.classes.items():
            for name, node in methods.items():
                yield cls, f"{cls}.{name}", node


def parse_module(path: str, root: str | None = None) -> ModuleIndex | None:
    with open(path, encoding="utf-8") as f:
        text = f.read()
    rel = os.path.relpath(path, root or _SRC_ROOT).replace(os.sep, "/")
    try:
        tree = ast.parse(text, filename=path)
    except SyntaxError:
        return None
    return ModuleIndex(path, rel, tree)


# ---------------------------------------------------------------------------
# PL001 — blocking call reachable from a continuation callback
# ---------------------------------------------------------------------------

# call-site name -> (positional indices, keyword names) holding callables
CONT_SITES = {
    "attach": ((1,), ("callback", "on_error")),
    "attach_counter": ((1,), ("callback", "on_error")),
    "then": ((1,), ("fn", "on_error")),
    "node": ((0,), ("fn",)),
    "subscribe": ((0,), ("fn",)),
    "register_subsystem": ((1,), ("poll",)),
    "async_start": ((0,), ("poll_fn",)),
}

BLOCKING_ATTRS = {"wait", "wait_all", "wait_any", "wait_some", "result",
                  "block_until_ready", "run_until_idle"}


def _const(node):
    return node.value if isinstance(node, ast.Constant) else ...


def _blocking_reason(call: ast.Call) -> str | None:
    """Name of the blocking operation, or None if the call is benign."""
    fn = call.func
    if isinstance(fn, ast.Attribute):
        attr = fn.attr
        if attr in BLOCKING_ATTRS:
            for kw in call.keywords:
                if kw.arg == "timeout" and _const(kw.value) == 0:
                    return None          # an explicit non-blocking probe
            return attr
        if attr == "acquire":
            for kw in call.keywords:
                if kw.arg == "blocking" and _const(kw.value) is False:
                    return None
                if kw.arg == "timeout" and _const(kw.value) == 0:
                    return None
            if call.args and _const(call.args[0]) is False:
                return None
            return "acquire"
        if attr == "join" and not call.args and not call.keywords:
            return "join"                # str.join always takes an argument
        if attr == "sleep" and isinstance(fn.value, ast.Name) \
                and fn.value.id == "time":
            return "time.sleep"
    elif isinstance(fn, ast.Name) and fn.id == "sleep":
        return "sleep"
    return None


def _callable_exprs(call: ast.Call):
    """Callable-position expressions of a continuation enqueue call."""
    name = None
    if isinstance(call.func, ast.Attribute):
        name = call.func.attr
    elif isinstance(call.func, ast.Name):
        name = call.func.id
    if name not in CONT_SITES:
        return []
    positions, kwnames = CONT_SITES[name]
    out = []
    for i in positions:
        if len(call.args) > i:
            out.append(call.args[i])
    for kw in call.keywords:
        if kw.arg in kwnames:
            out.append(kw.value)
    return out


def _unwrap_partial(expr):
    """functools.partial(f, ...) / partial(f, ...) -> f."""
    while isinstance(expr, ast.Call):
        fn = expr.func
        is_partial = (isinstance(fn, ast.Name) and fn.id == "partial") or \
            (isinstance(fn, ast.Attribute) and fn.attr == "partial")
        if is_partial and expr.args:
            expr = expr.args[0]
        else:
            return expr
    return expr


def _nested_defs(func_node) -> dict[str, ast.FunctionDef]:
    out = {}
    for node in ast.walk(func_node):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node is not func_node:
            out[node.name] = node
    return out


def _resolve_callable(expr, mi: ModuleIndex, cls: str | None, func_node):
    """Resolve a callable expression to [(cls, qual, node)]; lambdas
    come back as themselves."""
    expr = _unwrap_partial(expr)
    if isinstance(expr, ast.Lambda):
        return [(cls, "<lambda>", expr)]
    if isinstance(expr, ast.Name):
        nested = _nested_defs(func_node).get(expr.id)
        if nested is not None:
            return [(cls, expr.id, nested)]
        top = mi.functions.get(expr.id)
        if top is not None:
            return [(None, expr.id, top)]
    if isinstance(expr, ast.Attribute) and isinstance(expr.value, ast.Name) \
            and expr.value.id in ("self", "cls"):
        owner, node = mi.method(cls, expr.attr)
        if node is not None:
            return [(owner, f"{owner}.{expr.attr}", node)]
    return []


def _call_edges(mi: ModuleIndex, cls: str | None, func_node):
    """Intra-module callees of one function body."""
    out = []
    for node in ast.walk(func_node):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if isinstance(fn, ast.Name):
            target = _nested_defs(func_node).get(fn.id) \
                or mi.functions.get(fn.id)
            if target is not None and target is not func_node:
                owner = cls if target.name in _nested_defs(func_node) else None
                out.append((owner, fn.id, target))
        elif isinstance(fn, ast.Attribute) and isinstance(fn.value, ast.Name) \
                and fn.value.id in ("self", "cls"):
            owner, target = mi.method(cls, fn.attr)
            if target is not None and target is not func_node:
                out.append((owner, f"{owner}.{fn.attr}", target))
    return out


def _pl001(mi: ModuleIndex, findings: list[Finding]) -> None:
    roots = []   # (cls, qual, node, attach_qual, attach_line)
    for cls, qual, func in mi.iter_functions():
        for node in ast.walk(func):
            if isinstance(node, ast.Call):
                for expr in _callable_exprs(node):
                    for owner, cq, target in _resolve_callable(
                            expr, mi, cls, func):
                        roots.append((owner, cq, target, qual, node.lineno))
    seen: set[int] = set()
    for owner, root_qual, root_node, attach_qual, attach_line in roots:
        # BFS over intra-module calls from the callback body
        work = [(owner, root_qual, root_node, root_qual)]
        while work:
            cls, qual, node, chain = work.pop()
            if id(node) in seen:
                continue
            seen.add(id(node))
            for sub in ast.walk(node):
                if isinstance(sub, ast.Call):
                    reason = _blocking_reason(sub)
                    if reason is not None:
                        findings.append(Finding(
                            "PL001", mi.relpath, sub.lineno, qual,
                            f"`{reason}` blocks inside continuation "
                            f"callback `{chain}` (attached at "
                            f"{attach_qual}:{attach_line})"))
            if not isinstance(node, ast.Lambda):
                for nxt_cls, nxt_qual, nxt in _call_edges(mi, cls, node):
                    work.append((nxt_cls, nxt_qual, nxt,
                                 f"{chain} -> {nxt_qual}"))


# ---------------------------------------------------------------------------
# PL002 — persistent-handle lifecycle (straight-line bodies)
# ---------------------------------------------------------------------------

INIT_ATTRS = {"allreduce_init", "reduce_scatter_init", "allgather_init",
              "alltoall_init", "alltoallv_init", "broadcast_init",
              "channel_init", "send_init", "recv_init"}
INIT_CLASSES = {"PersistentCollective", "P2PChannel"}

_RECOVER = {"start": ACTIVE, "close": CLOSED, "rebuild": IDLE}


def _is_init_call(call: ast.Call) -> bool:
    fn = call.func
    if isinstance(fn, ast.Attribute) and fn.attr in INIT_ATTRS:
        return True
    if isinstance(fn, ast.Name) and fn.id in INIT_CLASSES:
        return True
    if isinstance(fn, ast.Attribute) and fn.attr in INIT_CLASSES:
        return True
    return False


def _sub_blocks(stmt):
    for field in ("body", "orelse", "finalbody"):
        block = getattr(stmt, field, None)
        if block:
            yield block
    for handler in getattr(stmt, "handlers", []) or []:
        yield handler.body


def _names_in(node) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _pl002_block(stmts, qual: str, relpath: str,
                 findings: list[Finding]) -> None:
    state: dict[str, str] = {}
    started: dict[str, bool] = {}
    reqs: dict[str, str] = {}      # request var -> handle var

    def apply(var: str, ev: str, line: int) -> None:
        st = state[var]
        if ev == "cancel":         # documented no-op when idle/complete
            if st == CLOSED:
                findings.append(Finding(
                    "PL002", relpath, line, qual,
                    f"use-after-close: `{var}.cancel()` on a closed "
                    f"handle"))
            elif st == ACTIVE:
                state[var] = IDLE
            return
        nxt = TRANSITIONS.get((st, ev))
        if nxt is None:
            why = VIOLATIONS.get((st, ev),
                                 f"illegal `{ev}` in state `{st}`")
            findings.append(Finding(
                "PL002", relpath, line, qual,
                f"{why}: `{var}.{ev}()` while the handle is `{st}`"))
            state[var] = _RECOVER.get(ev, st)
        else:
            state[var] = nxt
        if ev == "start":
            started[var] = True

    def handle_call(call: ast.Call, assigned_to: str | None) -> bool:
        """Apply one call's lifecycle effect; True if it was consumed."""
        fn = call.func
        # epoch.invalidate(...) staleness applies to every tracked handle
        if isinstance(fn, ast.Attribute) and fn.attr == "invalidate":
            for var in list(state):
                apply(var, "invalidate", call.lineno)
            return True
        if not isinstance(fn, ast.Attribute):
            return False
        # h.active.wait() — waiting a start that was never issued
        if fn.attr == "wait" and isinstance(fn.value, ast.Attribute) \
                and fn.value.attr == "active" \
                and isinstance(fn.value.value, ast.Name) \
                and fn.value.value.id in state:
            var = fn.value.value.id
            if not started.get(var, False):
                findings.append(Finding(
                    "PL002", relpath, call.lineno, qual,
                    f"wait-without-start: `{var}.active.wait()` but "
                    f"`{var}` was never started in this scope"))
            else:
                apply(var, "wait", call.lineno)
            return True
        if not isinstance(fn.value, ast.Name):
            return False
        base = fn.value.id
        if base in state:
            if fn.attr in ("start", "close", "rebuild", "cancel"):
                apply(base, fn.attr, call.lineno)
                if fn.attr == "start" and assigned_to is not None:
                    reqs[assigned_to] = base
                return True
            if state[base] == CLOSED:
                findings.append(Finding(
                    "PL002", relpath, call.lineno, qual,
                    f"use-after-close: `{base}.{fn.attr}()` on a closed "
                    f"handle"))
                return True
        if base in reqs and fn.attr == "wait":
            handle = reqs[base]
            if state.get(handle) == ACTIVE:
                apply(handle, "wait", call.lineno)
            return True
        return False

    for st in stmts:
        compound = list(_sub_blocks(st))
        if compound:
            for block in compound:
                _pl002_block(block, qual, relpath, findings)
            # anything the compound touched is untrackable afterwards
            touched = _names_in(st)
            for var in list(state):
                if var in touched:
                    state.pop(var, None)
                    started.pop(var, None)
            for var in list(reqs):
                if var in touched:
                    reqs.pop(var, None)
            continue
        if isinstance(st, ast.Assign) and len(st.targets) == 1 \
                and isinstance(st.targets[0], ast.Name) \
                and isinstance(st.value, ast.Call):
            target = st.targets[0].id
            if _is_init_call(st.value):
                state[target] = IDLE
                started[target] = False
                reqs.pop(target, None)
                continue
            consumed = handle_call(st.value, target)
            if not consumed:
                state.pop(target, None)
                started.pop(target, None)
                reqs.pop(target, None)
            continue
        for node in ast.walk(st):
            if isinstance(node, ast.Call):
                handle_call(node, None)


def _pl002(mi: ModuleIndex, findings: list[Finding]) -> None:
    for _cls, qual, func in mi.iter_functions():
        _pl002_block(func.body, qual, mi.relpath, findings)


# ---------------------------------------------------------------------------
# PL003 — lock-order cycles over lexically nested `with` acquisitions
# ---------------------------------------------------------------------------

def _lock_name(expr, cls: str | None) -> str | None:
    if isinstance(expr, ast.Attribute) and (
            expr.attr.endswith("lock") or expr.attr == "_mu"):
        if isinstance(expr.value, ast.Name) and expr.value.id in ("self",
                                                                  "cls"):
            return f"{cls}.{expr.attr}" if cls else f"*.{expr.attr}"
        return f"*.{expr.attr}"
    return None


class LockEdges:
    """Accumulated across every linted module, then cycle-checked."""

    def __init__(self):
        self.edges: dict[tuple[str, str], list[tuple[str, int, str]]] = {}

    def collect(self, mi: ModuleIndex) -> None:
        for cls, qual, func in mi.iter_functions():
            self._visit(func.body, [], cls, qual, mi.relpath)

    def _visit(self, stmts, held: list[str], cls, qual, relpath) -> None:
        for st in stmts:
            if isinstance(st, ast.With):
                names = []
                for item in st.items:
                    name = _lock_name(item.context_expr, cls)
                    if name is not None:
                        names.append((name, st.lineno))
                for outer in held:
                    for inner, line in names:
                        if outer != inner:
                            self.edges.setdefault((outer, inner), []).append(
                                (relpath, line, qual))
                inner_names = [n for n, _ in names]
                # multi-item `with a, b:` acquires left-to-right
                for i, (a, _) in enumerate(names):
                    for b, line in names[i + 1:]:
                        if a != b:
                            self.edges.setdefault((a, b), []).append(
                                (relpath, line, qual))
                self._visit(st.body, held + inner_names, cls, qual, relpath)
            else:
                for block in _sub_blocks(st):
                    self._visit(block, held, cls, qual, relpath)

    def cycles(self) -> list[tuple[list[str], list[tuple[str, int, str]]]]:
        graph: dict[str, set[str]] = {}
        for (a, b) in self.edges:
            graph.setdefault(a, set()).add(b)

        def path(src, dst):
            stack, seen = [(src, [src])], {src}
            while stack:
                node, p = stack.pop()
                if node == dst:
                    return p
                for nxt in graph.get(node, ()):
                    if nxt not in seen:
                        seen.add(nxt)
                        stack.append((nxt, p + [nxt]))
            return None

        out, reported = [], set()
        for (a, b) in sorted(self.edges):
            back = path(b, a)
            if back is None:
                continue
            cycle = [a] + back
            key = frozenset(cycle)
            if key in reported:
                continue
            reported.add(key)
            witnesses = list(self.edges[(a, b)])
            for i in range(len(back) - 1):
                witnesses += self.edges.get((back[i], back[i + 1]), [])
            out.append((cycle, witnesses))
        return out


def _pl003(lock_edges: LockEdges, findings: list[Finding]) -> None:
    for cycle, witnesses in lock_edges.cycles():
        relpath, line, qual = witnesses[0]
        where = ", ".join(f"{p}:{l} ({q})" for p, l, q in witnesses[:4])
        findings.append(Finding(
            "PL003", relpath, line, qual,
            f"lock-order cycle {' -> '.join(cycle)} (witnesses: {where})"))


# ---------------------------------------------------------------------------
# PL004 — donated carry referenced after a donating call
# ---------------------------------------------------------------------------

def _donated_positions(call: ast.Call):
    """Donated positions of a jit/_jit_smap construction call, if any."""
    fn = call.func
    is_jit = (isinstance(fn, ast.Attribute) and fn.attr == "jit") or \
        (isinstance(fn, ast.Name) and fn.id == "jit")
    if is_jit:
        for kw in call.keywords:
            if kw.arg != "donate_argnums":
                continue
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                pos = tuple(e.value for e in v.elts
                            if isinstance(e, ast.Constant))
                return pos or None
        return None
    if isinstance(fn, ast.Name) and fn.id == "_jit_smap" or \
            isinstance(fn, ast.Attribute) and fn.attr == "_jit_smap":
        for kw in call.keywords:
            if kw.arg == "donate" and _const(kw.value) is False:
                return None
        return (0,)
    return None


def _stores_in(node) -> set[str]:
    return {n.id for n in ast.walk(node)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store)}


def _pl004_block(stmts, donors: dict[str, tuple], donated: dict[str, int],
                 qual: str, relpath: str, findings: list[Finding]) -> None:
    for st in stmts:
        compound = list(_sub_blocks(st))
        if compound:
            for block in compound:
                _pl004_block(block, dict(donors), dict(donated), qual,
                             relpath, findings)
            for var in _stores_in(st):
                donated.pop(var, None)
                donors.pop(var, None)
            continue
        # 1. loads of already-donated buffers -> findings
        for node in ast.walk(st):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load) \
                    and node.id in donated:
                findings.append(Finding(
                    "PL004", relpath, node.lineno, qual,
                    f"`{node.id}` was donated at line {donated[node.id]} "
                    f"(XLA aliases the buffer) but is referenced again"))
                donated.pop(node.id)    # one finding per donation
        # 2. register new donors / donations from this statement
        for node in ast.walk(st):
            if not isinstance(node, ast.Call):
                continue
            pos = _donated_positions(node)
            if pos is not None and isinstance(st, ast.Assign) \
                    and len(st.targets) == 1 \
                    and isinstance(st.targets[0], ast.Name) \
                    and st.value is node:
                donors[st.targets[0].id] = pos
                continue
            if isinstance(node.func, ast.Name) and node.func.id in donors:
                for p in donors[node.func.id]:
                    if len(node.args) > p and isinstance(node.args[p],
                                                         ast.Name):
                        donated[node.args[p].id] = node.lineno
        # 3. rebinds kill tracking
        for var in _stores_in(st):
            donated.pop(var, None)
            if not (isinstance(st, ast.Assign)
                    and isinstance(st.value, ast.Call)
                    and _donated_positions(st.value) is not None):
                donors.pop(var, None)


def _pl004(mi: ModuleIndex, findings: list[Finding]) -> None:
    for _cls, qual, func in mi.iter_functions():
        _pl004_block(func.body, {}, {}, qual, mi.relpath, findings)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def lint_modules(modules: list[ModuleIndex]) -> list[Finding]:
    findings: list[Finding] = []
    lock_edges = LockEdges()
    for mi in modules:
        _pl001(mi, findings)
        _pl002(mi, findings)
        _pl004(mi, findings)
        lock_edges.collect(mi)
    _pl003(lock_edges, findings)
    findings.sort(key=lambda f: (f.rule, f.path, f.line))
    return findings


def lint_source(text: str, path: str = "fixture.py") -> list[Finding]:
    """Lint one source string (the fixture-test entry point)."""
    tree = ast.parse(text, filename=path)
    return lint_modules([ModuleIndex(path, path, tree)])


def collect_paths(root: str) -> list[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for name in sorted(filenames):
            if name.endswith(".py"):
                out.append(os.path.join(dirpath, name))
    return out


def load_allowlist() -> list[dict]:
    mod = _load_by_path("_repro_lint_allowlist",
                        os.path.join(_HERE, "progress_lint_allowlist.py"))
    entries = list(mod.ALLOWLIST)
    for entry in entries:
        for key in ("rule", "path", "qual", "why"):
            if not entry.get(key):
                raise ValueError(
                    f"allowlist entry {entry!r} is missing {key!r} — every "
                    f"exception needs a rule, a location and a written "
                    f"justification")
    return entries


def apply_allowlist(findings: list[Finding], entries: list[dict]) -> None:
    for f in findings:
        for e in entries:
            if e["rule"] != f.rule:
                continue
            if not f.path.endswith(e["path"]):
                continue
            if e["qual"] != "*" and f.qual != e["qual"] \
                    and not f.qual.startswith(e["qual"] + "."):
                continue
            f.allowed = True
            f.why = e["why"]
            break


def format_findings(findings: list[Finding]) -> str:
    """Markdown table, same pipe-table conventions as analysis/report.py."""
    out = ["| rule | location | symbol | finding |",
           "|---|---|---|---|"]
    for f in findings:
        note = f" *(allowlisted: {f.why})*" if f.allowed else ""
        out.append(f"| {f.rule} | `{f.location()}` | `{f.qual}` "
                   f"| {f.message}{note} |")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to lint (default: src/repro)")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero on any non-allowlisted finding")
    ap.add_argument("--no-allowlist", action="store_true",
                    help="report raw findings, ignoring the allowlist")
    args = ap.parse_args(argv)

    roots = args.paths or [_PKG_ROOT]
    files: list[str] = []
    for root in roots:
        if os.path.isdir(root):
            files += collect_paths(root)
        else:
            files.append(root)
    modules = [m for m in (parse_module(p) for p in files) if m is not None]
    findings = lint_modules(modules)
    if not args.no_allowlist:
        apply_allowlist(findings, load_allowlist())

    flagged = [f for f in findings if not f.allowed]
    allowed = [f for f in findings if f.allowed]
    print(f"## progress_lint: {len(files)} file(s), "
          f"{len(flagged)} finding(s), {len(allowed)} allowlisted\n")
    if findings:
        print(format_findings(findings))
    else:
        print("clean — no findings.")
    if flagged and args.strict:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
