"""Deliberate exceptions to the progress-safety lint.

Every entry matches findings by rule + path suffix + enclosing symbol
(``qual`` of the function the finding sits in; ``"*"`` matches any) and
MUST carry a non-empty ``why`` — the linter refuses an entry without a
written justification.  Keep this list short: an entry is a standing
claim that the flagged pattern is safe, reviewed against the rule's
rationale, not a mute button.
"""

ALLOWLIST = (
    {"rule": "PL001", "path": "repro/core/futures.py", "qual": "poll",
     "why": "fut.result() runs strictly after fut.done() returned True "
            "(io_future/chain polls), so it returns immediately — it only "
            "harvests a completed concurrent.futures result, it never "
            "parks the progress thread"},
    {"rule": "PL001", "path": "repro/data/pipeline.py",
     "qual": "PrefetchPipeline._poll",
     "why": "same done()-guarded harvest: the subsystem poll checks "
            "fut.done() and bails with NOPROGRESS otherwise; result() on "
            "a done future is a non-blocking fetch of the filled batch"},
)
