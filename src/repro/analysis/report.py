"""Render dry-run JSONL results as the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import json
import sys
from collections import defaultdict


def load(path: str) -> list[dict]:
    with open(path) as f:
        return [json.loads(l) for l in f if l.strip()]


def fmt_bytes(b) -> str:
    if b is None:
        return "-"
    if b >= 1e12:
        return f"{b / 1e12:.2f}TB"
    if b >= 1e9:
        return f"{b / 1e9:.2f}GB"
    if b >= 1e6:
        return f"{b / 1e6:.1f}MB"
    return f"{b / 1e3:.0f}KB"


def fmt_s(x) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.0f}µs"


def roofline_table(rows: list[dict], mesh: str) -> str:
    out = ["| arch | shape | dominant | compute | memory | collective | "
           "frac | useful | mem/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok" or r["mesh"] != mesh:
            continue
        rl = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | **{rl['dominant']}** "
            f"| {fmt_s(rl['compute_s'])} | {fmt_s(rl['memory_s'])} "
            f"| {fmt_s(rl['collective_s'])} | {rl['roofline_fraction']:.3f} "
            f"| {rl['useful_ratio']:.3f} "
            f"| {fmt_bytes(r['memory']['bytes_in_use_per_device'])} |")
    return "\n".join(out)


def dryrun_table(rows: list[dict]) -> str:
    out = ["| arch | shape | mesh | status | compile | FLOPs/dev | "
           "coll bytes/dev (AG/AR/RS/A2A/CP) | mem/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"SKIP ({r['reason'][:40]}…) | | | | |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"ERROR | | | | |")
            continue
        rl = r["roofline"]
        cb = rl["coll_by_type"]
        coll = "/".join(fmt_bytes(cb.get(k, 0)) for k in
                        ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute"))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {r['compile_s']}s | {rl['flops_per_device']:.3g} "
            f"| {coll} | {fmt_bytes(r['memory']['bytes_in_use_per_device'])} |")
    return "\n".join(out)


def main():
    rows = load(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_baseline.jsonl")
    which = sys.argv[2] if len(sys.argv) > 2 else "roofline"
    if which == "roofline":
        print("### Single-pod (16×16 = 256 chips)\n")
        print(roofline_table(rows, "16x16"))
        print("\n### Multi-pod (2×16×16 = 512 chips)\n")
        print(roofline_table(rows, "2x16x16"))
    else:
        print(dryrun_table(rows))


if __name__ == "__main__":
    main()
