"""Three-term roofline from the compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

HLO_FLOPs / HLO_bytes / collective_bytes come from
:mod:`repro.analysis.hlo` (per-device values; we scale to global by chip
count so the formulas above apply verbatim).  Hardware constants: TPU
v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses

from repro.analysis import hlo as hlo_mod
from repro.launch.mesh import TPU_V5E


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device HLO quantities
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_by_type: dict
    # terms (seconds)
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    # usefulness
    model_flops: float
    hlo_flops_global: float
    useful_ratio: float
    # memory fit
    bytes_in_use_per_device: float | None = None
    dynamic_while: bool = False
    # CPU-HLO parsed bytes are fusion-pessimistic (XLA:CPU fuses less than
    # XLA:TPU, so elementwise temporaries that would stay in VMEM/registers
    # on TPU appear as HBM traffic).  memory_s above uses the analytical
    # traffic model; this field keeps the parsed upper bound.
    memory_s_hlo_upper: float = 0.0
    bytes_analytical: float = 0.0

    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self) -> float:
        """Fraction of the roofline bound that is useful compute."""
        useful_compute_s = (self.model_flops /
                            (self.chips * TPU_V5E["peak_flops_bf16"]))
        return useful_compute_s / max(self.bound_s(), 1e-30)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["dominant"] = self.dominant
        d["bound_s"] = self.bound_s()
        d["roofline_fraction"] = self.roofline_fraction()
        return d


def analytical_bytes(cfg, shape, chips: int, mesh_shape: dict,
                     weight_bytes: float = 2.0) -> float:
    """Per-device HBM traffic model for one step (TPU-fused assumptions).

    Counts: parameter streams (fwd read + bwd read + grad write + optimizer
    read-modify-write of two f32 moments + f32 master params), layer-
    boundary activations (write fwd, read bwd, plus one remat re-read),
    flash-attention q/k/v/o traffic, logits, and KV-cache traffic for
    decode.  Elementwise temporaries are assumed fused (VMEM-resident).
    """
    from repro.models import registry
    B, S = shape.global_batch, shape.seq_len
    D = cfg.d_model
    n_params = registry.param_count(cfg)
    dp = 1
    for ax in ("pod", "data"):
        dp *= mesh_shape.get(ax, 1)
    tp = mesh_shape.get("model", 1)
    params_local = n_params / chips  # FSDP+TP shards params over all chips
    b_loc = max(B / dp, 1)
    act_layers = cfg.num_layers + cfg.num_encoder_layers

    if shape.kind == "train":
        # params: bf16 fwd+bwd reads (2x2B) + grad f32 w (4) + master f32
        # r/w (8) + two moments f32 r/w (16) = 32 B/param
        t = params_local * 32.0
        # layer-boundary activations: fwd write + bwd read + remat re-read
        t += act_layers * b_loc * S * D * 2 * 3
        # flash attention q/k/v/o streams (fwd + bwd ~2x)
        if cfg.num_heads:
            hd = cfg.resolved_head_dim()
            att = (cfg.num_layers if cfg.family != "hybrid"
                   else cfg.num_layers // max(cfg.shared_attn_every, 1))
            att += cfg.num_encoder_layers
            heads_w = (cfg.num_heads + 2 * cfg.num_kv_heads) * hd
            t += 3 * att * b_loc * S * heads_w * 2
        # logits + loss (f32) fwd+bwd
        t += 3 * b_loc * S * (cfg.vocab_size / tp) * 4
        # MoE dispatched tokens
        if cfg.moe is not None:
            t += 3 * cfg.num_layers * b_loc * S * cfg.moe.top_k * D * 2
        return t
    if shape.kind == "prefill":
        t = params_local * 2.0   # bf16 weight read
        t += act_layers * b_loc * S * D * 2 * 1
        if cfg.num_heads:
            hd = cfg.resolved_head_dim()
            heads_w = (cfg.num_heads + 2 * cfg.num_kv_heads) * hd
            t += act_layers * b_loc * S * heads_w * 2
        t += b_loc * S * (cfg.vocab_size / tp) * 4
        if cfg.moe is not None:
            t += cfg.num_layers * b_loc * S * cfg.moe.top_k * D * 2
        return t
    # decode: weights once (bf16=2B, int8=1B) + cache read/write
    t = params_local * weight_bytes
    if cfg.num_heads:
        hd = cfg.resolved_head_dim()
        att = (cfg.num_layers if cfg.family != "hybrid"
               else cfg.num_layers // max(cfg.shared_attn_every, 1))
        att += cfg.num_encoder_layers
        kv_b = 1 if getattr(cfg, "kv_cache_dtype", "bf16") == "int8" else 2
        cache = att * B * S * cfg.num_kv_heads * hd * kv_b * 2  # k+v read
        t += cache / chips
    if cfg.ssm is not None:
        from repro.models import mamba as M
        d_inner, nh, hp, ds = M.dims(cfg)
        t += cfg.num_layers * B * nh * hp * ds * 4 * 2 / chips
    t += max(B / dp, 1) * (cfg.vocab_size / tp) * 4
    return t


def from_compiled(compiled_text: str, *, arch: str, shape: str, mesh_name: str,
                  chips: int, model_flops: float,
                  bytes_in_use: float | None = None,
                  cfg=None, shape_spec=None, mesh_shape: dict | None = None,
                  weight_bytes: float = 2.0,
                  hw: dict = TPU_V5E) -> Roofline:
    h = hlo_mod.analyze(compiled_text)
    flops_dev = h["flops"]
    bytes_dev = h["bytes"]
    coll_dev = h["collective_bytes_total"]
    flops_global = flops_dev * chips
    compute_s = flops_global / (chips * hw["peak_flops_bf16"])
    memory_s_upper = bytes_dev * chips / (chips * hw["hbm_bytes_per_s"])
    if cfg is not None and shape_spec is not None:
        bytes_an = analytical_bytes(cfg, shape_spec, chips, mesh_shape or {},
                                    weight_bytes=weight_bytes)
    else:
        bytes_an = bytes_dev
    memory_s = bytes_an / hw["hbm_bytes_per_s"]
    collective_s = coll_dev * chips / (chips * hw["ici_bytes_per_s_per_link"])
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=flops_dev, bytes_per_device=bytes_dev,
        coll_bytes_per_device=coll_dev, coll_by_type=h["collective_bytes"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops,
        hlo_flops_global=flops_global,
        useful_ratio=model_flops / max(flops_global, 1e-30),
        bytes_in_use_per_device=bytes_in_use,
        dynamic_while=h["dynamic_while"],
        memory_s_hlo_upper=memory_s_upper,
        bytes_analytical=bytes_an,
    )
