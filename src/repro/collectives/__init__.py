"""User-level collectives (paper §4.7) — the canonical import surface.

Everything a caller needs rides one shape:

* ``CollectiveSpec`` — the frozen config record (backend, algorithm,
  chunks, round_batch) accepted by every surface: ``ServeEngine``,
  ``TrainLoopConfig``, ``UserCollectiveStep``/``FsdpStep``, both
  launchers, and every factory below.
* one-shot nonblocking ops: ``iallreduce`` / ``ireduce_scatter`` /
  ``iallgather`` / ``ialltoall`` ``(x, mesh, axis, *, spec=None, ...)``.
* persistent handle factories: ``allreduce_init`` /
  ``reduce_scatter_init`` / ``allgather_init`` / ``alltoall_init`` and
  the p2p family ``channel_init`` / ``send_init`` / ``recv_init``, all
  ``(like, mesh, axis, *, spec=None, epoch=None, stream=None,
  engine=None, ...)``.
* overlap machinery: ``EngineGradReducer`` (replicated grads) and the
  ZeRO-sharded ``FsdpReducer`` / ``FsdpLayout``.

Submodules stay importable directly (``repro.collectives.nonblocking``
etc.); ``schedules`` is re-exported as ``S`` for decomposition helpers.
"""
from repro.collectives import schedules as S
from repro.collectives.nonblocking import (
    CollectiveRequest,
    CollectiveSpec,
    MembershipEpoch,
    MembershipError,
    PersistentCollective,
    UserCollectives,
    allgather_init,
    allreduce_init,
    alltoall_init,
    default_collectives,
    iallgather,
    iallreduce,
    ialltoall,
    ireduce_scatter,
    reduce_scatter_init,
    spec_from_legacy,
)
from repro.collectives.overlap import (
    EngineGradReducer,
    FsdpGather,
    FsdpLayout,
    FsdpReducer,
    FsdpReduction,
)
from repro.collectives.p2p import (
    P2P,
    P2PChannel,
    PersistentRecv,
    PersistentSend,
    channel_init,
    default_p2p,
    recv_init,
    send_init,
)

__all__ = [
    "S",
    "CollectiveRequest", "CollectiveSpec", "MembershipEpoch",
    "MembershipError", "PersistentCollective", "UserCollectives",
    "spec_from_legacy", "default_collectives",
    "iallreduce", "ireduce_scatter", "iallgather", "ialltoall",
    "allreduce_init", "reduce_scatter_init", "allgather_init",
    "alltoall_init",
    "EngineGradReducer",
    "FsdpGather", "FsdpLayout", "FsdpReducer", "FsdpReduction",
    "P2P", "P2PChannel", "PersistentRecv", "PersistentSend",
    "default_p2p", "channel_init", "send_init", "recv_init",
]
