"""Gradient compression with error feedback (distributed-optimization
trick for the 1000+-node regime: the cross-pod (DCI) links are an order
of magnitude slower than ICI, so the pod-axis gradient reduction is
int8-quantized with per-bucket scales; the quantization error is fed back
into the next step (EF-SGD), preserving convergence).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.collectives import schedules as S


def quantize_int8(x: jax.Array, block: int = 2048):
    """Blockwise symmetric int8 quantization. Returns (q, scales)."""
    n = x.shape[-1]
    pad = (-n) % block
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]) if pad else x
    xb = xp.reshape(xp.shape[:-1] + (xp.shape[-1] // block, block))
    scale = jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array, orig_len: int) -> jax.Array:
    xb = q.astype(jnp.float32) * scale
    x = xb.reshape(xb.shape[:-2] + (-1,))
    return x[..., :orig_len]


def compressed_allreduce(x: jax.Array, axis: str, block: int = 2048,
                         algorithm: str = "ring") -> jax.Array:
    """int8 allreduce: quantize → user-schedule reduce (in f32 partial
    sums of dequantized chunks to avoid int overflow) → result.

    Traffic ≈ 1/4 of f32 + scales overhead (block 2048 → +0.2%).
    Returns the allreduced approximation of the f32 sum.
    """
    n = x.shape[-1]
    q, scale = quantize_int8(x, block)
    # ship int8 + scales; reduce by dequantize-add on each hop.  In the
    # SPMD formulation we express this as: dequantize locally and ring-
    # reduce in f32 but with the *wire* tensors being (q, scale) — the
    # compiled collective moves int8.  Implemented as reduce of deq with
    # custom ring over the quantized pair:
    P = S._axis_size(axis)
    if P == 1:
        return dequantize_int8(q, scale, n)
    perm = [(i, (i + 1) % P) for i in range(P)]
    acc = dequantize_int8(q, scale, n)
    cur_q, cur_s = q, scale
    for _ in range(P - 1):
        cur_q = jax.lax.ppermute(cur_q, axis, perm)   # int8 on the wire
        cur_s = jax.lax.ppermute(cur_s, axis, perm)
        acc = acc + dequantize_int8(cur_q, cur_s, n)
    return acc


class ErrorFeedback:
    """EF-SGD state helpers: feed the compression residual back next step.

    Usage (inside the train step, functional):
        comp, new_err = ef.compress(grads, err)
    """

    def __init__(self, axis: str, block: int = 2048):
        self.axis = axis
        self.block = block

    def init(self, grads):
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def reduce_with_feedback(self, grads, err):
        """Returns (reduced_grads, new_err). grads+err is quantized; the
        per-leaf residual (what int8 lost) becomes the next err."""
        def one(g, e):
            target = g.astype(jnp.float32) + e
            flat = target.reshape(-1)
            q, s = quantize_int8(flat, self.block)
            sent = dequantize_int8(q, s, flat.size).reshape(g.shape)
            new_e = target - sent
            red = compressed_allreduce(flat, self.axis, self.block)
            return red.reshape(g.shape), new_e

        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(err)
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (jax.tree.unflatten(treedef, [o[0] for o in out]),
                jax.tree.unflatten(treedef, [o[1] for o in out]))
