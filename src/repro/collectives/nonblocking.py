"""Nonblocking user-space collectives on the progress engine (paper §4.7).

The paper's third demonstration: collective algorithms built *in user
space* on the explicit progress engine rival native implementations.
``schedules.py`` holds the algorithms as monolithic ``shard_map``
programs — one XLA computation, invisible to the engine.  This module
compiles the same algorithms into **chunk-pipelined schedules** driven
by the engine, the way "Extending MPI with User-Level Schedules"
(Schafer et al.) builds persistent collective schedules and the MPI
Continuations work (Schuchart et al.) drives them to completion:

* the payload is split into K chunks;
* each algorithm is decomposed into per-round ``ppermute`` + combine
  steps, each its own jitted ``shard_map`` program;
* chunk c's round r+1 is chained off round r by a *continuation* on a
  ``jax_future`` (round r's output arrays), so rounds fire exactly when
  their inputs are device-ready — no wait loop, no blocking;
* all round tasks live on one dedicated collective ``Stream``, so a
  ``ProgressExecutor`` worker (or any ``engine.progress`` caller) can
  drive many in-flight collectives while the application computes.

``iallreduce`` / ``ireduce_scatter`` / ``iallgather`` / ``ialltoall``
return ``CollectiveRequest`` handles (``Request`` subclass): issue
returns immediately, completion is observed via ``is_complete`` /
``engine.wait`` like every other request in the system, and a failing
round fails the request instead of raising into the progress loop.

Chunking layouts keep outputs bit-identical to the native op:

* allreduce — elementwise, so chunks are contiguous last-dim slices
  (payload zero-padded to a multiple of n·K for the ring family);
* reduce-scatter — chunks interleave the per-rank blocks
  (``[..., n, K, m]`` view) so per-chunk block r slots reassemble into
  the native rank-r block;
* all-gather — the inverse interleave on the output side;
* all-to-all — acts on the leading block dim, so last-dim slices
  concatenate transparently.

Two amortization layers close the small-payload gap (per-round
dispatch+sync cost dominating when the wire time is microseconds):

* **round batching** — ``round_batch=K`` fuses K consecutive rounds of
  a chunk into ONE jitted dispatch (``schedules.fuse_rounds``: plain
  composition, so outputs stay bit-identical to the unbatched rounds).
  The default (``round_batch=None``) auto-picks from the payload size —
  small payloads collapse to 1–2 dispatches per chunk, large payloads
  keep per-round dispatch so chunks still pipeline.
* **persistent schedules** — ``allreduce_init``/... return a
  :class:`PersistentCollective` (MPI ``MPI_Allreduce_init`` + ``Start``
  semantics, Schafer et al.'s user-level persistent schedules): the
  plan (validation, chunk layout, join) and every fused round program
  are fixed and compiled once, and ``start(payload)`` re-binds a new
  payload to the same schedule paying only split+dispatch.  Carries are
  double-buffered through jit donation: each round program donates its
  carry input, so a steady-state start cycles two pre-warmed buffer
  generations per chunk instead of allocating per round.

A third layer makes ``start`` itself O(µs): **executor-driven starts**.
When the handle's collective stream is adopted by a *running*
``ProgressExecutor``, ``start(payload)`` only validates, creates the
request and enqueues a one-shot *issue task* on the stream; the
adopting worker runs the chunk split and dispatches round 0 on its next
sweep (the paper's progress-thread offload, applied to issue).  The
caller — a training step, the serve decode chain — pays an enqueue
(one lock-protected list append), not a jitted dispatch.  Without an
executor (or with it stopped) ``start`` falls back to caller-thread
dispatch, bit-identical semantics either way; the dispatching thread is
recorded on ``CollectiveRequest.issue_thread`` so tests can assert the
handoff happened.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import threading
import warnings
import weakref
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.collectives import schedules as S
from repro.core import debug
from repro.core.continuations import DEFERRED, INLINE, ContinuationQueue
from repro.core.engine import DONE, ProgressEngine, Stream, global_engine
from repro.core.futures import jax_future
from repro.core.request import CancelledError, Request


# ---------------------------------------------------------------------------
# Shape helpers (module-level jitted: stable function objects => one
# compile per distinct shape, not per call)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(1,))
def _pad_last_to(x, target: int):
    pad = target - x.shape[-1]
    if pad == 0:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])


@functools.partial(jax.jit, static_argnums=(1,))
def _slice_last(x, width: int):
    if x.shape[-1] == width:
        return x
    return x[..., :width]


@functools.partial(jax.jit, static_argnums=(1, 2))
def _split_last(x, chunks: int, width: int):
    """Contiguous last-dim split into ``chunks`` pieces of ``width``."""
    return tuple(x[..., c * width:(c + 1) * width] for c in range(chunks))


@jax.jit
def _concat_last(parts):
    if len(parts) == 1:
        return parts[0]
    return jnp.concatenate(parts, axis=-1)


def _first(parts):
    """Single-chunk passthrough join — plain Python, no jit dispatch."""
    return parts[0]


@functools.partial(jax.jit, static_argnums=(1, 2))
def _stack_last(x, k: int, width: int):
    """[..., k*width] -> [..., k, width]: contiguous chunks as a batch
    dim.  Every round body is written in terms of the last dim (and the
    [..., n, m] block view just before it), so the extra axis rides
    through the schedule untouched — K chunks share ONE program and its
    in-program collectives instead of K separate programs."""
    return jnp.reshape(x, x.shape[:-1] + (k, width))


@functools.partial(jax.jit, static_argnums=(1,))
def _unstack_last(y, total: int):
    """Inverse of ``_stack_last`` (+ drop padding): [..., k, w] ->
    [..., total]."""
    flat = jnp.reshape(y, y.shape[:-2] + (y.shape[-2] * y.shape[-1],))
    return flat[..., :total] if flat.shape[-1] != total else flat


@functools.partial(jax.jit, static_argnums=(1, 2))
def _rs_split(x, n: int, chunks: int):
    """Interleaved reduce-scatter split: chunk c gets piece c of every
    rank block, so chunked RS outputs reassemble into the native rank
    block.  x: [..., D] with D divisible by n*chunks."""
    m = x.shape[-1] // (n * chunks)
    v = x.reshape(x.shape[:-1] + (n, chunks, m))
    return tuple(v[..., :, c, :].reshape(x.shape[:-1] + (n * m,))
                 for c in range(chunks))


@jax.jit
def _rs_join(parts):
    """Per-chunk RS outputs [..., m] -> native rank block [..., K*m]."""
    if len(parts) == 1:
        return parts[0]
    return jnp.stack(parts, axis=-2).reshape(
        parts[0].shape[:-1] + (len(parts) * parts[0].shape[-1],))


@functools.partial(jax.jit, static_argnums=(1,))
def _ag_join(parts, n: int):
    """Per-chunk AG outputs [..., n*m] -> native [..., n*d]: the rank-r
    segment of the full output is the concat of every chunk's rank-r
    segment."""
    if len(parts) == 1:
        return parts[0]
    blocks = [p.reshape(p.shape[:-1] + (n, p.shape[-1] // n)) for p in parts]
    stacked = jnp.stack(blocks, axis=-2)          # [..., n, K, m]
    return stacked.reshape(parts[0].shape[:-1]
                           + (n * len(parts) * blocks[0].shape[-1],))


@functools.partial(jax.jit, static_argnums=(1, 2))
def _rs_stack(x, n: int, chunks: int):
    """Interleaved RS split as ONE stacked chunk batch: [..., D] ->
    [..., k, n*m] where row c is ``_rs_split``'s chunk c — the
    chunk-stacked-fusion analogue of ``_stack_last`` for the
    reduce-scatter interleave."""
    m = x.shape[-1] // (n * chunks)
    v = x.reshape(x.shape[:-1] + (n, chunks, m))
    v = jnp.moveaxis(v, -2, -3)                   # [..., k, n, m]
    return v.reshape(x.shape[:-1] + (chunks, n * m))


@jax.jit
def _rs_unstack(y):
    """Stacked RS output [..., k, m] -> native rank block [..., k*m]
    (identical to ``_rs_join`` of the k per-chunk outputs)."""
    return y.reshape(y.shape[:-2] + (y.shape[-2] * y.shape[-1],))


@functools.partial(jax.jit, static_argnums=(1,))
def _ag_unstack(y, n: int):
    """Stacked AG output [..., k, n*m] -> native [..., n*(k*m)]
    (identical to ``_ag_join`` of the k per-chunk outputs)."""
    k, w = y.shape[-2], y.shape[-1]
    v = y.reshape(y.shape[:-2] + (k, n, w // n))
    v = jnp.moveaxis(v, -3, -2)                   # [..., n, k, m]
    return v.reshape(y.shape[:-2] + (n * k * (w // n),))


# ---------------------------------------------------------------------------
# Round-decomposed schedules
# ---------------------------------------------------------------------------

def _take_block(chunks, pos):
    """chunks [..., n, m], traced pos -> [..., m] via dynamic_slice.

    Unlike the one-hot select in ``schedules._take_chunk`` this reads
    only the m-wide block — in a per-round program the one-hot form
    costs a full-payload pass *every round*, turning the ring's 2·W
    total traffic into (2n-1)·W."""
    start = [jnp.zeros((), jnp.int32)] * chunks.ndim
    start[-2] = pos
    sizes = chunks.shape[:-2] + (1,) + chunks.shape[-1:]
    return jax.lax.dynamic_slice(chunks, start, sizes).squeeze(-2)


def _put_block(out, cur, pos):
    """out [..., n, m] <- cur [..., m] at block pos (dynamic_update_slice:
    with the carry donated this is an in-place m-wide write)."""
    start = [jnp.zeros((), jnp.int32)] * out.ndim
    start[-2] = pos
    return jax.lax.dynamic_update_slice(out, cur[..., None, :], start)

class _Schedule:
    """One chunk's compiled pipeline: a tuple of jitted shard_map
    programs (init/rounds/finish, possibly fused by round batching),
    every entry carrying a pytree of arrays sharded on the leading
    dim."""

    __slots__ = ("stages",)

    def __init__(self, stages=()):
        self.stages = tuple(stages)

    @property
    def num_rounds(self) -> int:
        return len(self.stages)


class _RoundStage:
    """One raw (unjitted) round body plus whether a program *starting*
    with it may donate its carry input.  ``donate=False`` exactly when
    the input is the caller's payload: if padding/splitting is a no-op,
    jit may forward the caller's buffer straight through, and donating
    it would delete the user's array."""

    __slots__ = ("fn", "donate")

    def __init__(self, fn, donate: bool = True):
        self.fn = fn
        self.donate = donate


def _jit_smap(fn, mesh, axis, *, donate: bool = True):
    # donate the carry: program inputs past the first are intermediate
    # buffers the pipeline owns (the previous round's outputs), so XLA
    # aliases the through-flowing arrays instead of copying the full
    # payload once per round — with every program donating, a running
    # chunk cycles two live carry generations (the donated input being
    # read, the output being written): double-buffering via aliasing.
    return jax.jit(compat.shard_map(fn, mesh=mesh, in_specs=P(axis),
                                    out_specs=P(axis)),
                   donate_argnums=(0,) if donate else ())


class _RoundSchedule:
    """Round-decomposed schedule in raw form.

    ``compiled(round_batch)`` groups consecutive rounds by the batch
    factor, fuses each group into one program body
    (``schedules.fuse_rounds`` — plain composition, so the op sequence
    and chunk layouts are bit-identical to the unbatched rounds) and
    jits it as a single shard_map dispatch.  Compiled views are cached
    per batch factor, and the _RoundSchedule itself is cached per
    (algorithm, mesh, axis, n), so re-issuing never re-traces."""

    __slots__ = ("mesh", "axis", "stages", "_compiled")

    def __init__(self, mesh, axis, stages):
        self.mesh = mesh
        self.axis = axis
        self.stages = tuple(stages)
        self._compiled: dict[int, _Schedule] = {}

    @property
    def num_rounds(self) -> int:
        return len(self.stages)

    def compiled(self, round_batch: int = 1) -> _Schedule:
        b = max(1, min(int(round_batch), len(self.stages) or 1))
        sched = self._compiled.get(b)
        if sched is None:
            progs = []
            for i in range(0, len(self.stages), b):
                group = self.stages[i:i + b]
                progs.append(_jit_smap(
                    S.fuse_rounds([st.fn for st in group]),
                    self.mesh, self.axis, donate=group[0].donate))
            sched = _Schedule(progs)
            self._compiled[b] = sched
        return sched


# cache: (kind, algorithm-ish key, mesh, axis, n, extras) ->
# _RoundSchedule.  jit itself caches per payload shape; this cache keeps
# the *function objects* stable so re-issuing a collective never
# re-traces.
_schedule_cache: dict = {}


def _cached(key, build):
    sched = _schedule_cache.get(key)
    if sched is None:
        sched = build()
        _schedule_cache[key] = sched
    return sched


def _identity_schedule(mesh, axis):
    return _cached(("identity", mesh, axis),
                   lambda: _RoundSchedule(mesh, axis, ()))


def _recursive_doubling_schedule(mesh, axis, n):
    def build():
        stages = []
        mask = 1
        while mask < n:
            perm = [(i, i ^ mask) for i in range(n)]

            def step(v, perm=perm):
                return v + jax.lax.ppermute(v, axis, perm)

            stages.append(_RoundStage(step, donate=mask > 1))
            mask <<= 1
        return _RoundSchedule(mesh, axis, stages)

    return _cached(("rd", mesh, axis, n), build)


def _ring_rs_init(axis, n, d):
    """carry = (chunks [..., n, W/n], acc [..., W/n]) with acc = own
    starting chunk (rank r starts from chunk (r - d) mod n)."""
    def init(x):
        idx = S._axis_index(axis)
        w = x.shape[-1]
        chunks = jnp.reshape(x, x.shape[:-1] + (n, w // n))
        acc = _take_block(chunks, (idx - d) % n)
        return chunks, acc

    return init


def _ring_rs_round(axis, n, d, step):
    perm = [(i, (i + d) % n) for i in range(n)]

    def rnd(carry):
        chunks, acc = carry
        idx = S._axis_index(axis)
        acc = jax.lax.ppermute(acc, axis, perm)
        acc = acc + _take_block(chunks, (idx - d * (1 + step)) % n)
        return chunks, acc

    return rnd


def _ring_ag_start(axis, n):
    """AG step 0: place the (fully reduced) resident chunk at slot idx."""
    def start(carry):
        _, acc = carry
        idx = S._axis_index(axis)
        out = jnp.zeros(acc.shape[:-1] + (n, acc.shape[-1]), acc.dtype)
        out = _put_block(out, acc, idx)
        return out, acc

    return start


def _ring_ag_round(axis, n, d, step):
    perm = [(i, (i + d) % n) for i in range(n)]

    def rnd(carry):
        out, cur = carry
        idx = S._axis_index(axis)
        cur = jax.lax.ppermute(cur, axis, perm)
        pos = (idx - d * step) % n
        out = _put_block(out, cur, pos)
        return out, cur

    return rnd


def _ring_finish():
    def finish(carry):
        out, _ = carry
        return jnp.reshape(out, out.shape[:-2] + (out.shape[-2] * out.shape[-1],))

    return finish


def _ring_allreduce_schedule(mesh, axis, n, reverse):
    """2n-1 rounds: n-1 reduce-scatter, 1 AG placement, n-1 all-gather."""
    def build():
        d = -1 if reverse else 1
        stages = [_RoundStage(_ring_rs_init(axis, n, d), donate=False)]
        stages += [_RoundStage(_ring_rs_round(axis, n, d, s))
                   for s in range(1, n)]
        stages.append(_RoundStage(_ring_ag_start(axis, n)))
        stages += [_RoundStage(_ring_ag_round(axis, n, d, s))
                   for s in range(1, n)]
        stages.append(_RoundStage(_ring_finish()))
        return _RoundSchedule(mesh, axis, stages)

    return _cached(("ring", mesh, axis, n, reverse), build)


def _hd_halve_round(axis, n, mask):
    """One recursive-halving round: keep the half selected by rank bit
    ``mask``, ship the other half to the XOR partner, combine."""
    perm = [(i, i ^ mask) for i in range(n)]

    def halve(cur):
        idx = S._axis_index(axis)
        width = cur.shape[-1] // 2
        lo, hi = cur[..., :width], cur[..., width:]
        keep_hi = ((idx // mask) % 2) == 1
        send = jnp.where(keep_hi, lo, hi)
        recv = jax.lax.ppermute(send, axis, perm)
        mine = jnp.where(keep_hi, hi, lo)
        return mine + recv

    return halve


def _hd_double_round(axis, n, mask):
    """One recursive-doubling round: exchange with the XOR partner and
    concat in rank-bit order (inverse of the halving round)."""
    perm = [(i, i ^ mask) for i in range(n)]

    def double(cur):
        idx = S._axis_index(axis)
        recv = jax.lax.ppermute(cur, axis, perm)
        keep_hi = ((idx // mask) % 2) == 1
        lo = jnp.where(keep_hi, recv, cur)
        hi = jnp.where(keep_hi, cur, recv)
        return jnp.concatenate([lo, hi], axis=-1)

    return double


def _halving_doubling_schedule(mesh, axis, n):
    def build():
        stages = []
        first = True
        mask = n >> 1
        while mask >= 1:                      # reduce-scatter by halving
            stages.append(_RoundStage(_hd_halve_round(axis, n, mask),
                                      donate=not first))
            first = False
            mask >>= 1
        mask = 1
        while mask < n:                       # all-gather by doubling
            stages.append(_RoundStage(_hd_double_round(axis, n, mask)))
            mask <<= 1
        return _RoundSchedule(mesh, axis, stages)

    return _cached(("hd", mesh, axis, n), build)


def _hd_reduce_scatter_schedule(mesh, axis, n):
    """The halving phase standing alone: log2 n rounds, payload halving
    each round.  Rank bits are consumed MSB-first, so rank r finishes
    holding the contiguous rank-r block — the same output placement as
    the ring schedule / tiled ``psum_scatter``."""
    def build():
        stages = []
        first = True
        mask = n >> 1
        while mask >= 1:
            stages.append(_RoundStage(_hd_halve_round(axis, n, mask),
                                      donate=not first))
            first = False
            mask >>= 1
        return _RoundSchedule(mesh, axis, stages)

    return _cached(("hd_rs", mesh, axis, n), build)


def _hd_all_gather_schedule(mesh, axis, n):
    """The doubling phase standing alone: starting from rank r holding
    its own block, log2 n concat rounds reassemble native rank order."""
    def build():
        stages = []
        first = True
        mask = 1
        while mask < n:
            stages.append(_RoundStage(_hd_double_round(axis, n, mask),
                                      donate=not first))
            first = False
            mask <<= 1
        return _RoundSchedule(mesh, axis, stages)

    return _cached(("hd_ag", mesh, axis, n), build)


def _ring_reduce_scatter_schedule(mesh, axis, n):
    def build():
        def finish(carry):
            return carry[1]

        stages = [_RoundStage(_ring_rs_init(axis, n, 1), donate=False)]
        stages += [_RoundStage(_ring_rs_round(axis, n, 1, s))
                   for s in range(1, n)]
        stages.append(_RoundStage(finish))
        return _RoundSchedule(mesh, axis, stages)

    return _cached(("rs", mesh, axis, n), build)


def _ring_all_gather_schedule(mesh, axis, n):
    def build():
        def init(x):
            idx = S._axis_index(axis)
            out = jnp.zeros(x.shape[:-1] + (n, x.shape[-1]), x.dtype)
            return _put_block(out, x, idx), x

        stages = [_RoundStage(init, donate=False)]
        stages += [_RoundStage(_ring_ag_round(axis, n, 1, s))
                   for s in range(1, n)]
        stages.append(_RoundStage(_ring_finish()))
        return _RoundSchedule(mesh, axis, stages)

    return _cached(("ag", mesh, axis, n), build)


def _bruck_alltoall_schedule(mesh, axis, n):
    def build():
        def init(x):
            idx = S._axis_index(axis)
            return jnp.take(x, (jnp.arange(n) + idx) % n, axis=0)

        stages = [_RoundStage(init, donate=False)]
        step = 1
        while step < n:
            perm = [(i, (i + step) % n) for i in range(n)]
            move = [(k // step) % 2 == 1 for k in range(n)]

            def rnd(x, perm=perm, move=tuple(move)):
                moved = jax.lax.ppermute(x, axis, perm)
                sel = jnp.asarray(move).reshape((n,) + (1,) * (x.ndim - 1))
                return jnp.where(sel, moved, x)

            stages.append(_RoundStage(rnd))
            step <<= 1

        def finish(x):
            idx = S._axis_index(axis)
            return jnp.take(x, (idx - jnp.arange(n)) % n, axis=0)

        stages.append(_RoundStage(finish))
        return _RoundSchedule(mesh, axis, stages)

    return _cached(("bruck", mesh, axis, n), build)


# ---------------------------------------------------------------------------
# The request handle
# ---------------------------------------------------------------------------

class MembershipError(RuntimeError):
    """A membership change invalidated this collective mid-flight.

    Retryable by construction: the payload was *not* consumed — rebuild
    the persistent handle's plan on the surviving mesh
    (``PersistentCollective.rebuild``) and ``start`` it again, or
    re-issue the one-shot op against the new mesh.  ``survivors`` is the
    surviving device count the epoch was invalidated with, ``version``
    the epoch generation that killed this request."""

    def __init__(self, message: str, *, survivors: int | None = None,
                 version: int | None = None):
        super().__init__(message)
        self.survivors = survivors
        self.version = version


class MembershipEpoch:
    """Generation counter for the set of devices collectives run on.

    The fault-tolerance monitors (``HeartbeatMonitor`` flagging a dead
    peer, ``StepWatchdog`` firing on a hung step) call ``invalidate``
    from their engine-subsystem polls; persistent handles registered on
    the epoch get their in-flight start failed with a retryable
    :class:`MembershipError` (exactly once, through the same
    ``_fail_lock`` discipline the chunk pipeline uses), and a handle
    built under an older generation refuses further ``start``s until
    ``rebuild`` re-plans it on the surviving mesh.  Subscribed listeners
    (the serve engine's drain/re-admit, the trainer's reducer rebuild)
    run after the handles are failed — invalidation is cheap enough to
    run inline in a subsystem poll; listeners must only *record* the
    change and fail fast, deferring heavy rebuild work to their own
    threads (a listener that drains streams inside the poll that fired
    it would deadlock an executor worker against itself)."""

    def __init__(self, n_devices: int | None = None):
        self._lock = debug.make_lock("MembershipEpoch._lock")
        self.version = 0
        self.n_devices = (n_devices if n_devices is not None
                          else len(jax.devices()))
        self.invalidations = 0
        self._handles: "weakref.WeakSet" = weakref.WeakSet()
        self._listeners: list[Callable[["MembershipEpoch",
                                        "MembershipError"], None]] = []

    def register(self, handle: "PersistentCollective") -> None:
        with self._lock:
            self._handles.add(handle)

    def subscribe(self, fn: Callable[["MembershipEpoch", "MembershipError"],
                                     None]) -> None:
        """``fn(epoch, exc)`` runs after every invalidation, once the
        registered handles' in-flight starts have been failed."""
        with self._lock:
            self._listeners.append(fn)

    def invalidate(self, *, survivors: int, reason: str = "") -> "MembershipError":
        """Declare a membership change down to ``survivors`` devices.

        Bumps the generation, fails every registered handle's in-flight
        start with a :class:`MembershipError`, then notifies listeners.
        Returns the error instance (also raised into waiters)."""
        with self._lock:
            self.version += 1
            self.invalidations += 1
            self.n_devices = int(survivors)
            version = self.version
            handles = list(self._handles)
            listeners = list(self._listeners)
        exc = MembershipError(
            f"membership epoch {version}: {int(survivors)} surviving "
            f"device(s)" + (f" ({reason})" if reason else ""),
            survivors=int(survivors), version=version)
        for h in handles:
            h._membership_changed(exc)
        for fn in listeners:
            fn(self, exc)
        return exc

    def __repr__(self):
        return (f"MembershipEpoch(version={self.version}, "
                f"n_devices={self.n_devices}, "
                f"handles={len(self._handles)})")


class CollectiveRequest(Request):
    """Handle for an in-flight user-space collective.

    Carries the collective stream so ``wait()`` (and ``engine.wait``
    callers who pass ``req.stream``) progress the right serial context;
    ``rounds_done``/``rounds_total`` expose pipeline position (in
    *dispatches* — with round batching one dispatch covers several
    algorithm rounds) for stats and tests.  ``issue_thread`` is the
    ident of the thread that dispatched round 0 (None until it has):
    with an executor-driven start this is an executor worker, not the
    ``start()`` caller."""

    __slots__ = ("engine", "stream", "queue", "ctx", "op", "algorithm",
                 "num_chunks", "rounds_total", "rounds_done", "_fail_lock",
                 "_cancelled", "issue_thread")

    def __init__(self, engine: ProgressEngine, stream: Stream, queue,
                 op: str, algorithm: str, num_chunks: int,
                 rounds_total: int, ctx=None):
        super().__init__(tag=f"i{op}")
        self.engine = engine
        self.stream = stream
        self.queue = queue
        self.ctx = ctx
        self.op = op
        self.algorithm = algorithm
        self.num_chunks = num_chunks
        self.rounds_total = rounds_total
        self.rounds_done = 0
        self._fail_lock = threading.Lock()
        self._cancelled = False
        self.issue_thread: int | None = None

    @property
    def cancelled(self) -> bool:
        return self._cancelled

    def cancel(self) -> None:
        """MPI_Cancel + MPI_Wait semantics: complete the request with
        ``CancelledError`` so waiters return instead of spinning.
        Already-dispatched round programs retire harmlessly — their
        completion continuations observe the completed request and
        abandon the chunk instead of dispatching further rounds.  A
        persistent handle whose active start was cancelled is
        restartable (fail-then-restart safe)."""
        with self._fail_lock:
            if self._complete:
                return
            self._cancelled = True
            self.fail(CancelledError(f"{self.tag} cancelled"))
        if self.ctx is not None:
            self.ctx.cancelled += 1

    def wait(self, engine=None, stream=None, timeout: float | None = None):
        """MPI_Wait: drive the collective's stream until complete.

        Two refinements over the generic ``engine.wait`` loop: a
        DEFERRED queue is drained by the waiter (the queue is
        exactly-once under concurrent drains, so this is safe even with
        an executor attached — and without one the round chain would
        stall with everything 'ready'), and when a progress sweep finds
        nothing to complete — the in-flight round program is still
        executing on the devices — the waiter *parks* on the oldest
        not-yet-ready round's device arrays (a GIL-free blocking wait)
        instead of re-polling.  On oversubscribed CPU hosts the busy
        spin competes for cores with the very device threads running the
        collective; parking returns them.  Parking is bounded by one
        in-flight round program, so ``timeout`` is checked between
        rounds (it can overshoot by at most one round's runtime); tasks
        whose state isn't device arrays fall back to the poll loop."""
        import time

        from repro.core.futures import _arrays_ready
        eng = engine if engine is not None else self.engine
        s = stream if stream is not None else self.stream
        q = self.queue
        deferred = q is not None and q.policy == DEFERRED
        ex = eng.executor
        t0 = time.monotonic()
        while not self.is_complete:
            owned = ex is not None and ex.running and ex.owns(s)
            made = 0 if owned else eng.progress(s)
            if deferred:
                made += q.drain()
            if timeout is not None and not self.is_complete \
                    and time.monotonic() - t0 > timeout:
                # completion is re-checked first: a request that finished
                # during this very sweep returns its result, never a
                # spurious TimeoutError
                raise TimeoutError(f"wait timed out after {timeout}s")
            if made or self.is_complete:
                continue
            with s._lock:
                states = [t.state for t in s._tasks if t.state is not None]
            busy = next((st for st in states if not _arrays_ready(st)), None)
            if busy is not None:
                jax.block_until_ready(busy)
            elif owned:
                # everything ready but not yet retired: the workers'
                # next sweep will do it — yield instead of hot-spinning
                time.sleep(20e-6)
        return self.value()

    def __repr__(self):
        return (f"CollectiveRequest({self.op}/{self.algorithm}, "
                f"chunks={self.num_chunks}, "
                f"rounds={self.rounds_done}/{self.rounds_total}, "
                f"complete={self.is_complete})")


# ---------------------------------------------------------------------------
# The chunk pipeline driver
# ---------------------------------------------------------------------------

class _ChunkPipeline:
    """Drives K chunks through their round schedules via continuations.

    Every stage dispatch happens inside a continuation callback (or in
    ``launch`` for round 0): run stage r, register a ``jax_future`` for
    its outputs on the collective stream, attach the next continuation.
    A stage that raises — or a future that fails — fails the collective
    request exactly once; remaining chunks are abandoned (their pending
    futures complete harmlessly).

    ``defer=True`` (executor-driven start) skips the inline ``launch``:
    the caller enqueues a one-shot issue task on the collective stream
    instead, and the stream's adopting worker runs ``launch`` — the
    chunk split and every round-0 dispatch — on its next sweep."""

    def __init__(self, ctx: "UserCollectives", req: CollectiveRequest,
                 schedules, payloads_fn: Callable[[], list],
                 join: Callable[[list], Any], defer: bool = False):
        self.ctx = ctx
        self.req = req
        self.schedules = schedules
        self.join = join
        self._lock = threading.Lock()
        self._results: list = [None] * len(schedules)
        self._remaining = len(schedules)
        self._payloads_fn = payloads_fn
        if not defer:
            self.launch()

    def launch(self) -> None:
        """Split the payload and dispatch round 0 of every chunk on the
        *calling* thread (the start() caller, or — deferred — the
        executor worker that owns the collective stream)."""
        if self.req.is_complete:
            return                    # cancelled before the issue task ran
        self.req.issue_thread = threading.get_ident()
        fn, self._payloads_fn = self._payloads_fn, None
        try:
            payloads = fn()
        except BaseException as exc:  # noqa: BLE001
            self._fail(exc)
            return
        for c, payload in enumerate(payloads):
            self._advance(c, 0, payload)

    def _advance(self, c: int, r: int, value) -> None:
        if self.req.is_complete:
            return                    # another chunk failed: abandon
        stages = self.schedules[c].stages
        if r >= len(stages):
            # degenerate schedule (n == 1): completion still flows
            # through one future so issue never completes synchronously
            fut = jax_future(self.ctx.engine, value, self.ctx.stream)
            self.ctx.queue.attach(
                fut, lambda rq, c=c: self._chunk_done(c, rq.value()),
                on_error=self._on_error)
            return
        try:
            out = stages[r](value)
        except BaseException as exc:  # noqa: BLE001
            self._fail(exc)
            return
        self.req.rounds_done += 1
        fut = jax_future(self.ctx.engine, out, self.ctx.stream)
        if r + 1 < len(stages):
            cb = lambda rq, c=c, r=r: self._advance(c, r + 1, rq.value())  # noqa: E731
        else:
            cb = lambda rq, c=c: self._chunk_done(c, rq.value())  # noqa: E731
        self.ctx.queue.attach(fut, cb, on_error=self._on_error)

    def _fail(self, exc: BaseException) -> None:
        """Fail the request exactly once; the failure counter moves with
        the request, not with every chunk that observes the failure."""
        with self.req._fail_lock:
            if self.req.is_complete:
                return
            self.req.fail(exc)
        self.ctx.failed += 1

    def _on_error(self, rq) -> None:
        self._fail(rq.exception or RuntimeError("collective round failed"))

    def _chunk_done(self, c: int, value) -> None:
        with self._lock:
            self._results[c] = value
            self._remaining -= 1
            done = self._remaining == 0
        if not done or self.req.is_complete:
            return
        try:
            result = self.join(self._results)
        except BaseException as exc:  # noqa: BLE001
            self._fail(exc)
            return
        with self.req._fail_lock:
            if self.req.is_complete:
                return                # lost the race to cancel()/fail()
            self.req.complete(result)
        self.ctx.completed += 1


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def _axis_len(mesh, axis: str) -> int:
    return dict(mesh.shape)[axis]


def _largest_divisor_leq(total: int, k: int) -> int:
    k = max(1, min(k, total))
    while total % k:
        k -= 1
    return k


def _check_payload(x, op: str) -> None:
    """All four collectives shard the leading dim and chunk/schedule over
    the last — a 1-D payload would chunk the sharded dim itself and die
    deep inside a round program; reject it eagerly instead."""
    if len(x.shape) < 2:
        raise ValueError(
            f"i{op}: payload must be at least 2-D ([sharded_dim, ..., "
            f"payload_dim]), got shape {tuple(x.shape)}; reshape(-1, 1) "
            f"scalars-per-rank or add a trailing payload dim")


# ---------------------------------------------------------------------------
# CollectiveSpec — the one collective-tuning config object
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CollectiveSpec:
    """How collectives run: backend + algorithm + chunking + fusion.

    One frozen value replaces the ``collective_backend`` /
    ``collective_algorithm`` / ``collective_chunks`` /
    ``collective_round_batch`` kwarg sprawl that every surface
    (``ServeEngine``, ``TrainLoopConfig``, ``UserCollectiveStep``, both
    launchers) used to duplicate.  Validation is eager — a bad algorithm
    name or chunk count raises at construction, never from inside a
    round program.  ``resolve(axis_size)`` applies the power-of-two
    fallback for a concrete axis (still eager: before any tracing).
    """

    backend: str = "native"
    algorithm: str = "ring"
    chunks: int = 1
    round_batch: int | None = None

    def __post_init__(self):
        if self.backend not in ("native", "user"):
            raise ValueError(
                f"CollectiveSpec.backend must be 'native' or 'user', "
                f"got {self.backend!r}")
        if self.algorithm not in S.ALGORITHMS:
            raise ValueError(
                f"CollectiveSpec.algorithm {self.algorithm!r} unknown; "
                f"options: {sorted(S.ALGORITHMS)}")
        if int(self.chunks) < 1:
            raise ValueError(
                f"CollectiveSpec.chunks must be >= 1, got {self.chunks}")
        if self.round_batch is not None and int(self.round_batch) < 0:
            raise ValueError(
                f"CollectiveSpec.round_batch must be None (auto) or "
                f">= 0, got {self.round_batch}")

    @property
    def user(self) -> bool:
        return self.backend == "user"

    def resolve(self, axis_size: int) -> "CollectiveSpec":
        """The pow2 check for a concrete axis: power-of-two-only
        algorithms fall back to ring (with the resolve_algorithm
        warning) on other sizes."""
        algorithm = S.resolve_algorithm(self.algorithm, axis_size)
        if algorithm == self.algorithm:
            return self
        return dataclasses.replace(self, algorithm=algorithm)


# one warning per config surface per process: the point is a visible
# nudge, not a firehose on every construction in a serving loop
_legacy_kwargs_warned: set[str] = set()


def spec_from_legacy(spec: "CollectiveSpec | None" = None, *,
                     surface: str, backend: str | None = None,
                     algorithm: str | None = None,
                     chunks: int | None = None,
                     round_batch: int | None = None,
                     default: "CollectiveSpec | None" = None,
                     ) -> "CollectiveSpec":
    """Coerce one surface's legacy ``collective_*`` kwargs into a
    :class:`CollectiveSpec` (deprecation shim, one release).

    ``spec`` wins when given (mixing it with legacy kwargs raises — a
    silent precedence rule would hide config bugs).  Any legacy kwarg
    emits a ``DeprecationWarning`` once per ``surface`` per process.
    """
    legacy = {k: v for k, v in (("backend", backend),
                                ("algorithm", algorithm),
                                ("chunks", chunks),
                                ("round_batch", round_batch))
              if v is not None}
    if spec is not None:
        if legacy:
            raise ValueError(
                f"{surface}: pass either collective_spec or the legacy "
                f"collective_* kwargs, not both (got {sorted(legacy)})")
        return spec
    base = default if default is not None else CollectiveSpec()
    if not legacy:
        return base
    if surface not in _legacy_kwargs_warned:
        _legacy_kwargs_warned.add(surface)
        warnings.warn(
            f"{surface}: the collective_backend / collective_algorithm / "
            f"collective_chunks / collective_round_batch kwargs are "
            f"deprecated; pass collective_spec=CollectiveSpec(...) "
            f"(repro.collectives) instead",
            DeprecationWarning, stacklevel=3)
    return dataclasses.replace(base, **legacy)


# ---------------------------------------------------------------------------
# Issue plans (everything about a collective that does NOT depend on the
# payload *values* — so persistent handles can fix it once)
# ---------------------------------------------------------------------------

class _Plan:
    """Issue-invariant description of one collective for one payload
    signature (shape, dtype, mesh, axis): the chunk split, the raw
    per-chunk round schedules (compiled per the resolved round-batch
    factor at issue/init time) and the join.  All validation and
    heuristics happen when the plan is built; issuing against a plan is
    pure split + dispatch."""

    __slots__ = ("op", "algorithm", "shape", "dtype", "mesh", "axis",
                 "schedules", "split", "join", "payload_bytes",
                 "round_batch")

    def __init__(self, op, algorithm, shape, dtype, mesh, axis,
                 schedules, split, join, payload_bytes, round_batch):
        self.op = op
        self.algorithm = algorithm
        self.shape = shape
        self.dtype = dtype
        self.mesh = mesh
        self.axis = axis
        self.schedules = schedules
        self.split = split
        self.join = join
        self.payload_bytes = payload_bytes
        self.round_batch = round_batch

    @property
    def num_rounds(self) -> int:
        return max((s.num_rounds for s in self.schedules), default=0)


def _payload_bytes(shape, dtype) -> int:
    size = 1
    for s in shape:
        size *= int(s)
    try:
        return size * jnp.dtype(dtype).itemsize
    except TypeError:
        return size * 4


def _resolve_round_batch(round_batch, payload_bytes: int,
                         num_rounds: int) -> int:
    """None / <=0 means auto: pick from the payload size (small payloads
    collapse to 1–2 dispatches per chunk, large keep per-round)."""
    if round_batch is None or int(round_batch) <= 0:
        return S.auto_round_batch(payload_bytes, num_rounds)
    return int(round_batch)


def _plan_allreduce(mesh, axis: str, shape, dtype, algorithm: str,
                    chunks: int, round_batch=None) -> _Plan:
    n = _axis_len(mesh, axis)
    algorithm = S.resolve_algorithm(algorithm, n)
    chunks = max(1, int(chunks))
    D = shape[-1]
    nbytes = _payload_bytes(shape, dtype)
    if n == 1:
        return _Plan("allreduce", algorithm, tuple(shape), dtype, mesh,
                     axis, [_identity_schedule(mesh, axis)],
                     lambda x: [x], _first, nbytes, 1)
    if algorithm == "recursive_doubling":
        base = _recursive_doubling_schedule(mesh, axis, n)
        per = -(-D // chunks)        # rd has no per-rank block structure
    else:
        # ring family (+ halving/doubling): chunk width a multiple of n
        # so every chunk splits evenly into per-rank blocks
        per = -(-D // (n * chunks)) * n
        if algorithm == "halving_doubling":
            base = _halving_doubling_schedule(mesh, axis, n)
        else:
            base = _ring_allreduce_schedule(mesh, axis, n, False)
    pad_to = per * chunks
    batch = _resolve_round_batch(round_batch, nbytes, base.num_rounds)
    if chunks == 1:
        if pad_to == D:
            split = lambda x: [x]                               # noqa: E731
            join = _first
        else:
            split = lambda x: [_pad_last_to(x, pad_to)]         # noqa: E731
            join = lambda parts: _slice_last(parts[0], D)       # noqa: E731
        scheds = [base]
    elif algorithm != "bidir" and batch >= base.num_rounds:
        # chunk fusion for the fully-batched (small payload) regime: all
        # K chunks ride ONE program as a stacked batch dim, cutting the
        # in-program collective count K×.  Bit-identical to the
        # per-chunk issue: every element's cross-rank summation order
        # depends only on ring position / partner masks, never on which
        # chunk the element landed in.
        split = lambda x: [_stack_last(                         # noqa: E731
            _pad_last_to(x, pad_to), chunks, per)]
        join = lambda parts: _unstack_last(parts[0], D)         # noqa: E731
        scheds = [base]
    elif algorithm == "recursive_doubling":
        # no divisibility constraint: contiguous near-equal slices
        widths = [len(r) for r in _split_ranges(D, min(chunks, D))]
        split = lambda x: _contiguous_chunks(x, widths)         # noqa: E731
        scheds = [base] * len(widths)
        join = _concat_last
    else:
        split = lambda x: list(_split_last(                     # noqa: E731
            _pad_last_to(x, pad_to), chunks, per))
        if algorithm == "bidir":
            # both ICI directions at once: alternate ring direction
            # per chunk (chunks=1 degenerates to a forward ring)
            scheds = [_ring_allreduce_schedule(mesh, axis, n, bool(c % 2))
                      for c in range(chunks)]
        else:
            scheds = [base] * chunks
        join = lambda parts: _slice_last(                       # noqa: E731
            _concat_last(tuple(parts)), D)
    return _Plan("allreduce", algorithm, tuple(shape), dtype, mesh, axis,
                 scheds, split, join, nbytes, batch)


def _plan_reduce_scatter(mesh, axis: str, shape, dtype,
                         algorithm: str = "ring", chunks: int = 1,
                         round_batch=None) -> _Plan:
    n = _axis_len(mesh, axis)
    D = shape[-1]
    if D % n:
        raise ValueError(
            f"ireduce_scatter: last dim {D} not divisible by "
            f"axis size {n}")
    nbytes = _payload_bytes(shape, dtype)
    if n == 1:
        return _Plan("reduce_scatter", "ring", tuple(shape), dtype, mesh,
                     axis, [_identity_schedule(mesh, axis)],
                     lambda x: [x], _first, nbytes, 1)
    algorithm = S.resolve_rs_ag_algorithm(algorithm, n, op="reduce_scatter")
    k = _largest_divisor_leq(D // n, max(1, int(chunks)))
    base = (_hd_reduce_scatter_schedule(mesh, axis, n)
            if algorithm == "halving_doubling"
            else _ring_reduce_scatter_schedule(mesh, axis, n))
    batch = _resolve_round_batch(round_batch, nbytes, base.num_rounds)
    if k == 1:
        split = lambda x: [x]                                   # noqa: E731
        join = _first
        scheds = [base]
    elif batch >= base.num_rounds:
        # chunk-stacked fusion (the PR-4 small-payload regime): all K
        # interleaved chunks ride ONE fused program as a stacked batch
        # dim.  Bit-identical to the per-chunk issue — the round bodies
        # act on the last dim only, and each element's summation order
        # depends on ring position / partner masks, not its chunk row.
        split = lambda x: [_rs_stack(x, n, k)]                  # noqa: E731
        join = lambda parts: _rs_unstack(parts[0])              # noqa: E731
        scheds = [base]
    else:
        split = lambda x: list(_rs_split(x, n, k))              # noqa: E731
        join = lambda parts: _rs_join(tuple(parts))             # noqa: E731
        scheds = [base] * k
    return _Plan("reduce_scatter", algorithm, tuple(shape), dtype, mesh,
                 axis, scheds, split, join, nbytes, batch)


def _plan_allgather(mesh, axis: str, shape, dtype,
                    algorithm: str = "ring", chunks: int = 1,
                    round_batch=None) -> _Plan:
    n = _axis_len(mesh, axis)
    nbytes = _payload_bytes(shape, dtype)
    if n == 1:
        return _Plan("allgather", "ring", tuple(shape), dtype, mesh, axis,
                     [_identity_schedule(mesh, axis)],
                     lambda x: [x], _first, nbytes, 1)
    algorithm = S.resolve_rs_ag_algorithm(algorithm, n, op="allgather")
    d = shape[-1]
    k = _largest_divisor_leq(d, max(1, int(chunks)))
    base = (_hd_all_gather_schedule(mesh, axis, n)
            if algorithm == "halving_doubling"
            else _ring_all_gather_schedule(mesh, axis, n))
    batch = _resolve_round_batch(round_batch, nbytes, base.num_rounds)
    if k == 1:
        split = lambda x: [x]                                   # noqa: E731
        join = _first
        scheds = [base]
    elif batch >= base.num_rounds:
        # chunk-stacked fusion: contiguous chunks as a batch dim, one
        # fused program, inverse interleave on the way out
        split = lambda x: [_stack_last(x, k, d // k)]           # noqa: E731
        join = lambda parts: _ag_unstack(parts[0], n)           # noqa: E731
        scheds = [base]
    else:
        split = lambda x: list(_split_last(x, k, d // k))       # noqa: E731
        join = lambda parts: _ag_join(tuple(parts), n)          # noqa: E731
        scheds = [base] * k
    return _Plan("allgather", algorithm, tuple(shape), dtype, mesh, axis,
                 scheds, split, join, nbytes, batch)


def _plan_alltoall(mesh, axis: str, shape, dtype, chunks: int,
                   round_batch=None) -> _Plan:
    n = _axis_len(mesh, axis)
    lead = shape[0]
    if lead % n:
        raise ValueError(
            f"ialltoall: leading dim {lead} not divisible by "
            f"axis size {n}")
    nbytes = _payload_bytes(shape, dtype)
    if n == 1:
        return _Plan("alltoall", "bruck", tuple(shape), dtype, mesh, axis,
                     [_identity_schedule(mesh, axis)],
                     lambda x: [x], _first, nbytes, 1)
    D = shape[-1]
    widths = [len(r) for r in _split_ranges(D, min(max(1, int(chunks)), D))]
    base = _bruck_alltoall_schedule(mesh, axis, n)
    batch = _resolve_round_batch(round_batch, nbytes, base.num_rounds)
    if len(widths) == 1:
        split = lambda x: [x]                                   # noqa: E731
        join = _first
    else:
        split = lambda x: _contiguous_chunks(x, widths)         # noqa: E731
        join = _concat_last
    return _Plan("alltoall", "bruck", tuple(shape), dtype, mesh, axis,
                 [base] * len(widths), split, join, nbytes, batch)


class UserCollectives:
    """Issue context for nonblocking user-space collectives.

    Owns one dedicated collective ``Stream`` (created on the engine, or
    adopted by a ``ProgressExecutor`` when given) and one
    ``ContinuationQueue`` that chains the per-round dispatches.  INLINE
    policy (default) runs the chaining on whichever thread progresses
    the stream — a background worker, or the waiting thread itself;
    DEFERRED routes it through the queue's ready list (adopt the queue
    on an executor so its workers drain it between polls).
    """

    _ids = itertools.count()

    def __init__(self, engine: Optional[ProgressEngine] = None, *,
                 executor=None, stream: Optional[Stream] = None,
                 policy: str = INLINE, name: str = "",
                 epoch: "MembershipEpoch | None" = None):
        self.engine = engine if engine is not None else global_engine()
        self.executor = executor
        self.epoch = epoch
        self.name = name or f"usercoll{next(UserCollectives._ids)}"
        self._own_stream = stream is None
        if stream is None:
            if executor is not None:
                stream = executor.stream(f"{self.name}-stream")
            else:
                stream = self.engine.stream(f"{self.name}-stream")
        self.stream = stream
        self.queue = ContinuationQueue(self.engine, self.stream,
                                       policy=policy, name=f"{self.name}-q")
        self._adopted_queue = False
        if executor is not None and policy == DEFERRED:
            executor.adopt_queue(self.queue)
            self._adopted_queue = True
        self.issued = 0
        self.completed = 0
        self.failed = 0
        self.cancelled = 0
        self._closed = False

    # -- the collectives ---------------------------------------------------
    def iallreduce(self, x, mesh, axis: str, *, algorithm: str = "ring",
                   chunks: int = 1, round_batch: int | None = None,
                   spec: "CollectiveSpec | None" = None) -> CollectiveRequest:
        """Nonblocking allreduce of ``x`` (leading dim sharded on
        ``axis``), bit-identical to ``psum`` under the same shard_map
        layout.  ``algorithm`` is any ``schedules.ALGORITHMS`` key;
        power-of-two-only algorithms fall back to ring with a warning on
        other axis sizes (eager — nothing raises from inside jit).
        ``round_batch`` fuses that many consecutive rounds into one
        jitted dispatch per chunk (None/0: auto from payload size).
        ``spec`` (a :class:`CollectiveSpec`) overrides all three."""
        self._check_open()
        _check_payload(x, "allreduce")
        if spec is not None:
            algorithm, chunks, round_batch = \
                spec.algorithm, spec.chunks, spec.round_batch
        plan = _plan_allreduce(mesh, axis, tuple(x.shape),
                               getattr(x, "dtype", jnp.float32),
                               algorithm, chunks, round_batch)
        return self._issue_plan(plan, x)

    def ireduce_scatter(self, x, mesh, axis: str, *,
                        algorithm: str = "ring", chunks: int = 1,
                        round_batch: int | None = None,
                        spec: "CollectiveSpec | None" = None,
                        ) -> CollectiveRequest:
        """Nonblocking reduce-scatter (matches tiled ``psum_scatter`` on
        the last dim).  Requires the last dim divisible by the axis size
        (validated eagerly).  ``algorithm`` is ``ring`` or
        ``halving_doubling`` (the halving phase alone); other names fall
        back to ring with a warning."""
        self._check_open()
        _check_payload(x, "reduce_scatter")
        if spec is not None:
            algorithm, chunks, round_batch = \
                spec.algorithm, spec.chunks, spec.round_batch
        plan = _plan_reduce_scatter(mesh, axis, tuple(x.shape),
                                    getattr(x, "dtype", jnp.float32),
                                    algorithm, chunks, round_batch)
        return self._issue_plan(plan, x)

    def iallgather(self, x, mesh, axis: str, *, algorithm: str = "ring",
                   chunks: int = 1, round_batch: int | None = None,
                   spec: "CollectiveSpec | None" = None) -> CollectiveRequest:
        """Nonblocking all-gather (matches tiled ``all_gather`` on the
        last dim).  ``algorithm`` is ``ring`` or ``halving_doubling``
        (the doubling phase alone)."""
        self._check_open()
        _check_payload(x, "allgather")
        if spec is not None:
            algorithm, chunks, round_batch = \
                spec.algorithm, spec.chunks, spec.round_batch
        plan = _plan_allgather(mesh, axis, tuple(x.shape),
                               getattr(x, "dtype", jnp.float32),
                               algorithm, chunks, round_batch)
        return self._issue_plan(plan, x)

    def ialltoall(self, x, mesh, axis: str, *, chunks: int = 1,
                  round_batch: int | None = None,
                  spec: "CollectiveSpec | None" = None) -> CollectiveRequest:
        """Nonblocking Bruck all-to-all over the leading block dim
        (matches ``bruck_alltoall`` / native ``all_to_all``).  The
        global leading dim must be n·n blocks (n per device)."""
        self._check_open()
        _check_payload(x, "alltoall")
        if spec is not None:
            chunks, round_batch = spec.chunks, spec.round_batch
        plan = _plan_alltoall(mesh, axis, tuple(x.shape),
                              getattr(x, "dtype", jnp.float32),
                              chunks, round_batch)
        return self._issue_plan(plan, x)

    # -- persistent handles (MPI *_init / MPI_Start) -----------------------
    def allreduce_init(self, x, mesh, axis: str, *,
                       algorithm: str = "ring", chunks: int = 1,
                       round_batch: int | None = None,
                       spec: "CollectiveSpec | None" = None,
                       warmup: bool = True,
                       epoch: "MembershipEpoch | None" = None,
                       ) -> "PersistentCollective":
        """MPI_Allreduce_init: build a persistent schedule for payloads
        shaped like ``x`` (an array or ShapeDtypeStruct — only
        shape/dtype are read).  ``start(payload)`` re-issues the
        pre-compiled schedule; see :class:`PersistentCollective`.  Two
        handles with the same signature share round programs through the
        schedule cache, so a second init is cheap.  ``epoch`` (default:
        the context's) makes the handle membership-aware; ``spec`` (a
        :class:`CollectiveSpec`) overrides algorithm/chunks/round_batch
        and is the canonical form — the individual kwargs remain for
        compatibility."""
        self._check_open()
        _check_payload(x, "allreduce")
        if spec is not None:
            algorithm, chunks, round_batch = \
                spec.algorithm, spec.chunks, spec.round_batch
        shape = tuple(x.shape)
        dtype = getattr(x, "dtype", jnp.float32)
        replan = lambda m, a: _plan_allreduce(        # noqa: E731
            m, a, shape, dtype, algorithm, chunks, round_batch)
        return PersistentCollective(
            self, replan(mesh, axis), warmup=warmup,
            epoch=epoch if epoch is not None else self.epoch, replan=replan)

    def reduce_scatter_init(self, x, mesh, axis: str, *,
                            algorithm: str = "ring", chunks: int = 1,
                            round_batch: int | None = None,
                            spec: "CollectiveSpec | None" = None,
                            warmup: bool = True,
                            epoch: "MembershipEpoch | None" = None,
                            ) -> "PersistentCollective":
        self._check_open()
        _check_payload(x, "reduce_scatter")
        if spec is not None:
            algorithm, chunks, round_batch = \
                spec.algorithm, spec.chunks, spec.round_batch
        shape = tuple(x.shape)
        dtype = getattr(x, "dtype", jnp.float32)
        replan = lambda m, a: _plan_reduce_scatter(   # noqa: E731
            m, a, shape, dtype, algorithm, chunks, round_batch)
        return PersistentCollective(
            self, replan(mesh, axis), warmup=warmup,
            epoch=epoch if epoch is not None else self.epoch, replan=replan)

    def allgather_init(self, x, mesh, axis: str, *,
                       algorithm: str = "ring", chunks: int = 1,
                       round_batch: int | None = None,
                       spec: "CollectiveSpec | None" = None,
                       warmup: bool = True,
                       epoch: "MembershipEpoch | None" = None,
                       ) -> "PersistentCollective":
        self._check_open()
        _check_payload(x, "allgather")
        if spec is not None:
            algorithm, chunks, round_batch = \
                spec.algorithm, spec.chunks, spec.round_batch
        shape = tuple(x.shape)
        dtype = getattr(x, "dtype", jnp.float32)
        replan = lambda m, a: _plan_allgather(        # noqa: E731
            m, a, shape, dtype, algorithm, chunks, round_batch)
        return PersistentCollective(
            self, replan(mesh, axis), warmup=warmup,
            epoch=epoch if epoch is not None else self.epoch, replan=replan)

    def alltoall_init(self, x, mesh, axis: str, *, chunks: int = 1,
                      round_batch: int | None = None,
                      spec: "CollectiveSpec | None" = None,
                      warmup: bool = True,
                      epoch: "MembershipEpoch | None" = None,
                      ) -> "PersistentCollective":
        self._check_open()
        _check_payload(x, "alltoall")
        if spec is not None:
            chunks, round_batch = spec.chunks, spec.round_batch
        shape = tuple(x.shape)
        dtype = getattr(x, "dtype", jnp.float32)
        replan = lambda m, a: _plan_alltoall(         # noqa: E731
            m, a, shape, dtype, chunks, round_batch)
        return PersistentCollective(
            self, replan(mesh, axis), warmup=warmup,
            epoch=epoch if epoch is not None else self.epoch, replan=replan)

    # -- machinery ---------------------------------------------------------
    def _issue_plan(self, plan: _Plan, x) -> CollectiveRequest:
        scheds = [rs.compiled(plan.round_batch) for rs in plan.schedules]
        return self._issue(plan.op, plan.algorithm, scheds,
                           lambda: plan.split(x), plan.join)

    def _adopting_executor(self):
        """The running executor whose worker owns this context's stream,
        or None — the gate for executor-driven starts."""
        ex = self.executor if self.executor is not None \
            else self.engine.executor
        if ex is not None and ex.running and ex.owns(self.stream):
            return ex
        return None

    def _issue(self, op, algorithm, scheds, payloads, join, *,
               defer: bool = False) -> CollectiveRequest:
        """``payloads`` is the chunk list, or a thunk producing it — the
        deferred (executor-driven) path passes a thunk so the split too
        runs on the worker, not the start() caller."""
        payloads_fn = payloads if callable(payloads) else lambda: payloads
        req = CollectiveRequest(self.engine, self.stream, self.queue, op,
                                algorithm, len(scheds),
                                sum(s.num_rounds for s in scheds), ctx=self)
        self.issued += 1
        pipe = _ChunkPipeline(self, req, scheds, payloads_fn, join,
                              defer=defer)
        if defer:
            # one-shot issue task: the worker that owns the collective
            # stream splits + dispatches round 0 on its next sweep, so
            # the start() caller paid only this enqueue
            def issue_task(thing, pipe=pipe) -> str:
                pipe.launch()
                return DONE

            self.engine.async_start(issue_task, None, self.stream)
        return req

    def _check_open(self):
        if self._closed:
            raise RuntimeError(f"UserCollectives {self.name!r} is closed")

    # -- lifecycle ---------------------------------------------------------
    @property
    def in_flight(self) -> int:
        return self.issued - self.completed - self.failed - self.cancelled

    def close(self, *, drain: bool = True, timeout: float | None = 30.0) -> None:
        """Drain in-flight collectives, then release the stream/queue.
        With ``drain=False`` (the abandon path — e.g. unwinding an
        exception) pending continuations are cancelled and a still-busy
        stream is left registered on the engine rather than freed, so
        close never raises over the application's original error.
        Safe to call twice."""
        if self._closed:
            return
        self._closed = True          # block new issues during the drain
        if drain:
            import time
            t0 = time.monotonic()
            ex = self.executor
            while self.stream.pending or self.queue.ready:
                # parking is only correct when SOMEONE ELSE progresses the
                # stream: a close() running on the very worker that owns it
                # (a membership-rebuild continuation, say) would sleep
                # until the timeout waiting for itself — progress inline
                # instead (streams are serial contexts, progress is safe
                # from any thread)
                if ex is not None and ex.running and ex.owns(self.stream) \
                        and threading.get_ident() \
                        not in ex.worker_thread_idents():
                    time.sleep(50e-6)
                else:
                    self.engine.progress(self.stream)
                    self.queue.drain()
                if timeout is not None and time.monotonic() - t0 > timeout:
                    # reopen so a retry close() actually drains/releases
                    # instead of no-opping with the stream/queue leaked
                    self._closed = False
                    raise TimeoutError(
                        f"UserCollectives.close: {self.stream.pending} tasks "
                        f"/ {self.queue.ready} continuations still pending")
        if self._adopted_queue:
            self.executor.release_queue(self.queue)
        # abandon path: running ready continuations would dispatch further
        # rounds onto a stream nobody will progress — cancel them instead
        self.queue.close(run_ready=drain)
        if self._own_stream:
            if self.executor is not None and self.executor.owns(self.stream):
                self.executor.release(self.stream)
            if not self.stream.pending:
                self.engine.free_stream(self.stream)
            # else: abandoned in-flight tasks retire on future progress
            # sweeps (progress_all/finalize); the stream stays registered

    def __enter__(self) -> "UserCollectives":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close(drain=exc_type is None)

    def __repr__(self):
        return (f"UserCollectives({self.name!r}, issued={self.issued}, "
                f"completed={self.completed}, failed={self.failed}, "
                f"cancelled={self.cancelled})")


class PersistentCollective:
    """Persistent collective schedule: MPI ``*_init`` + ``MPI_Start``
    semantics on the progress engine (Schafer et al.'s user-level
    persistent schedules).

    Built once per (op, payload shape, dtype, algorithm, chunks,
    round-batch, mesh, axis): the plan — validation, chunk-split layout,
    join — is fixed, the fused round programs are instantiated, and with
    ``warmup=True`` compiled by one throwaway start on zeros, so the
    first *real* start never traces or compiles.  The warm-up also
    cycles each chunk's donated carry chain once, materializing the two
    buffer generations per chunk (donated input being read, output being
    written) that every subsequent start re-uses from XLA's pool — the
    pre-allocated double-buffered carries.

    Lifecycle: at most ONE outstanding start (MPI semantics — starting
    an active persistent request is erroneous and raises);
    ``cancel()`` cancels the active request; a handle whose last start
    failed or was cancelled is restartable with the next ``start``
    (fail-then-restart safe: abandoned round tasks retire on later
    progress sweeps and never touch the new start's chunks).

    Membership awareness: built against a :class:`MembershipEpoch`, the
    handle registers itself; ``epoch.invalidate`` fails the in-flight
    start with a retryable :class:`MembershipError` and marks the handle
    stale — further ``start``s raise until ``rebuild(mesh)`` re-plans
    the same op (shape, dtype, algorithm, chunks, round batch) against
    the surviving mesh, re-using PR-4's fail-then-restart machinery:
    abandoned round programs from the dead epoch retire harmlessly while
    the rebuilt schedule runs."""

    __slots__ = ("ctx", "plan", "round_batch", "schedules", "active",
                 "starts", "_closed", "epoch", "_epoch_version", "_replan",
                 "rebuilds", "__weakref__")

    def __init__(self, ctx: UserCollectives, plan: _Plan, *,
                 warmup: bool = True, epoch: "MembershipEpoch | None" = None,
                 replan: Callable[[Any, str], _Plan] | None = None):
        self.ctx = ctx
        self.plan = plan
        self.round_batch = plan.round_batch
        self.schedules = [rs.compiled(self.round_batch)
                          for rs in plan.schedules]
        self.active: CollectiveRequest | None = None
        self.starts = 0
        self.rebuilds = 0
        self._closed = False
        self.epoch = epoch
        self._replan = replan
        self._epoch_version = epoch.version if epoch is not None else 0
        if epoch is not None:
            epoch.register(self)
        debug.track_handle(self, "PersistentCollective")
        if warmup:
            self.start(jnp.zeros(plan.shape, plan.dtype)).wait(timeout=600)
            self.starts = 0          # the warm-up doesn't count

    # -- introspection -----------------------------------------------------
    @property
    def op(self) -> str:
        return self.plan.op

    @property
    def algorithm(self) -> str:
        return self.plan.algorithm

    @property
    def num_chunks(self) -> int:
        return len(self.schedules)

    @property
    def dispatches_per_start(self) -> int:
        """Jitted dispatches one start costs (rounds after fusion)."""
        return sum(s.num_rounds for s in self.schedules)

    # -- lifecycle ---------------------------------------------------------
    def start(self, payload) -> CollectiveRequest:
        """MPI_Start: re-bind ``payload`` to the persistent schedule and
        issue.  Raises while the previous start is still in flight (a
        failed or cancelled one is complete, hence restartable).

        When the collective stream is adopted by a running executor the
        start is *executor-driven*: this call only validates and
        enqueues a one-shot issue task (O(µs)), and the adopting worker
        splits the payload and dispatches round 0; otherwise round 0
        dispatches here, on the calling thread."""
        if self._closed:
            raise RuntimeError(f"{self!r} is closed")
        self.ctx._check_open()
        if self.epoch is not None and self._epoch_version != self.epoch.version:
            raise MembershipError(
                f"persistent {self.plan.op} handle is stale: built under "
                f"membership epoch {self._epoch_version}, current is "
                f"{self.epoch.version} ({self.epoch.n_devices} surviving "
                f"device(s)) — rebuild(mesh) before restarting",
                survivors=self.epoch.n_devices, version=self.epoch.version)
        active = self.active
        if active is not None and not active.is_complete:
            raise RuntimeError(
                f"persistent {self.plan.op} already has an active start "
                f"(MPI semantics: complete or cancel it before restarting)")
        if self.plan.shape is not None and hasattr(payload, "shape") \
                and tuple(payload.shape) != self.plan.shape:
            raise ValueError(
                f"persistent {self.plan.op} built for shape "
                f"{self.plan.shape}, got {tuple(payload.shape)}")
        if self.plan.dtype is not None and hasattr(payload, "dtype") \
                and jnp.dtype(payload.dtype) != jnp.dtype(self.plan.dtype):
            raise ValueError(
                f"persistent {self.plan.op} built for dtype "
                f"{jnp.dtype(self.plan.dtype)}, got "
                f"{jnp.dtype(payload.dtype)}")
        defer = self.ctx._adopting_executor() is not None
        req = self.ctx._issue(self.plan.op, self.plan.algorithm,
                              self.schedules,
                              lambda: self.plan.split(payload),
                              self.plan.join, defer=defer)
        self.active = req
        self.starts += 1
        # REPRO_DEBUG lifecycle mirror: runs after the guards above, so a
        # legal start always lands; complete_probe settles a retired
        # previous start, racing_invalidate tolerates the benign
        # version-check/invalidation window (the epoch still fails this
        # request through req._fail_lock)
        debug.handle_event(self, "start", kind="PersistentCollective",
                           complete_probe=lambda: True,
                           racing_invalidate=True)
        return req

    def cancel(self) -> None:
        """MPI_Cancel on the active start (no-op when idle/complete)."""
        if self.active is not None:
            self.active.cancel()

    # -- membership --------------------------------------------------------
    @property
    def stale(self) -> bool:
        return (self.epoch is not None
                and self._epoch_version != self.epoch.version)

    def _membership_changed(self, exc: "MembershipError") -> None:
        """Epoch invalidation: fail the in-flight start exactly once
        (same ``_fail_lock`` discipline as the chunk pipeline — whoever
        completes the request first wins; the loser observes
        ``is_complete`` and backs off).  Cheap by design: callable from
        a subsystem poll."""
        debug.handle_event(self, "invalidate", kind="PersistentCollective")
        req = self.active
        if req is None:
            return
        with req._fail_lock:
            if req.is_complete:
                return
            req.fail(exc)
        self.ctx.failed += 1

    def rebuild(self, mesh, axis: str | None = None, *,
                warmup: bool = False) -> "PersistentCollective":
        """Re-plan the same collective against ``mesh`` (the survivors)
        and adopt the current epoch generation.  The op, payload
        signature, algorithm, chunk count and round-batch policy carry
        over; schedules for the new axis size come from (or populate)
        the shared schedule cache.  Any incomplete start must be failed
        or cancelled first — epoch invalidation already did that for the
        membership-change path."""
        if self._closed:
            raise RuntimeError(f"{self!r} is closed")
        if self._replan is None:
            raise RuntimeError(
                f"persistent {self.plan.op} handle has no replan thunk "
                f"(constructed directly from a _Plan?) — build it via "
                f"UserCollectives.*_init to make it rebuildable")
        active = self.active
        if active is not None and not active.is_complete:
            raise RuntimeError(
                f"persistent {self.plan.op}: rebuild with a live start "
                f"in flight; cancel it (or let the epoch fail it) first")
        debug.handle_event(self, "rebuild", kind="PersistentCollective",
                           complete_probe=lambda: True)
        plan = self._replan(mesh, axis if axis is not None
                            else self.plan.axis)
        self.plan = plan
        self.round_batch = plan.round_batch
        self.schedules = [rs.compiled(plan.round_batch)
                          for rs in plan.schedules]
        self.active = None
        self.rebuilds += 1
        if self.epoch is not None:
            self._epoch_version = self.epoch.version
        if warmup:
            self.start(jnp.zeros(plan.shape, plan.dtype)).wait(timeout=600)
            self.starts -= 1         # the warm-up doesn't count
        return self

    def close(self) -> None:
        """Release the handle: further starts raise.  The underlying
        round programs stay in the shared schedule cache (other handles
        with the same signature keep using them)."""
        debug.handle_event(self, "close", kind="PersistentCollective")
        self._closed = True
        self.active = None

    def __repr__(self):
        return (f"PersistentCollective({self.plan.op}/"
                f"{self.plan.algorithm}, shape={self.plan.shape}, "
                f"chunks={self.num_chunks}, "
                f"round_batch={self.round_batch}, starts={self.starts})")


def _split_ranges(total: int, k: int):
    base, extra = divmod(total, k)
    ranges, off = [], 0
    for i in range(k):
        w = base + (1 if i < extra else 0)
        ranges.append(range(off, off + w))
        off += w
    return [r for r in ranges if len(r)]


def _contiguous_chunks(x, widths):
    parts, off = [], 0
    for w in widths:
        parts.append(_chunk_at(x, off, w))
        off += w
    return parts


@functools.partial(jax.jit, static_argnums=(1, 2))
def _chunk_at(x, off: int, width: int):
    return x[..., off:off + width]


# -- module-level convenience (one default context per engine) --------------

def default_collectives(engine: Optional[ProgressEngine] = None,
                        **kwargs) -> UserCollectives:
    eng = engine if engine is not None else global_engine()
    ctx = getattr(eng, "_user_collectives", None)
    if ctx is None or ctx._closed:
        ctx = UserCollectives(eng, **kwargs)
        eng._user_collectives = ctx
        return ctx
    # cache hit: refuse to hand back a context configured differently
    # from what the caller asked for (e.g. INLINE when DEFERRED+executor
    # was requested) — silent policy mismatches are undebuggable
    if (("policy" in kwargs and kwargs["policy"] != ctx.queue.policy)
            or ("executor" in kwargs
                and kwargs["executor"] is not ctx.executor)
            or ("stream" in kwargs and kwargs["stream"] is not ctx.stream)):
        raise ValueError(
            f"engine already has a default UserCollectives "
            f"({ctx.name!r}: policy={ctx.queue.policy}, "
            f"executor={ctx.executor}) configured differently; close it "
            f"first or construct a UserCollectives explicitly")
    return ctx


def _default_ctx(engine, stream):
    """Context for the module-level factories: the engine's default
    collectives, optionally pinned to an explicit ``stream`` (mismatches
    against an existing default context raise — see
    ``default_collectives``)."""
    if stream is not None:
        return default_collectives(engine, stream=stream)
    return default_collectives(engine)


# The canonical handle-factory shape (all four collectives, and the p2p
# channel factories in collectives/p2p.py, follow it):
#
#     <op>_init(like, mesh, axis_name, *, spec=None, epoch=None,
#               stream=None, engine=None, warmup=True)
#
# ``like`` carries the payload signature (array or ShapeDtypeStruct),
# ``spec`` a CollectiveSpec; the legacy algorithm/chunks/round_batch
# kwargs remain accepted for one release.

def iallreduce(x, mesh, axis: str, *, spec: CollectiveSpec | None = None,
               engine: Optional[ProgressEngine] = None,
               stream: Optional[Stream] = None,
               algorithm: str = "ring", chunks: int = 1,
               round_batch: int | None = None) -> CollectiveRequest:
    return _default_ctx(engine, stream).iallreduce(
        x, mesh, axis, algorithm=algorithm, chunks=chunks,
        round_batch=round_batch, spec=spec)


def ireduce_scatter(x, mesh, axis: str, *,
                    spec: CollectiveSpec | None = None,
                    engine: Optional[ProgressEngine] = None,
                    stream: Optional[Stream] = None,
                    algorithm: str = "ring", chunks: int = 1,
                    round_batch: int | None = None) -> CollectiveRequest:
    return _default_ctx(engine, stream).ireduce_scatter(
        x, mesh, axis, algorithm=algorithm, chunks=chunks,
        round_batch=round_batch, spec=spec)


def iallgather(x, mesh, axis: str, *, spec: CollectiveSpec | None = None,
               engine: Optional[ProgressEngine] = None,
               stream: Optional[Stream] = None,
               algorithm: str = "ring", chunks: int = 1,
               round_batch: int | None = None) -> CollectiveRequest:
    return _default_ctx(engine, stream).iallgather(
        x, mesh, axis, algorithm=algorithm, chunks=chunks,
        round_batch=round_batch, spec=spec)


def ialltoall(x, mesh, axis: str, *, spec: CollectiveSpec | None = None,
              engine: Optional[ProgressEngine] = None,
              stream: Optional[Stream] = None,
              chunks: int = 1,
              round_batch: int | None = None) -> CollectiveRequest:
    return _default_ctx(engine, stream).ialltoall(
        x, mesh, axis, chunks=chunks, round_batch=round_batch, spec=spec)


def allreduce_init(x, mesh, axis: str, *,
                   spec: CollectiveSpec | None = None,
                   epoch: "MembershipEpoch | None" = None,
                   stream: Optional[Stream] = None,
                   engine: Optional[ProgressEngine] = None,
                   algorithm: str = "ring", chunks: int = 1,
                   round_batch: int | None = None,
                   warmup: bool = True) -> PersistentCollective:
    return _default_ctx(engine, stream).allreduce_init(
        x, mesh, axis, algorithm=algorithm, chunks=chunks,
        round_batch=round_batch, spec=spec, warmup=warmup, epoch=epoch)


def reduce_scatter_init(x, mesh, axis: str, *,
                        spec: CollectiveSpec | None = None,
                        epoch: "MembershipEpoch | None" = None,
                        stream: Optional[Stream] = None,
                        engine: Optional[ProgressEngine] = None,
                        algorithm: str = "ring", chunks: int = 1,
                        round_batch: int | None = None,
                        warmup: bool = True) -> PersistentCollective:
    return _default_ctx(engine, stream).reduce_scatter_init(
        x, mesh, axis, algorithm=algorithm, chunks=chunks,
        round_batch=round_batch, spec=spec, warmup=warmup, epoch=epoch)


def allgather_init(x, mesh, axis: str, *,
                   spec: CollectiveSpec | None = None,
                   epoch: "MembershipEpoch | None" = None,
                   stream: Optional[Stream] = None,
                   engine: Optional[ProgressEngine] = None,
                   algorithm: str = "ring", chunks: int = 1,
                   round_batch: int | None = None,
                   warmup: bool = True) -> PersistentCollective:
    return _default_ctx(engine, stream).allgather_init(
        x, mesh, axis, algorithm=algorithm, chunks=chunks,
        round_batch=round_batch, spec=spec, warmup=warmup, epoch=epoch)


def alltoall_init(x, mesh, axis: str, *,
                  spec: CollectiveSpec | None = None,
                  epoch: "MembershipEpoch | None" = None,
                  stream: Optional[Stream] = None,
                  engine: Optional[ProgressEngine] = None,
                  chunks: int = 1,
                  round_batch: int | None = None,
                  warmup: bool = True) -> PersistentCollective:
    return _default_ctx(engine, stream).alltoall_init(
        x, mesh, axis, chunks=chunks,
        round_batch=round_batch, spec=spec, warmup=warmup, epoch=epoch)
