"""Nonblocking user-space collectives on the progress engine (paper §4.7).

The paper's third demonstration: collective algorithms built *in user
space* on the explicit progress engine rival native implementations.
``schedules.py`` holds the algorithms as monolithic ``shard_map``
programs — one XLA computation, invisible to the engine.  This module
compiles the same algorithms into **chunk-pipelined schedules** driven
by the engine, the way "Extending MPI with User-Level Schedules"
(Schafer et al.) builds persistent collective schedules and the MPI
Continuations work (Schuchart et al.) drives them to completion:

* the payload is split into K chunks;
* each algorithm is decomposed into per-round ``ppermute`` + combine
  steps, each its own jitted ``shard_map`` program;
* chunk c's round r+1 is chained off round r by a *continuation* on a
  ``jax_future`` (round r's output arrays), so rounds fire exactly when
  their inputs are device-ready — no wait loop, no blocking;
* all round tasks live on one dedicated collective ``Stream``, so a
  ``ProgressExecutor`` worker (or any ``engine.progress`` caller) can
  drive many in-flight collectives while the application computes.

``iallreduce`` / ``ireduce_scatter`` / ``iallgather`` / ``ialltoall``
return ``CollectiveRequest`` handles (``Request`` subclass): issue
returns immediately, completion is observed via ``is_complete`` /
``engine.wait`` like every other request in the system, and a failing
round fails the request instead of raising into the progress loop.

Chunking layouts keep outputs bit-identical to the native op:

* allreduce — elementwise, so chunks are contiguous last-dim slices
  (payload zero-padded to a multiple of n·K for the ring family);
* reduce-scatter — chunks interleave the per-rank blocks
  (``[..., n, K, m]`` view) so per-chunk block r slots reassemble into
  the native rank-r block;
* all-gather — the inverse interleave on the output side;
* all-to-all — acts on the leading block dim, so last-dim slices
  concatenate transparently.
"""
from __future__ import annotations

import functools
import itertools
import threading
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.collectives import schedules as S
from repro.core.continuations import DEFERRED, INLINE, ContinuationQueue
from repro.core.engine import ProgressEngine, Stream, global_engine
from repro.core.futures import jax_future
from repro.core.request import Request


# ---------------------------------------------------------------------------
# Shape helpers (module-level jitted: stable function objects => one
# compile per distinct shape, not per call)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(1,))
def _pad_last_to(x, target: int):
    pad = target - x.shape[-1]
    if pad == 0:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])


@functools.partial(jax.jit, static_argnums=(1,))
def _slice_last(x, width: int):
    if x.shape[-1] == width:
        return x
    return x[..., :width]


@functools.partial(jax.jit, static_argnums=(1, 2))
def _split_last(x, chunks: int, width: int):
    """Contiguous last-dim split into ``chunks`` pieces of ``width``."""
    return tuple(x[..., c * width:(c + 1) * width] for c in range(chunks))


@jax.jit
def _concat_last(parts):
    if len(parts) == 1:
        return parts[0]
    return jnp.concatenate(parts, axis=-1)


@functools.partial(jax.jit, static_argnums=(1, 2))
def _rs_split(x, n: int, chunks: int):
    """Interleaved reduce-scatter split: chunk c gets piece c of every
    rank block, so chunked RS outputs reassemble into the native rank
    block.  x: [..., D] with D divisible by n*chunks."""
    m = x.shape[-1] // (n * chunks)
    v = x.reshape(x.shape[:-1] + (n, chunks, m))
    return tuple(v[..., :, c, :].reshape(x.shape[:-1] + (n * m,))
                 for c in range(chunks))


@jax.jit
def _rs_join(parts):
    """Per-chunk RS outputs [..., m] -> native rank block [..., K*m]."""
    if len(parts) == 1:
        return parts[0]
    return jnp.stack(parts, axis=-2).reshape(
        parts[0].shape[:-1] + (len(parts) * parts[0].shape[-1],))


@functools.partial(jax.jit, static_argnums=(1,))
def _ag_join(parts, n: int):
    """Per-chunk AG outputs [..., n*m] -> native [..., n*d]: the rank-r
    segment of the full output is the concat of every chunk's rank-r
    segment."""
    if len(parts) == 1:
        return parts[0]
    blocks = [p.reshape(p.shape[:-1] + (n, p.shape[-1] // n)) for p in parts]
    stacked = jnp.stack(blocks, axis=-2)          # [..., n, K, m]
    return stacked.reshape(parts[0].shape[:-1]
                           + (n * len(parts) * blocks[0].shape[-1],))


# ---------------------------------------------------------------------------
# Round-decomposed schedules
# ---------------------------------------------------------------------------

def _take_block(chunks, pos):
    """chunks [..., n, m], traced pos -> [..., m] via dynamic_slice.

    Unlike the one-hot select in ``schedules._take_chunk`` this reads
    only the m-wide block — in a per-round program the one-hot form
    costs a full-payload pass *every round*, turning the ring's 2·W
    total traffic into (2n-1)·W."""
    start = [jnp.zeros((), jnp.int32)] * chunks.ndim
    start[-2] = pos
    sizes = chunks.shape[:-2] + (1,) + chunks.shape[-1:]
    return jax.lax.dynamic_slice(chunks, start, sizes).squeeze(-2)


def _put_block(out, cur, pos):
    """out [..., n, m] <- cur [..., m] at block pos (dynamic_update_slice:
    with the carry donated this is an in-place m-wide write)."""
    start = [jnp.zeros((), jnp.int32)] * out.ndim
    start[-2] = pos
    return jax.lax.dynamic_update_slice(out, cur[..., None, :], start)

class _Schedule:
    """One chunk's compiled pipeline: optional init, per-round step
    functions, optional finish — every entry a jitted shard_map program
    carrying a pytree of arrays sharded on the leading dim."""

    __slots__ = ("stages",)

    def __init__(self, init, rounds, finish):
        stages = []
        if init is not None:
            stages.append(init)
        stages.extend(rounds)
        if finish is not None:
            stages.append(finish)
        self.stages = tuple(stages)

    @property
    def num_rounds(self) -> int:
        return len(self.stages)


def _jit_smap(fn, mesh, axis, *, donate: bool = True):
    # donate the carry: stage inputs past the first are intermediate
    # buffers the pipeline owns (the previous round's outputs), so XLA
    # aliases the through-flowing arrays instead of copying the full
    # payload once per round.  The FIRST stage of a schedule never
    # donates: when padding/splitting is a no-op, jit may forward the
    # caller's buffer straight through, and donating it would delete the
    # user's input array.
    return jax.jit(compat.shard_map(fn, mesh=mesh, in_specs=P(axis),
                                    out_specs=P(axis)),
                   donate_argnums=(0,) if donate else ())


# cache: (kind, algorithm-ish key, mesh, axis, n, extras) -> _Schedule.
# jit itself caches per payload shape; this cache keeps the *function
# objects* stable so re-issuing a collective never re-traces.
_schedule_cache: dict = {}


def _cached(key, build):
    sched = _schedule_cache.get(key)
    if sched is None:
        sched = build()
        _schedule_cache[key] = sched
    return sched


def _identity_schedule(mesh, axis):
    return _cached(("identity", mesh, axis),
                   lambda: _Schedule(None, (), None))


def _recursive_doubling_schedule(mesh, axis, n):
    def build():
        rounds = []
        mask = 1
        while mask < n:
            perm = [(i, i ^ mask) for i in range(n)]

            def step(v, perm=perm):
                return v + jax.lax.ppermute(v, axis, perm)

            rounds.append(_jit_smap(step, mesh, axis, donate=mask > 1))
            mask <<= 1
        return _Schedule(None, tuple(rounds), None)

    return _cached(("rd", mesh, axis, n), build)


def _ring_rs_init(mesh, axis, n, d):
    """carry = (chunks [..., n, W/n], acc [..., W/n]) with acc = own
    starting chunk (rank r starts from chunk (r - d) mod n)."""
    def init(x):
        idx = S._axis_index(axis)
        w = x.shape[-1]
        chunks = jnp.reshape(x, x.shape[:-1] + (n, w // n))
        acc = _take_block(chunks, (idx - d) % n)
        return chunks, acc

    return _jit_smap(init, mesh, axis, donate=False)


def _ring_rs_round(mesh, axis, n, d, step):
    perm = [(i, (i + d) % n) for i in range(n)]

    def rnd(carry):
        chunks, acc = carry
        idx = S._axis_index(axis)
        acc = jax.lax.ppermute(acc, axis, perm)
        acc = acc + _take_block(chunks, (idx - d * (1 + step)) % n)
        return chunks, acc

    return _jit_smap(rnd, mesh, axis)


def _ring_ag_start(mesh, axis, n):
    """AG step 0: place the (fully reduced) resident chunk at slot idx."""
    def start(carry):
        _, acc = carry
        idx = S._axis_index(axis)
        out = jnp.zeros(acc.shape[:-1] + (n, acc.shape[-1]), acc.dtype)
        out = _put_block(out, acc, idx)
        return out, acc

    return _jit_smap(start, mesh, axis)


def _ring_ag_round(mesh, axis, n, d, step):
    perm = [(i, (i + d) % n) for i in range(n)]

    def rnd(carry):
        out, cur = carry
        idx = S._axis_index(axis)
        cur = jax.lax.ppermute(cur, axis, perm)
        pos = (idx - d * step) % n
        out = _put_block(out, cur, pos)
        return out, cur

    return _jit_smap(rnd, mesh, axis)


def _ring_finish(mesh, axis):
    def finish(carry):
        out, _ = carry
        return jnp.reshape(out, out.shape[:-2] + (out.shape[-2] * out.shape[-1],))

    return _jit_smap(finish, mesh, axis)


def _ring_allreduce_schedule(mesh, axis, n, reverse):
    """2n-1 rounds: n-1 reduce-scatter, 1 AG placement, n-1 all-gather."""
    def build():
        d = -1 if reverse else 1
        rounds = [_ring_rs_round(mesh, axis, n, d, s) for s in range(1, n)]
        rounds.append(_ring_ag_start(mesh, axis, n))
        rounds.extend(_ring_ag_round(mesh, axis, n, d, s) for s in range(1, n))
        return _Schedule(_ring_rs_init(mesh, axis, n, d), tuple(rounds),
                         _ring_finish(mesh, axis))

    return _cached(("ring", mesh, axis, n, reverse), build)


def _halving_doubling_schedule(mesh, axis, n):
    def build():
        rounds = []
        first = True
        mask = n >> 1
        while mask >= 1:                      # reduce-scatter by halving
            perm = [(i, i ^ mask) for i in range(n)]

            def halve(cur, perm=perm, mask=mask):
                idx = S._axis_index(axis)
                width = cur.shape[-1] // 2
                lo, hi = cur[..., :width], cur[..., width:]
                keep_hi = ((idx // mask) % 2) == 1
                send = jnp.where(keep_hi, lo, hi)
                recv = jax.lax.ppermute(send, axis, perm)
                mine = jnp.where(keep_hi, hi, lo)
                return mine + recv

            rounds.append(_jit_smap(halve, mesh, axis, donate=not first))
            first = False
            mask >>= 1
        mask = 1
        while mask < n:                       # all-gather by doubling
            perm = [(i, i ^ mask) for i in range(n)]

            def double(cur, perm=perm, mask=mask):
                idx = S._axis_index(axis)
                recv = jax.lax.ppermute(cur, axis, perm)
                keep_hi = ((idx // mask) % 2) == 1
                lo = jnp.where(keep_hi, recv, cur)
                hi = jnp.where(keep_hi, cur, recv)
                return jnp.concatenate([lo, hi], axis=-1)

            rounds.append(_jit_smap(double, mesh, axis))
            mask <<= 1
        return _Schedule(None, tuple(rounds), None)

    return _cached(("hd", mesh, axis, n), build)


def _ring_reduce_scatter_schedule(mesh, axis, n):
    def build():
        rounds = [_ring_rs_round(mesh, axis, n, 1, s) for s in range(1, n)]

        def finish(carry):
            return carry[1]

        return _Schedule(_ring_rs_init(mesh, axis, n, 1), tuple(rounds),
                         _jit_smap(finish, mesh, axis))

    return _cached(("rs", mesh, axis, n), build)


def _ring_all_gather_schedule(mesh, axis, n):
    def build():
        def init(x):
            idx = S._axis_index(axis)
            out = jnp.zeros(x.shape[:-1] + (n, x.shape[-1]), x.dtype)
            return _put_block(out, x, idx), x

        rounds = [_ring_ag_round(mesh, axis, n, 1, s) for s in range(1, n)]
        return _Schedule(_jit_smap(init, mesh, axis, donate=False),
                         tuple(rounds),
                         _ring_finish(mesh, axis))

    return _cached(("ag", mesh, axis, n), build)


def _bruck_alltoall_schedule(mesh, axis, n):
    def build():
        def init(x):
            idx = S._axis_index(axis)
            return jnp.take(x, (jnp.arange(n) + idx) % n, axis=0)

        rounds = []
        step = 1
        while step < n:
            perm = [(i, (i + step) % n) for i in range(n)]
            move = [(k // step) % 2 == 1 for k in range(n)]

            def rnd(x, perm=perm, move=tuple(move)):
                moved = jax.lax.ppermute(x, axis, perm)
                sel = jnp.asarray(move).reshape((n,) + (1,) * (x.ndim - 1))
                return jnp.where(sel, moved, x)

            rounds.append(_jit_smap(rnd, mesh, axis))
            step <<= 1

        def finish(x):
            idx = S._axis_index(axis)
            return jnp.take(x, (idx - jnp.arange(n)) % n, axis=0)

        return _Schedule(_jit_smap(init, mesh, axis, donate=False),
                         tuple(rounds), _jit_smap(finish, mesh, axis))

    return _cached(("bruck", mesh, axis, n), build)


# ---------------------------------------------------------------------------
# The request handle
# ---------------------------------------------------------------------------

class CollectiveRequest(Request):
    """Handle for an in-flight user-space collective.

    Carries the collective stream so ``wait()`` (and ``engine.wait``
    callers who pass ``req.stream``) progress the right serial context;
    ``rounds_done``/``rounds_total`` expose pipeline position for stats
    and tests."""

    __slots__ = ("engine", "stream", "queue", "op", "algorithm",
                 "num_chunks", "rounds_total", "rounds_done", "_fail_lock")

    def __init__(self, engine: ProgressEngine, stream: Stream, queue,
                 op: str, algorithm: str, num_chunks: int,
                 rounds_total: int):
        super().__init__(tag=f"i{op}")
        self.engine = engine
        self.stream = stream
        self.queue = queue
        self.op = op
        self.algorithm = algorithm
        self.num_chunks = num_chunks
        self.rounds_total = rounds_total
        self.rounds_done = 0
        self._fail_lock = threading.Lock()

    def wait(self, engine=None, stream=None, timeout: float | None = None):
        """MPI_Wait: drive the collective's stream until complete.

        A DEFERRED queue needs its ready list drained by an owner; when
        no executor worker does that, the waiter must — otherwise the
        round chain stalls forever with everything 'ready'."""
        eng = engine if engine is not None else self.engine
        s = stream if stream is not None else self.stream
        q = self.queue
        if q is not None and q.policy == DEFERRED:
            import time
            t0 = time.monotonic()
            while not self.is_complete:
                eng._advance(s)
                q.drain()
                if timeout is not None and time.monotonic() - t0 > timeout:
                    raise TimeoutError(f"wait timed out after {timeout}s")
            return self.value()
        return eng.wait(self, stream=s, timeout=timeout)

    def __repr__(self):
        return (f"CollectiveRequest({self.op}/{self.algorithm}, "
                f"chunks={self.num_chunks}, "
                f"rounds={self.rounds_done}/{self.rounds_total}, "
                f"complete={self.is_complete})")


# ---------------------------------------------------------------------------
# The chunk pipeline driver
# ---------------------------------------------------------------------------

class _ChunkPipeline:
    """Drives K chunks through their round schedules via continuations.

    Every stage dispatch happens inside a continuation callback (or at
    issue time for round 0): run stage r, register a ``jax_future`` for
    its outputs on the collective stream, attach the next continuation.
    A stage that raises — or a future that fails — fails the collective
    request exactly once; remaining chunks are abandoned (their pending
    futures complete harmlessly)."""

    def __init__(self, ctx: "UserCollectives", req: CollectiveRequest,
                 schedules, payloads, join: Callable[[list], Any]):
        self.ctx = ctx
        self.req = req
        self.schedules = schedules
        self.join = join
        self._lock = threading.Lock()
        self._results: list = [None] * len(payloads)
        self._remaining = len(payloads)
        for c, payload in enumerate(payloads):
            self._advance(c, 0, payload)

    def _advance(self, c: int, r: int, value) -> None:
        if self.req.is_complete:
            return                    # another chunk failed: abandon
        stages = self.schedules[c].stages
        if r >= len(stages):
            # degenerate schedule (n == 1): completion still flows
            # through one future so issue never completes synchronously
            fut = jax_future(self.ctx.engine, value, self.ctx.stream)
            self.ctx.queue.attach(
                fut, lambda rq, c=c: self._chunk_done(c, rq.value()),
                on_error=self._on_error)
            return
        try:
            out = stages[r](value)
        except BaseException as exc:  # noqa: BLE001
            self._fail(exc)
            return
        self.req.rounds_done += 1
        fut = jax_future(self.ctx.engine, out, self.ctx.stream)
        if r + 1 < len(stages):
            cb = lambda rq, c=c, r=r: self._advance(c, r + 1, rq.value())  # noqa: E731
        else:
            cb = lambda rq, c=c: self._chunk_done(c, rq.value())  # noqa: E731
        self.ctx.queue.attach(fut, cb, on_error=self._on_error)

    def _fail(self, exc: BaseException) -> None:
        """Fail the request exactly once; the failure counter moves with
        the request, not with every chunk that observes the failure."""
        with self.req._fail_lock:
            if self.req.is_complete:
                return
            self.req.fail(exc)
        self.ctx.failed += 1

    def _on_error(self, rq) -> None:
        self._fail(rq.exception or RuntimeError("collective round failed"))

    def _chunk_done(self, c: int, value) -> None:
        with self._lock:
            self._results[c] = value
            self._remaining -= 1
            done = self._remaining == 0
        if not done or self.req.is_complete:
            return
        try:
            result = self.join(self._results)
        except BaseException as exc:  # noqa: BLE001
            self._fail(exc)
            return
        with self.req._fail_lock:
            if not self.req.is_complete:
                self.req.complete(result)
        self.ctx.completed += 1


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def _axis_len(mesh, axis: str) -> int:
    return dict(mesh.shape)[axis]


def _largest_divisor_leq(total: int, k: int) -> int:
    k = max(1, min(k, total))
    while total % k:
        k -= 1
    return k


def _check_payload(x, op: str) -> None:
    """All four collectives shard the leading dim and chunk/schedule over
    the last — a 1-D payload would chunk the sharded dim itself and die
    deep inside a round program; reject it eagerly instead."""
    if len(x.shape) < 2:
        raise ValueError(
            f"i{op}: payload must be at least 2-D ([sharded_dim, ..., "
            f"payload_dim]), got shape {tuple(x.shape)}; reshape(-1, 1) "
            f"scalars-per-rank or add a trailing payload dim")


class UserCollectives:
    """Issue context for nonblocking user-space collectives.

    Owns one dedicated collective ``Stream`` (created on the engine, or
    adopted by a ``ProgressExecutor`` when given) and one
    ``ContinuationQueue`` that chains the per-round dispatches.  INLINE
    policy (default) runs the chaining on whichever thread progresses
    the stream — a background worker, or the waiting thread itself;
    DEFERRED routes it through the queue's ready list (adopt the queue
    on an executor so its workers drain it between polls).
    """

    _ids = itertools.count()

    def __init__(self, engine: Optional[ProgressEngine] = None, *,
                 executor=None, stream: Optional[Stream] = None,
                 policy: str = INLINE, name: str = ""):
        self.engine = engine if engine is not None else global_engine()
        self.executor = executor
        self.name = name or f"usercoll{next(UserCollectives._ids)}"
        self._own_stream = stream is None
        if stream is None:
            if executor is not None:
                stream = executor.stream(f"{self.name}-stream")
            else:
                stream = self.engine.stream(f"{self.name}-stream")
        self.stream = stream
        self.queue = ContinuationQueue(self.engine, self.stream,
                                       policy=policy, name=f"{self.name}-q")
        self._adopted_queue = False
        if executor is not None and policy == DEFERRED:
            executor.adopt_queue(self.queue)
            self._adopted_queue = True
        self.issued = 0
        self.completed = 0
        self.failed = 0
        self._closed = False

    # -- the collectives ---------------------------------------------------
    def iallreduce(self, x, mesh, axis: str, *, algorithm: str = "ring",
                   chunks: int = 1) -> CollectiveRequest:
        """Nonblocking allreduce of ``x`` (leading dim sharded on
        ``axis``), bit-identical to ``psum`` under the same shard_map
        layout.  ``algorithm`` is any ``schedules.ALGORITHMS`` key;
        power-of-two-only algorithms fall back to ring with a warning on
        other axis sizes (eager — nothing raises from inside jit)."""
        self._check_open()
        _check_payload(x, "allreduce")
        n = _axis_len(mesh, axis)
        algorithm = S.resolve_algorithm(algorithm, n)
        chunks = max(1, int(chunks))
        D = x.shape[-1]
        if n == 1:
            scheds = [_identity_schedule(mesh, axis)]
            payloads = [x]
            join = _concat_last
        elif algorithm == "recursive_doubling":
            # no divisibility constraint: contiguous near-equal slices
            widths = [len(r) for r in _split_ranges(D, min(chunks, D))]
            payloads = _contiguous_chunks(x, widths)
            scheds = [_recursive_doubling_schedule(mesh, axis, n)] * len(payloads)
            join = _concat_last
        else:
            # ring family (+ halving/doubling): pad to a multiple of n*K
            # so every chunk splits evenly into per-rank blocks
            per = -(-D // (n * chunks)) * n          # chunk width
            xp = _pad_last_to(x, per * chunks)
            payloads = list(_split_last(xp, chunks, per))
            if algorithm == "bidir":
                # both ICI directions at once: alternate ring direction
                # per chunk (chunks=1 degenerates to a forward ring)
                scheds = [_ring_allreduce_schedule(mesh, axis, n, bool(c % 2))
                          for c in range(chunks)]
            elif algorithm == "halving_doubling":
                scheds = [_halving_doubling_schedule(mesh, axis, n)] * chunks
            else:
                scheds = [_ring_allreduce_schedule(mesh, axis, n, False)] * chunks
            join = lambda parts: _slice_last(_concat_last(tuple(parts)), D)  # noqa: E731
        return self._issue("allreduce", algorithm, scheds, payloads, join)

    def ireduce_scatter(self, x, mesh, axis: str, *,
                        chunks: int = 1) -> CollectiveRequest:
        """Nonblocking ring reduce-scatter (matches tiled
        ``psum_scatter`` on the last dim).  Requires the last dim
        divisible by the axis size (validated eagerly)."""
        self._check_open()
        _check_payload(x, "reduce_scatter")
        n = _axis_len(mesh, axis)
        D = x.shape[-1]
        if D % n:
            raise ValueError(
                f"ireduce_scatter: last dim {D} not divisible by "
                f"axis size {n}")
        if n == 1:
            return self._issue("reduce_scatter", "ring",
                               [_identity_schedule(mesh, axis)], [x],
                               _concat_last)
        k = _largest_divisor_leq(D // n, max(1, int(chunks)))
        payloads = list(_rs_split(x, n, k))
        scheds = [_ring_reduce_scatter_schedule(mesh, axis, n)] * k
        return self._issue("reduce_scatter", "ring", scheds, payloads,
                           lambda parts: _rs_join(tuple(parts)))

    def iallgather(self, x, mesh, axis: str, *,
                   chunks: int = 1) -> CollectiveRequest:
        """Nonblocking ring all-gather (matches tiled ``all_gather`` on
        the last dim)."""
        self._check_open()
        _check_payload(x, "allgather")
        n = _axis_len(mesh, axis)
        if n == 1:
            return self._issue("allgather", "ring",
                               [_identity_schedule(mesh, axis)], [x],
                               _concat_last)
        d = x.shape[-1]
        k = _largest_divisor_leq(d, max(1, int(chunks)))
        payloads = list(_split_last(x, k, d // k))
        scheds = [_ring_all_gather_schedule(mesh, axis, n)] * k
        return self._issue("allgather", "ring", scheds, payloads,
                           lambda parts: _ag_join(tuple(parts), n))

    def ialltoall(self, x, mesh, axis: str, *,
                  chunks: int = 1) -> CollectiveRequest:
        """Nonblocking Bruck all-to-all over the leading block dim
        (matches ``bruck_alltoall`` / native ``all_to_all``).  The
        global leading dim must be n·n blocks (n per device)."""
        self._check_open()
        _check_payload(x, "alltoall")
        n = _axis_len(mesh, axis)
        lead = x.shape[0]
        if lead % n:
            raise ValueError(
                f"ialltoall: leading dim {lead} not divisible by "
                f"axis size {n}")
        if n == 1:
            return self._issue("alltoall", "bruck",
                               [_identity_schedule(mesh, axis)], [x],
                               _concat_last)
        D = x.shape[-1]
        widths = [len(r) for r in _split_ranges(D, min(max(1, int(chunks)), D))]
        payloads = _contiguous_chunks(x, widths)
        scheds = [_bruck_alltoall_schedule(mesh, axis, n)] * len(payloads)
        return self._issue("alltoall", "bruck", scheds, payloads, _concat_last)

    # -- machinery ---------------------------------------------------------
    def _issue(self, op, algorithm, scheds, payloads, join) -> CollectiveRequest:
        req = CollectiveRequest(self.engine, self.stream, self.queue, op,
                                algorithm, len(payloads),
                                sum(s.num_rounds for s in scheds))
        self.issued += 1
        _ChunkPipeline(self, req, scheds, payloads, join)
        return req

    def _check_open(self):
        if self._closed:
            raise RuntimeError(f"UserCollectives {self.name!r} is closed")

    # -- lifecycle ---------------------------------------------------------
    @property
    def in_flight(self) -> int:
        return self.issued - self.completed - self.failed

    def close(self, *, drain: bool = True, timeout: float | None = 30.0) -> None:
        """Drain in-flight collectives, then release the stream/queue.
        With ``drain=False`` (the abandon path — e.g. unwinding an
        exception) pending continuations are cancelled and a still-busy
        stream is left registered on the engine rather than freed, so
        close never raises over the application's original error.
        Safe to call twice."""
        if self._closed:
            return
        self._closed = True          # block new issues during the drain
        if drain:
            import time
            t0 = time.monotonic()
            ex = self.executor
            while self.stream.pending or self.queue.ready:
                if ex is not None and ex.running and ex.owns(self.stream):
                    time.sleep(50e-6)
                else:
                    self.engine.progress(self.stream)
                    self.queue.drain()
                if timeout is not None and time.monotonic() - t0 > timeout:
                    # reopen so a retry close() actually drains/releases
                    # instead of no-opping with the stream/queue leaked
                    self._closed = False
                    raise TimeoutError(
                        f"UserCollectives.close: {self.stream.pending} tasks "
                        f"/ {self.queue.ready} continuations still pending")
        if self._adopted_queue:
            self.executor.release_queue(self.queue)
        # abandon path: running ready continuations would dispatch further
        # rounds onto a stream nobody will progress — cancel them instead
        self.queue.close(run_ready=drain)
        if self._own_stream:
            if self.executor is not None and self.executor.owns(self.stream):
                self.executor.release(self.stream)
            if not self.stream.pending:
                self.engine.free_stream(self.stream)
            # else: abandoned in-flight tasks retire on future progress
            # sweeps (progress_all/finalize); the stream stays registered

    def __enter__(self) -> "UserCollectives":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close(drain=exc_type is None)

    def __repr__(self):
        return (f"UserCollectives({self.name!r}, issued={self.issued}, "
                f"completed={self.completed}, failed={self.failed})")


def _split_ranges(total: int, k: int):
    base, extra = divmod(total, k)
    ranges, off = [], 0
    for i in range(k):
        w = base + (1 if i < extra else 0)
        ranges.append(range(off, off + w))
        off += w
    return [r for r in ranges if len(r)]


def _contiguous_chunks(x, widths):
    parts, off = [], 0
    for w in widths:
        parts.append(_chunk_at(x, off, w))
        off += w
    return parts


@functools.partial(jax.jit, static_argnums=(1, 2))
def _chunk_at(x, off: int, width: int):
    return x[..., off:off + width]


# -- module-level convenience (one default context per engine) --------------

def default_collectives(engine: Optional[ProgressEngine] = None,
                        **kwargs) -> UserCollectives:
    eng = engine if engine is not None else global_engine()
    ctx = getattr(eng, "_user_collectives", None)
    if ctx is None or ctx._closed:
        ctx = UserCollectives(eng, **kwargs)
        eng._user_collectives = ctx
        return ctx
    # cache hit: refuse to hand back a context configured differently
    # from what the caller asked for (e.g. INLINE when DEFERRED+executor
    # was requested) — silent policy mismatches are undebuggable
    if (("policy" in kwargs and kwargs["policy"] != ctx.queue.policy)
            or ("executor" in kwargs
                and kwargs["executor"] is not ctx.executor)
            or ("stream" in kwargs and kwargs["stream"] is not ctx.stream)):
        raise ValueError(
            f"engine already has a default UserCollectives "
            f"({ctx.name!r}: policy={ctx.queue.policy}, "
            f"executor={ctx.executor}) configured differently; close it "
            f"first or construct a UserCollectives explicitly")
    return ctx


def iallreduce(x, mesh, axis: str, *, engine: Optional[ProgressEngine] = None,
               algorithm: str = "ring", chunks: int = 1) -> CollectiveRequest:
    return default_collectives(engine).iallreduce(
        x, mesh, axis, algorithm=algorithm, chunks=chunks)


def ireduce_scatter(x, mesh, axis: str, *,
                    engine: Optional[ProgressEngine] = None,
                    chunks: int = 1) -> CollectiveRequest:
    return default_collectives(engine).ireduce_scatter(x, mesh, axis,
                                                       chunks=chunks)


def iallgather(x, mesh, axis: str, *,
               engine: Optional[ProgressEngine] = None,
               chunks: int = 1) -> CollectiveRequest:
    return default_collectives(engine).iallgather(x, mesh, axis, chunks=chunks)


def ialltoall(x, mesh, axis: str, *,
              engine: Optional[ProgressEngine] = None,
              chunks: int = 1) -> CollectiveRequest:
    return default_collectives(engine).ialltoall(x, mesh, axis, chunks=chunks)
