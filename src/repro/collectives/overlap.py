"""Computation/communication overlap (paper §2.3–§2.4) on TPU.

The paper's point: a multi-wait-block operation only overlaps if
*progress* runs between its stages.  In an SPMD program the scheduler is
the XLA compiler — overlap is obtained **structurally**, by writing the
program so communication of piece i-1 is dataflow-independent of the
compute of piece i:

* ``microbatched_grad_step`` — gradient accumulation where the bucketed
  allreduce of microbatch i-1's grads has no dependency on microbatch
  i's backward pass, so XLA's latency-hiding scheduler can run the
  collective behind the compute (DDP-style bucket overlap).
* ``collective_matmul_ag`` — all-gather→matmul rewritten as a rolled
  ppermute loop: every step multiplies the chunk it already has while
  ppermute ships the next one (Wang et al.'s collective-matmul; the
  device-side analogue of "progress runs while you compute").
* ``collective_matmul_rs`` — matmul→reduce-scatter, same idea backwards.
"""
from __future__ import annotations

import functools
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.collectives import schedules as S


# ---------------------------------------------------------------------------
# Bucketed, overlapped gradient reduction
# ---------------------------------------------------------------------------

def bucket_tree(tree, bucket_bytes: int = 1 << 25):
    """Partition tree leaves into buckets of ~bucket_bytes (DDP-style).

    Returns list of lists of leaf indices (ordered as tree_leaves).
    Buckets are **per-dtype**: the bucketed reduction concatenates a
    bucket's leaves into one payload, and a mixed-dtype concat would
    promote (bf16 leaves reduced — and shipped — as f32, results
    diverging from the per-leaf native reduction).  Non-array leaves
    (no ``size``/``dtype``) are rejected eagerly: they cannot be
    byte-counted or concatenated, and counting them as 0 used to let
    them accumulate into one unbounded bucket.
    """
    leaves = jax.tree.leaves(tree)
    buckets = []
    open_buckets: dict = {}          # dtype -> [indices, byte count]
    order: list = []                 # dtypes in first-seen order
    for i, leaf in enumerate(leaves):
        if not hasattr(leaf, "size") or not hasattr(leaf, "dtype"):
            raise TypeError(
                f"bucket_tree: leaf {i} is {type(leaf).__name__}, not an "
                f"array; bucketed reduction needs array leaves (wrap "
                f"scalars in jnp.asarray)")
        dt = jnp.dtype(leaf.dtype)
        if dt not in open_buckets:
            open_buckets[dt] = [[], 0]
            order.append(dt)
        cur = open_buckets[dt]
        cur[0].append(i)
        cur[1] += leaf.size * dt.itemsize
        if cur[1] >= bucket_bytes:
            buckets.append(cur[0])
            open_buckets[dt] = [[], 0]
    for dt in order:
        if open_buckets[dt][0]:
            buckets.append(open_buckets[dt][0])
    return buckets


def allreduce_tree(grads, axis: str, algorithm: str = "psum",
                   bucket_bytes: int = 1 << 25):
    """Reduce a gradient pytree across `axis` inside shard_map.

    algorithm "psum" uses the native op; others use the user-level
    schedules from :mod:`schedules` — the Fig-13 comparison at scale.
    Buckets exist to give the scheduler independent collectives it can
    overlap with backward compute; they are single-dtype (see
    :func:`bucket_tree`), so each bucket reduces in its leaves' native
    dtype — bit-comparable to the per-leaf native op, and bf16 buckets
    ship bf16 bytes instead of silently upcasting the wire format.
    """
    leaves, treedef = jax.tree.flatten(grads)
    if algorithm == "psum":
        red = [jax.lax.psum(g, axis) for g in leaves]
        return jax.tree.unflatten(treedef, red)
    fn = S.ALGORITHMS[algorithm]
    buckets = bucket_tree(grads, bucket_bytes)
    red = [None] * len(leaves)
    for bucket in buckets:
        flat = jnp.concatenate([leaves[i].reshape(-1) for i in bucket])
        flat = fn(flat, axis)
        off = 0
        for i in bucket:
            n = leaves[i].size
            red[i] = flat[off:off + n].reshape(leaves[i].shape)
            off += n
    return jax.tree.unflatten(treedef, red)


def microbatched_grad_fn(loss_fn: Callable, num_microbatches: int,
                         axis: str | None = None,
                         algorithm: str = "psum",
                         bucket_bytes: int = 1 << 25):
    """Build grad_fn(params, batch) -> (loss, grads) that splits the batch
    into microbatches, accumulates grads with lax.scan, and reduces across
    `axis` (if inside shard_map).  The scan makes microbatch i's backward
    independent of microbatch i-1's reduction — overlap-friendly."""

    def grad_fn(params, batch):
        def split(x):
            B = x.shape[0]
            assert B % num_microbatches == 0, (B, num_microbatches)
            return x.reshape((num_microbatches, B // num_microbatches) + x.shape[1:])

        mbatches = jax.tree.map(split, batch)
        vg = jax.value_and_grad(loss_fn, has_aux=True)

        def body(acc, mb):
            (loss, aux), g = vg(params, mb)
            acc_loss, acc_g = acc
            acc_g = jax.tree.map(jnp.add, acc_g, g)
            return (acc_loss + loss, acc_g), None

        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zero_g), mbatches)
        inv = 1.0 / num_microbatches
        loss = loss * inv
        grads = jax.tree.map(lambda g: g * inv, grads)
        if axis is not None:
            grads = allreduce_tree(grads, axis, algorithm, bucket_bytes)
            loss = jax.lax.pmean(loss, axis)
        return loss, grads

    return grad_fn


# ---------------------------------------------------------------------------
# Engine-driven bucketed gradient reduction (paper §4.7 at the host level)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(1,))
def _flatten_bucket(leaves, n: int):
    """Stacked per-device leaves [n, *shape] -> one [n, bucket] payload."""
    return jnp.concatenate([g.reshape(n, -1) for g in leaves], axis=-1)


@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def _unflatten_bucket(flat, shapes: tuple, scale: float, n: int):
    """Reduced [n, bucket] payload (every row = the cross-device sum)
    back into reduced leaves [*shape] (row 0, optionally scaled)."""
    out, off = [], 0
    for shape in shapes:
        size = 1
        for s in shape:
            size *= s
        leaf = flat[0, off:off + size].reshape(shape)
        out.append(leaf * scale if scale != 1.0 else leaf)
        off += size
    return out


class TreeReduction:
    """Handle for an in-flight engine-driven gradient reduction: one
    nonblocking collective request per bucket plus the reassembly plan."""

    def __init__(self, reducer: "EngineGradReducer", requests, buckets,
                 shapes, dtypes, treedef, num_leaves: int):
        self.reducer = reducer
        self.requests = requests
        self._buckets = buckets
        self._shapes = shapes
        self._dtypes = dtypes
        self._treedef = treedef
        self._num_leaves = num_leaves

    @property
    def is_complete(self) -> bool:
        return all(r.is_complete for r in self.requests)

    def wait(self, timeout: float | None = None):
        """Drive the engine until every bucket reduced; returns the
        reduced gradient pytree (leaves deduplicated back to one copy).
        Waits per-request (``CollectiveRequest.wait``) so the waiter can
        park on in-flight round programs instead of busy-polling; order
        doesn't matter — every bucket must finish.  ``timeout`` is one
        overall deadline across the whole set, not per bucket."""
        import time
        deadline = None if timeout is None else time.monotonic() + timeout
        for req in self.requests:
            remaining = None if deadline is None else \
                max(0.0, deadline - time.monotonic())
            req.wait(timeout=remaining)
        n = self.reducer.axis_size
        scale = (1.0 / n) if self.reducer.mean else 1.0
        red = [None] * self._num_leaves
        for req, bucket in zip(self.requests, self._buckets):
            shapes = tuple(self._shapes[i] for i in bucket)
            leaves = _unflatten_bucket(req.value(), shapes, scale, n)
            for i, leaf in zip(bucket, leaves):
                red[i] = leaf.astype(self._dtypes[i])
        return jax.tree.unflatten(self._treedef, red)


class EngineGradReducer:
    """DDP-style bucketed gradient allreduce driven by the progress
    engine (the 'engine mode' of :func:`allreduce_tree`).

    Input gradients are *stacked per-device* trees — each leaf
    ``[axis_size, *shape]`` sharded on the leading dim (the output of a
    ``shard_map``-local grad step: device i's local gradient in row i).
    ``iallreduce_tree`` flattens leaves into ~``bucket_bytes`` buckets
    and issues one chunk-pipelined nonblocking :func:`iallreduce` per
    bucket, so the reductions progress on the collective stream while
    the caller keeps computing (backward of the next microbatch, the
    optimizer of the previous step, prefetch fills...).  ``mean=True``
    scales by 1/axis_size on reassembly — the data-parallel gradient
    mean.

    Buckets reduce through **persistent schedules**: the first
    ``iallreduce_tree`` builds one :class:`~repro.collectives.
    nonblocking.PersistentCollective` per (bucket ordinal, shape, dtype)
    and every later step re-``start``s the cached handle — plan,
    validation, round programs and donated carries are all reused, so a
    training step pays only split+dispatch per bucket (MPI
    ``Allreduce_init``/``Start`` across the step loop).  ``round_batch``
    (None = auto from bucket size) fuses consecutive schedule rounds
    per dispatch."""

    def __init__(self, mesh, axis: str, *, engine=None, collectives=None,
                 algorithm: str = "ring", chunks: int = 4,
                 bucket_bytes: int = 1 << 25, mean: bool = True,
                 executor=None, round_batch: int | None = None,
                 epoch=None):
        from repro.collectives import nonblocking as NB
        self.mesh = mesh
        self.axis = axis
        self.axis_size = dict(mesh.shape)[axis]
        self._algorithm_pref = algorithm
        self.algorithm = S.resolve_algorithm(algorithm, self.axis_size)
        self.chunks = chunks
        self.bucket_bytes = bucket_bytes
        self.mean = mean
        self.round_batch = round_batch
        self.epoch = epoch
        self.remeshes = 0
        self._own_coll = collectives is None
        self.coll = collectives if collectives is not None else \
            NB.UserCollectives(engine, executor=executor, name="gradreduce",
                               epoch=epoch)
        # (bucket ordinal, payload shape, dtype) -> PersistentCollective.
        # Keyed per ordinal: two same-shaped buckets in one step need two
        # handles (a persistent handle allows one outstanding start).
        self._persistent: dict = {}

    def _handle(self, ordinal: int, flat):
        key = (ordinal, tuple(flat.shape), str(flat.dtype))
        handle = self._persistent.get(key)
        if handle is None:
            # warmup=False: the first start compiles (same cost the old
            # one-shot path paid); later starts hit the warm programs
            handle = self.coll.allreduce_init(
                flat, self.mesh, self.axis, algorithm=self.algorithm,
                chunks=self.chunks, round_batch=self.round_batch,
                warmup=False, epoch=self.epoch)
            self._persistent[key] = handle
        return handle

    def remesh(self, mesh, axis: str | None = None) -> "EngineGradReducer":
        """Adopt the survivors' mesh after a membership change.

        The stacked-gradient payload shape carries the axis size in its
        leading dim, so the old persistent handles can't be re-planned
        in place — they are closed and fresh ones (new shape, new mesh,
        algorithm re-resolved for the surviving axis size) build lazily
        on the next ``iallreduce_tree``, which therefore resumes the
        reduction on survivors within the same training step."""
        for handle in self._persistent.values():
            handle.close()
        self._persistent.clear()
        self.mesh = mesh
        if axis is not None:
            self.axis = axis
        self.axis_size = dict(mesh.shape)[self.axis]
        self.algorithm = S.resolve_algorithm(self._algorithm_pref,
                                             self.axis_size)
        self.remeshes += 1
        return self

    def iallreduce_tree(self, stacked_grads) -> TreeReduction:
        """Issue the bucketed reduction; returns immediately."""
        leaves, treedef = jax.tree.flatten(stacked_grads)
        n = self.axis_size
        shapes = [tuple(g.shape[1:]) for g in leaves]
        dtypes = [g.dtype for g in leaves]
        # single-dtype buckets: _flatten_bucket concatenates, and a
        # mixed bucket would promote (reduce bf16 as f32).  Same rule —
        # and same one-open-bucket-per-dtype grouping — as bucket_tree,
        # so interleaved-dtype trees (bf16 weights between f32 norm
        # scales) still coalesce instead of fragmenting per leaf.
        buckets = []
        open_buckets: dict = {}      # dtype -> [indices, per-device bytes]
        order: list = []
        for i, g in enumerate(leaves):
            dt = jnp.dtype(g.dtype)
            if dt not in open_buckets:
                open_buckets[dt] = [[], 0]
                order.append(dt)
            cur = open_buckets[dt]
            cur[0].append(i)
            cur[1] += (g.size // max(1, g.shape[0])) * dt.itemsize
            if cur[1] >= self.bucket_bytes:
                buckets.append(cur[0])
                open_buckets[dt] = [[], 0]
        for dt in order:
            if open_buckets[dt][0]:
                buckets.append(open_buckets[dt][0])
        requests = []
        for bi, bucket in enumerate(buckets):
            flat = _flatten_bucket(tuple(leaves[i] for i in bucket), n)
            handle = self._handle(bi, flat)
            if handle.active is not None and not handle.active.is_complete:
                # overlapping tree reductions (caller didn't wait the
                # previous one): fall back to a one-shot issue rather
                # than violating the handle's single-start invariant
                requests.append(self.coll.iallreduce(
                    flat, self.mesh, self.axis, algorithm=self.algorithm,
                    chunks=self.chunks, round_batch=self.round_batch))
            else:
                requests.append(handle.start(flat))
        return TreeReduction(self, requests, buckets, shapes, dtypes,
                             treedef, len(leaves))

    def allreduce_tree(self, stacked_grads, timeout: float | None = None):
        """Blocking convenience: issue + engine-driven wait."""
        return self.iallreduce_tree(stacked_grads).wait(timeout=timeout)

    def close(self) -> None:
        for handle in self._persistent.values():
            handle.close()
        self._persistent.clear()
        if self._own_coll:
            self.coll.close()


# ---------------------------------------------------------------------------
# Collective matmul (all-gather / reduce-scatter fused into the GEMM loop)
# ---------------------------------------------------------------------------

def collective_matmul_ag(x: jax.Array, w: jax.Array, axis: str) -> jax.Array:
    """y = all_gather(x, axis) @ w — without materializing the gather.

    x: [m_local, K]; w: [K, n_local].  Each of the P steps multiplies the
    resident chunk while ppermute ships the next — compute hides the
    collective (the paper's overlap goal, expressed structurally).
    Returns [P*m_local, n_local].
    """
    n = S._axis_size(axis)
    idx = S._axis_index(axis)
    if n == 1:
        return x @ w
    m = x.shape[0]
    out = jnp.zeros((n, m, w.shape[-1]), x.dtype)
    perm = [(i, (i + 1) % n) for i in range(n)]
    cur, pos = x, idx
    for step in range(n):
        part = cur @ w                               # compute resident chunk
        oh = jax.nn.one_hot(pos, n, dtype=part.dtype)
        out = out + oh[:, None, None] * part[None]
        if step != n - 1:
            cur = jax.lax.ppermute(cur, axis, perm)  # ship next chunk
            pos = (pos - 1) % n
    return out.reshape(n * m, w.shape[-1])


def collective_matmul_rs(x: jax.Array, w: jax.Array, axis: str) -> jax.Array:
    """y = reduce_scatter(x @ all-partitioned w) — matmul chunks feed the
    ring as they finish.  x: [M, k_local]; w: [k_local, N] with the
    contraction sharded; output rows scattered: [M/P rows... ] —
    formulated here as: compute x @ w (partial sums), ring-reduce-scatter
    over rows so rank r keeps rows r·(M/P):(r+1)·(M/P) fully reduced."""
    n = S._axis_size(axis)
    if n == 1:
        return x @ w
    partial_y = x @ w                                 # [M, N] partial sums
    M = partial_y.shape[0]
    assert M % n == 0
    # reduce-scatter over leading dim: reuse last-dim helper via transpose
    yt = jnp.moveaxis(partial_y, 0, -1)               # [N, M]
    red = S.ring_reduce_scatter(yt, axis)             # [N, M/P]
    return jnp.moveaxis(red, -1, 0)                   # [M/P, N]


def ag_matmul_reference(x, w, axis):
    return jax.lax.all_gather(x, axis, tiled=True) @ w
