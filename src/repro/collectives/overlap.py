"""Computation/communication overlap (paper §2.3–§2.4) on TPU.

The paper's point: a multi-wait-block operation only overlaps if
*progress* runs between its stages.  In an SPMD program the scheduler is
the XLA compiler — overlap is obtained **structurally**, by writing the
program so communication of piece i-1 is dataflow-independent of the
compute of piece i:

* ``microbatched_grad_step`` — gradient accumulation where the bucketed
  allreduce of microbatch i-1's grads has no dependency on microbatch
  i's backward pass, so XLA's latency-hiding scheduler can run the
  collective behind the compute (DDP-style bucket overlap).
* ``collective_matmul_ag`` — all-gather→matmul rewritten as a rolled
  ppermute loop: every step multiplies the chunk it already has while
  ppermute ships the next one (Wang et al.'s collective-matmul; the
  device-side analogue of "progress runs while you compute").
* ``collective_matmul_rs`` — matmul→reduce-scatter, same idea backwards.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.collectives import schedules as S


# ---------------------------------------------------------------------------
# Bucketed, overlapped gradient reduction
# ---------------------------------------------------------------------------

def bucket_tree(tree, bucket_bytes: int = 1 << 25):
    """Partition tree leaves into buckets of ~bucket_bytes (DDP-style).

    Returns list of lists of leaf indices (ordered as tree_leaves).
    """
    leaves = jax.tree.leaves(tree)
    buckets, cur, cur_bytes = [], [], 0
    for i, leaf in enumerate(leaves):
        nb = leaf.size * leaf.dtype.itemsize if hasattr(leaf, "size") else 0
        cur.append(i)
        cur_bytes += nb
        if cur_bytes >= bucket_bytes:
            buckets.append(cur)
            cur, cur_bytes = [], 0
    if cur:
        buckets.append(cur)
    return buckets


def allreduce_tree(grads, axis: str, algorithm: str = "psum",
                   bucket_bytes: int = 1 << 25):
    """Reduce a gradient pytree across `axis` inside shard_map.

    algorithm "psum" uses the native op; others use the user-level
    schedules from :mod:`schedules` — the Fig-13 comparison at scale.
    Buckets exist to give the scheduler independent collectives it can
    overlap with backward compute.
    """
    leaves, treedef = jax.tree.flatten(grads)
    if algorithm == "psum":
        red = [jax.lax.psum(g, axis) for g in leaves]
        return jax.tree.unflatten(treedef, red)
    fn = S.ALGORITHMS[algorithm]
    buckets = bucket_tree(grads, bucket_bytes)
    red = [None] * len(leaves)
    for bucket in buckets:
        flat = jnp.concatenate([leaves[i].reshape(-1) for i in bucket])
        flat = fn(flat, axis)
        off = 0
        for i in bucket:
            n = leaves[i].size
            red[i] = flat[off:off + n].reshape(leaves[i].shape).astype(leaves[i].dtype)
            off += n
    return jax.tree.unflatten(treedef, red)


def microbatched_grad_fn(loss_fn: Callable, num_microbatches: int,
                         axis: str | None = None,
                         algorithm: str = "psum",
                         bucket_bytes: int = 1 << 25):
    """Build grad_fn(params, batch) -> (loss, grads) that splits the batch
    into microbatches, accumulates grads with lax.scan, and reduces across
    `axis` (if inside shard_map).  The scan makes microbatch i's backward
    independent of microbatch i-1's reduction — overlap-friendly."""

    def grad_fn(params, batch):
        def split(x):
            B = x.shape[0]
            assert B % num_microbatches == 0, (B, num_microbatches)
            return x.reshape((num_microbatches, B // num_microbatches) + x.shape[1:])

        mbatches = jax.tree.map(split, batch)
        vg = jax.value_and_grad(loss_fn, has_aux=True)

        def body(acc, mb):
            (loss, aux), g = vg(params, mb)
            acc_loss, acc_g = acc
            acc_g = jax.tree.map(jnp.add, acc_g, g)
            return (acc_loss + loss, acc_g), None

        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zero_g), mbatches)
        inv = 1.0 / num_microbatches
        loss = loss * inv
        grads = jax.tree.map(lambda g: g * inv, grads)
        if axis is not None:
            grads = allreduce_tree(grads, axis, algorithm, bucket_bytes)
            loss = jax.lax.pmean(loss, axis)
        return loss, grads

    return grad_fn


# ---------------------------------------------------------------------------
# Collective matmul (all-gather / reduce-scatter fused into the GEMM loop)
# ---------------------------------------------------------------------------

def collective_matmul_ag(x: jax.Array, w: jax.Array, axis: str) -> jax.Array:
    """y = all_gather(x, axis) @ w — without materializing the gather.

    x: [m_local, K]; w: [K, n_local].  Each of the P steps multiplies the
    resident chunk while ppermute ships the next — compute hides the
    collective (the paper's overlap goal, expressed structurally).
    Returns [P*m_local, n_local].
    """
    n = S._axis_size(axis)
    idx = S._axis_index(axis)
    if n == 1:
        return x @ w
    m = x.shape[0]
    out = jnp.zeros((n, m, w.shape[-1]), x.dtype)
    perm = [(i, (i + 1) % n) for i in range(n)]
    cur, pos = x, idx
    for step in range(n):
        part = cur @ w                               # compute resident chunk
        oh = jax.nn.one_hot(pos, n, dtype=part.dtype)
        out = out + oh[:, None, None] * part[None]
        if step != n - 1:
            cur = jax.lax.ppermute(cur, axis, perm)  # ship next chunk
            pos = (pos - 1) % n
    return out.reshape(n * m, w.shape[-1])


def collective_matmul_rs(x: jax.Array, w: jax.Array, axis: str) -> jax.Array:
    """y = reduce_scatter(x @ all-partitioned w) — matmul chunks feed the
    ring as they finish.  x: [M, k_local]; w: [k_local, N] with the
    contraction sharded; output rows scattered: [M/P rows... ] —
    formulated here as: compute x @ w (partial sums), ring-reduce-scatter
    over rows so rank r keeps rows r·(M/P):(r+1)·(M/P) fully reduced."""
    n = S._axis_size(axis)
    if n == 1:
        return x @ w
    partial_y = x @ w                                 # [M, N] partial sums
    M = partial_y.shape[0]
    assert M % n == 0
    # reduce-scatter over leading dim: reuse last-dim helper via transpose
    yt = jnp.moveaxis(partial_y, 0, -1)               # [N, M]
    red = S.ring_reduce_scatter(yt, axis)             # [N, M/P]
    return jnp.moveaxis(red, -1, 0)                   # [M/P, N]


def ag_matmul_reference(x, w, axis):
    return jax.lax.all_gather(x, axis, tiled=True) @ w
