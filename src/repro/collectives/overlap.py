"""Computation/communication overlap (paper §2.3–§2.4) on TPU.

The paper's point: a multi-wait-block operation only overlaps if
*progress* runs between its stages.  In an SPMD program the scheduler is
the XLA compiler — overlap is obtained **structurally**, by writing the
program so communication of piece i-1 is dataflow-independent of the
compute of piece i:

* ``microbatched_grad_step`` — gradient accumulation where the bucketed
  allreduce of microbatch i-1's grads has no dependency on microbatch
  i's backward pass, so XLA's latency-hiding scheduler can run the
  collective behind the compute (DDP-style bucket overlap).
* ``collective_matmul_ag`` — all-gather→matmul rewritten as a rolled
  ppermute loop: every step multiplies the chunk it already has while
  ppermute ships the next one (Wang et al.'s collective-matmul; the
  device-side analogue of "progress runs while you compute").
* ``collective_matmul_rs`` — matmul→reduce-scatter, same idea backwards.
"""
from __future__ import annotations

import functools
import threading
import time
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.collectives import schedules as S
from repro.core import debug


# ---------------------------------------------------------------------------
# Bucketed, overlapped gradient reduction
# ---------------------------------------------------------------------------

def bucket_tree(tree, bucket_bytes: int = 1 << 25):
    """Partition tree leaves into buckets of ~bucket_bytes (DDP-style).

    Returns list of lists of leaf indices (ordered as tree_leaves).
    Buckets are **per-dtype**: the bucketed reduction concatenates a
    bucket's leaves into one payload, and a mixed-dtype concat would
    promote (bf16 leaves reduced — and shipped — as f32, results
    diverging from the per-leaf native reduction).  Non-array leaves
    (no ``size``/``dtype``) are rejected eagerly: they cannot be
    byte-counted or concatenated, and counting them as 0 used to let
    them accumulate into one unbounded bucket.
    """
    leaves = jax.tree.leaves(tree)
    buckets = []
    open_buckets: dict = {}          # dtype -> [indices, byte count]
    order: list = []                 # dtypes in first-seen order
    for i, leaf in enumerate(leaves):
        if not hasattr(leaf, "size") or not hasattr(leaf, "dtype"):
            raise TypeError(
                f"bucket_tree: leaf {i} is {type(leaf).__name__}, not an "
                f"array; bucketed reduction needs array leaves (wrap "
                f"scalars in jnp.asarray)")
        dt = jnp.dtype(leaf.dtype)
        if dt not in open_buckets:
            open_buckets[dt] = [[], 0]
            order.append(dt)
        cur = open_buckets[dt]
        cur[0].append(i)
        cur[1] += leaf.size * dt.itemsize
        if cur[1] >= bucket_bytes:
            buckets.append(cur[0])
            open_buckets[dt] = [[], 0]
    for dt in order:
        if open_buckets[dt][0]:
            buckets.append(open_buckets[dt][0])
    return buckets


def allreduce_tree(grads, axis: str, algorithm: str = "psum",
                   bucket_bytes: int = 1 << 25):
    """Reduce a gradient pytree across `axis` inside shard_map.

    algorithm "psum" uses the native op; others use the user-level
    schedules from :mod:`schedules` — the Fig-13 comparison at scale.
    Buckets exist to give the scheduler independent collectives it can
    overlap with backward compute; they are single-dtype (see
    :func:`bucket_tree`), so each bucket reduces in its leaves' native
    dtype — bit-comparable to the per-leaf native op, and bf16 buckets
    ship bf16 bytes instead of silently upcasting the wire format.
    """
    leaves, treedef = jax.tree.flatten(grads)
    if algorithm == "psum":
        red = [jax.lax.psum(g, axis) for g in leaves]
        return jax.tree.unflatten(treedef, red)
    fn = S.ALGORITHMS[algorithm]
    buckets = bucket_tree(grads, bucket_bytes)
    red = [None] * len(leaves)
    for bucket in buckets:
        flat = jnp.concatenate([leaves[i].reshape(-1) for i in bucket])
        flat = fn(flat, axis)
        off = 0
        for i in bucket:
            n = leaves[i].size
            red[i] = flat[off:off + n].reshape(leaves[i].shape)
            off += n
    return jax.tree.unflatten(treedef, red)


def microbatched_grad_fn(loss_fn: Callable, num_microbatches: int,
                         axis: str | None = None,
                         algorithm: str = "psum",
                         bucket_bytes: int = 1 << 25):
    """Build grad_fn(params, batch) -> (loss, grads) that splits the batch
    into microbatches, accumulates grads with lax.scan, and reduces across
    `axis` (if inside shard_map).  The scan makes microbatch i's backward
    independent of microbatch i-1's reduction — overlap-friendly."""

    def grad_fn(params, batch):
        def split(x):
            B = x.shape[0]
            assert B % num_microbatches == 0, (B, num_microbatches)
            return x.reshape((num_microbatches, B // num_microbatches) + x.shape[1:])

        mbatches = jax.tree.map(split, batch)
        vg = jax.value_and_grad(loss_fn, has_aux=True)

        def body(acc, mb):
            (loss, aux), g = vg(params, mb)
            acc_loss, acc_g = acc
            acc_g = jax.tree.map(jnp.add, acc_g, g)
            return (acc_loss + loss, acc_g), None

        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zero_g), mbatches)
        inv = 1.0 / num_microbatches
        loss = loss * inv
        grads = jax.tree.map(lambda g: g * inv, grads)
        if axis is not None:
            grads = allreduce_tree(grads, axis, algorithm, bucket_bytes)
            loss = jax.lax.pmean(loss, axis)
        return loss, grads

    return grad_fn


# ---------------------------------------------------------------------------
# Engine-driven bucketed gradient reduction (paper §4.7 at the host level)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(1,))
def _flatten_bucket(leaves, n: int):
    """Stacked per-device leaves [n, *shape] -> one [n, bucket] payload."""
    return jnp.concatenate([g.reshape(n, -1) for g in leaves], axis=-1)


@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def _unflatten_bucket(flat, shapes: tuple, scale: float, n: int):
    """Reduced [n, bucket] payload (every row = the cross-device sum)
    back into reduced leaves [*shape] (row 0, optionally scaled)."""
    out, off = [], 0
    for shape in shapes:
        size = 1
        for s in shape:
            size *= s
        leaf = flat[0, off:off + size].reshape(shape)
        out.append(leaf * scale if scale != 1.0 else leaf)
        off += size
    return out


class TreeReduction:
    """Handle for an in-flight engine-driven gradient reduction: one
    nonblocking collective request per bucket plus the reassembly plan."""

    def __init__(self, reducer: "EngineGradReducer", requests, buckets,
                 shapes, dtypes, treedef, num_leaves: int):
        self.reducer = reducer
        self.requests = requests
        self._buckets = buckets
        self._shapes = shapes
        self._dtypes = dtypes
        self._treedef = treedef
        self._num_leaves = num_leaves

    @property
    def is_complete(self) -> bool:
        return all(r.is_complete for r in self.requests)

    def wait(self, timeout: float | None = None):
        """Drive the engine until every bucket reduced; returns the
        reduced gradient pytree (leaves deduplicated back to one copy).
        Waits per-request (``CollectiveRequest.wait``) so the waiter can
        park on in-flight round programs instead of busy-polling; order
        doesn't matter — every bucket must finish.  ``timeout`` is one
        overall deadline across the whole set, not per bucket."""
        import time
        deadline = None if timeout is None else time.monotonic() + timeout
        for req in self.requests:
            remaining = None if deadline is None else \
                max(0.0, deadline - time.monotonic())
            req.wait(timeout=remaining)
        n = self.reducer.axis_size
        scale = (1.0 / n) if self.reducer.mean else 1.0
        red = [None] * self._num_leaves
        for req, bucket in zip(self.requests, self._buckets):
            shapes = tuple(self._shapes[i] for i in bucket)
            leaves = _unflatten_bucket(req.value(), shapes, scale, n)
            for i, leaf in zip(bucket, leaves):
                red[i] = leaf.astype(self._dtypes[i])
        return jax.tree.unflatten(self._treedef, red)


class EngineGradReducer:
    """DDP-style bucketed gradient allreduce driven by the progress
    engine (the 'engine mode' of :func:`allreduce_tree`).

    Input gradients are *stacked per-device* trees — each leaf
    ``[axis_size, *shape]`` sharded on the leading dim (the output of a
    ``shard_map``-local grad step: device i's local gradient in row i).
    ``iallreduce_tree`` flattens leaves into ~``bucket_bytes`` buckets
    and issues one chunk-pipelined nonblocking :func:`iallreduce` per
    bucket, so the reductions progress on the collective stream while
    the caller keeps computing (backward of the next microbatch, the
    optimizer of the previous step, prefetch fills...).  ``mean=True``
    scales by 1/axis_size on reassembly — the data-parallel gradient
    mean.

    Buckets reduce through **persistent schedules**: the first
    ``iallreduce_tree`` builds one :class:`~repro.collectives.
    nonblocking.PersistentCollective` per (bucket ordinal, shape, dtype)
    and every later step re-``start``s the cached handle — plan,
    validation, round programs and donated carries are all reused, so a
    training step pays only split+dispatch per bucket (MPI
    ``Allreduce_init``/``Start`` across the step loop).  ``round_batch``
    (None = auto from bucket size) fuses consecutive schedule rounds
    per dispatch."""

    def __init__(self, mesh, axis: str, *, engine=None, collectives=None,
                 algorithm: str = "ring", chunks: int = 4,
                 bucket_bytes: int = 1 << 25, mean: bool = True,
                 executor=None, round_batch: int | None = None,
                 epoch=None, spec=None):
        from repro.collectives import nonblocking as NB
        if spec is not None:
            algorithm = spec.algorithm
            chunks = spec.chunks
            round_batch = spec.round_batch
        self.mesh = mesh
        self.axis = axis
        self.axis_size = dict(mesh.shape)[axis]
        self._algorithm_pref = algorithm
        self.algorithm = S.resolve_algorithm(algorithm, self.axis_size)
        self.chunks = chunks
        self.bucket_bytes = bucket_bytes
        self.mean = mean
        self.round_batch = round_batch
        self.epoch = epoch
        self.remeshes = 0
        self._own_coll = collectives is None
        self.coll = collectives if collectives is not None else \
            NB.UserCollectives(engine, executor=executor, name="gradreduce",
                               epoch=epoch)
        # (bucket ordinal, payload shape, dtype) -> PersistentCollective.
        # Keyed per ordinal: two same-shaped buckets in one step need two
        # handles (a persistent handle allows one outstanding start).
        self._persistent: dict = {}

    def _handle(self, ordinal: int, flat):
        key = (ordinal, tuple(flat.shape), str(flat.dtype))
        handle = self._persistent.get(key)
        if handle is None:
            # warmup=False: the first start compiles (same cost the old
            # one-shot path paid); later starts hit the warm programs
            handle = self.coll.allreduce_init(
                flat, self.mesh, self.axis, algorithm=self.algorithm,
                chunks=self.chunks, round_batch=self.round_batch,
                warmup=False, epoch=self.epoch)
            self._persistent[key] = handle
        return handle

    def remesh(self, mesh, axis: str | None = None) -> "EngineGradReducer":
        """Adopt the survivors' mesh after a membership change.

        The stacked-gradient payload shape carries the axis size in its
        leading dim, so the old persistent handles can't be re-planned
        in place — they are closed and fresh ones (new shape, new mesh,
        algorithm re-resolved for the surviving axis size) build lazily
        on the next ``iallreduce_tree``, which therefore resumes the
        reduction on survivors within the same training step."""
        for handle in self._persistent.values():
            handle.close()
        self._persistent.clear()
        self.mesh = mesh
        if axis is not None:
            self.axis = axis
        self.axis_size = dict(mesh.shape)[self.axis]
        self.algorithm = S.resolve_algorithm(self._algorithm_pref,
                                             self.axis_size)
        self.remeshes += 1
        return self

    def iallreduce_tree(self, stacked_grads) -> TreeReduction:
        """Issue the bucketed reduction; returns immediately."""
        leaves, treedef = jax.tree.flatten(stacked_grads)
        n = self.axis_size
        shapes = [tuple(g.shape[1:]) for g in leaves]
        dtypes = [g.dtype for g in leaves]
        # single-dtype buckets: _flatten_bucket concatenates, and a
        # mixed bucket would promote (reduce bf16 as f32).  Same rule —
        # and same one-open-bucket-per-dtype grouping — as bucket_tree,
        # so interleaved-dtype trees (bf16 weights between f32 norm
        # scales) still coalesce instead of fragmenting per leaf.
        buckets = []
        open_buckets: dict = {}      # dtype -> [indices, per-device bytes]
        order: list = []
        for i, g in enumerate(leaves):
            dt = jnp.dtype(g.dtype)
            if dt not in open_buckets:
                open_buckets[dt] = [[], 0]
                order.append(dt)
            cur = open_buckets[dt]
            cur[0].append(i)
            cur[1] += (g.size // max(1, g.shape[0])) * dt.itemsize
            if cur[1] >= self.bucket_bytes:
                buckets.append(cur[0])
                open_buckets[dt] = [[], 0]
        for dt in order:
            if open_buckets[dt][0]:
                buckets.append(open_buckets[dt][0])
        requests = []
        for bi, bucket in enumerate(buckets):
            flat = _flatten_bucket(tuple(leaves[i] for i in bucket), n)
            handle = self._handle(bi, flat)
            if handle.active is not None and not handle.active.is_complete:
                # overlapping tree reductions (caller didn't wait the
                # previous one): fall back to a one-shot issue rather
                # than violating the handle's single-start invariant
                requests.append(self.coll.iallreduce(
                    flat, self.mesh, self.axis, algorithm=self.algorithm,
                    chunks=self.chunks, round_batch=self.round_batch))
            else:
                requests.append(handle.start(flat))
        return TreeReduction(self, requests, buckets, shapes, dtypes,
                             treedef, len(leaves))

    def allreduce_tree(self, stacked_grads, timeout: float | None = None):
        """Blocking convenience: issue + engine-driven wait."""
        return self.iallreduce_tree(stacked_grads).wait(timeout=timeout)

    def close(self) -> None:
        for handle in self._persistent.values():
            handle.close()
        self._persistent.clear()
        if self._own_coll:
            self.coll.close()


# ---------------------------------------------------------------------------
# ZeRO-style FSDP on persistent reduce-scatter / all-gather handles
# ---------------------------------------------------------------------------

class FsdpLayout:
    """Flat-bucket layout for ZeRO-style parameter sharding.

    Computed once from a parameter-tree template: leaves are grouped
    into per-dtype buckets (:func:`bucket_tree` — one concatenated
    payload per bucket, never mixing dtypes), each bucket's flat width
    padded up to a multiple of the data-axis size so rank ``r`` owns the
    contiguous block ``r`` of the flat bucket — exactly the block
    placement both the ring and halving/doubling reduce-scatter
    schedules (and native ``psum_scatter``) produce.  The flatten /
    unflatten helpers are traceable, so they run *inside* the jitted
    grad program: the gathered flat buckets never round-trip through
    per-leaf host reassembly.
    """

    def __init__(self, params, n: int, bucket_bytes: int = 1 << 25):
        leaves, self.treedef = jax.tree.flatten(params)
        self.n = n
        self.shapes = [tuple(l.shape) for l in leaves]
        self.dtypes = [jnp.dtype(l.dtype) for l in leaves]
        self.sizes = [int(l.size) for l in leaves]
        self.buckets = bucket_tree(params, bucket_bytes)
        self.widths = []                 # padded flat width, multiple of n
        self.totals = []                 # unpadded flat width
        for bucket in self.buckets:
            total = sum(self.sizes[i] for i in bucket)
            self.totals.append(total)
            self.widths.append(-(-total // n) * n)

    @property
    def num_buckets(self) -> int:
        return len(self.buckets)

    def bucket_dtype(self, b: int):
        return self.dtypes[self.buckets[b][0]]

    # -- traceable ---------------------------------------------------------
    def flatten_bucket(self, leaves, b: int):
        """Full (unstacked) leaves -> the padded flat bucket ``[W]``."""
        idx = self.buckets[b]
        dt = self.bucket_dtype(b)
        flat = jnp.concatenate(
            [jnp.asarray(leaves[i]).reshape(-1).astype(dt) for i in idx])
        pad = self.widths[b] - self.totals[b]
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), dt)])
        return flat

    def unflatten(self, flats):
        """Flat buckets ``[W]`` (one per bucket) -> the parameter tree."""
        out = [None] * len(self.shapes)
        for b, flat in enumerate(flats):
            off = 0
            for i in self.buckets[b]:
                out[i] = jax.lax.slice_in_dim(
                    flat, off, off + self.sizes[i]).reshape(self.shapes[i])
                off += self.sizes[i]
        return jax.tree.unflatten(self.treedef, out)

    # -- host-side ---------------------------------------------------------
    def shard_params(self, params, mesh, axis: str):
        """Full replicated params -> list of ``[n, W/n]`` shard stacks,
        placed with the leading dim sharded on ``axis`` (row ``r`` on
        rank ``r`` — ZeRO-3 resident state)."""
        leaves = jax.tree.leaves(params)
        out = []
        for b in range(self.num_buckets):
            flat = self.flatten_bucket(leaves, b)
            stack = flat.reshape(self.n, self.widths[b] // self.n)
            out.append(jax.device_put(stack, NamedSharding(mesh, P(axis))))
        return out

    def unshard_params(self, shards):
        """Shard stacks ``[n, W/n]`` -> the full parameter tree (host-side
        convenience for checkpointing / eval; the training path gathers
        through the engine instead)."""
        flats = [jnp.asarray(s).reshape(-1) for s in shards]
        return self.unflatten(flats)


class FsdpReduction:
    """In-flight bucketed gradient reduce-scatter: one nonblocking
    collective request per flat bucket; ``wait`` returns the reduced
    shard stacks ``[n, W/n]`` (row ``r`` = rank ``r``'s grad-sum block,
    unscaled — the optimizer applies the 1/n data-parallel mean)."""

    def __init__(self, requests):
        self.requests = requests

    @property
    def is_complete(self) -> bool:
        return all(r.is_complete for r in self.requests)

    def wait(self, timeout: float | None = None):
        deadline = None if timeout is None else time.monotonic() + timeout
        out = []
        for req in self.requests:
            remaining = None if deadline is None else \
                max(0.0, deadline - time.monotonic())
            out.append(req.wait(timeout=remaining))
        return out


class FsdpGather:
    """In-flight chained parameter prefetch (the §4.6 continuation
    pattern): one persistent all-gather start per flat bucket, each
    start *chained* as a continuation instead of issued eagerly.

    Two chain shapes:

    * ``after=None`` — bucket ``i+1``'s start is attached to bucket
      ``i``'s completion: a self-propagating prefetch train that
      progresses on the collective stream while the caller computes.
    * ``after=[req, ...]`` (one request-like per bucket, e.g.
      ``jax_future`` s over the optimizer's updated shards) — bucket
      ``i``'s start fires when its *compute future* completes, so the
      gather for the next step's layer group begins the moment its
      shards materialize, behind whatever XLA is still running.

    ``blocked_s`` / ``window_s`` give the prefetch-overlap accounting:
    the fraction of the gather window the caller did *not* spend blocked
    in ``wait`` is communication hidden behind compute.
    """

    def __init__(self, reducer: "FsdpReducer", shards, after=None):
        if after is not None and len(after) != len(shards):
            raise ValueError(
                f"after must carry one request per bucket: "
                f"{len(after)} != {len(shards)}")
        self.reducer = reducer
        self._shards = shards
        self._after = after
        self._reqs: list = [None] * len(shards)
        self._exc: BaseException | None = None
        self._lock = threading.Lock()
        self._t0 = time.monotonic()
        self._t_done: float | None = None
        self.blocked_s = 0.0
        if not shards:
            self._t_done = self._t0
        elif after is None:
            self._start(0)
        else:
            q = reducer.coll.queue
            for i, fut in enumerate(after):
                q.attach(fut, functools.partial(self._on_upstream, i),
                         on_error=functools.partial(self._on_failed, i))

    # -- chain links (run inline on whichever thread progresses) ----------
    def _start(self, i: int) -> None:
        try:
            req = self.reducer._start_gather(i, self._shards[i])
        except BaseException as exc:  # noqa: BLE001 - surfaced by wait()
            with self._lock:
                self._exc = exc
            return
        with self._lock:
            self._reqs[i] = req
        if self._after is None and i + 1 < len(self._shards):
            self.reducer.coll.queue.attach(
                req, lambda _req: self._start(i + 1),
                on_error=functools.partial(self._on_failed, i))

    def _on_upstream(self, i: int, _req) -> None:
        self._start(i)

    def _on_failed(self, i: int, req) -> None:
        with self._lock:
            if self._exc is None:
                self._exc = req.exception or RuntimeError(
                    f"fsdp gather {i} failed")

    # -- waiting -----------------------------------------------------------
    def _drive_until(self, cond, deadline) -> None:
        coll = self.reducer.coll
        eng, s, q = coll.engine, coll.stream, coll.queue
        from repro.core.continuations import DEFERRED
        while not cond():
            ex = eng.executor
            owned = ex is not None and ex.running and ex.owns(s)
            made = 0 if owned else eng.progress(s)
            if q.policy == DEFERRED:
                made += q.drain()
            if cond():
                return
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError("fsdp gather wait timed out")
            if not made:
                time.sleep(20e-6)

    def wait(self, timeout: float | None = None):
        """Drive the engine until every bucket gathered; returns the
        gathered flat buckets ``[n, W]`` (every row a full copy).
        Time spent blocked here is accumulated into ``blocked_s``."""
        deadline = None if timeout is None else time.monotonic() + timeout
        out = []
        for i in range(len(self._shards)):
            t = time.monotonic()
            self._drive_until(
                lambda: self._reqs[i] is not None or self._exc is not None,
                deadline)
            with self._lock:
                req, exc = self._reqs[i], self._exc
            if req is None:
                raise exc
            remaining = None if deadline is None else \
                max(0.0, deadline - time.monotonic())
            out.append(req.wait(timeout=remaining))
            self.blocked_s += time.monotonic() - t
        if self._t_done is None:
            self._t_done = time.monotonic()
            self.reducer._note_gather(self)
        return out

    @property
    def window_s(self) -> float:
        end = self._t_done if self._t_done is not None else time.monotonic()
        return max(end - self._t0, 1e-9)

    @property
    def overlap_fraction(self) -> float:
        """Fraction of the gather window hidden behind caller compute."""
        return max(0.0, min(1.0, 1.0 - self.blocked_s / self.window_s))


class FsdpReducer:
    """ZeRO-style FSDP communication on the progress engine.

    Where :class:`EngineGradReducer` allreduces full gradients (every
    rank ends with every element), this reducer keeps optimizer state
    and parameters *sharded* over the data axis and moves half the wire
    bytes per step:

    * ``ireduce_scatter(flat_grads)`` — per-bucket stacked gradients
      ``[n, W]`` through persistent ``reduce_scatter_init`` handles; the
      reduced block lands directly on its owning rank as ``[n, W/n]``
      (no transposed copy of the other ranks' blocks ever ships).
    * ``igather(shards, after=...)`` — persistent ``allgather_init``
      starts for the next step's full params, chained as continuations
      off compute futures (:class:`FsdpGather`), so gather rounds
      progress on executor streams while XLA runs the current bucket.

    Handles are cached per (op, bucket ordinal, payload shape, dtype) —
    the MPI ``*_init``/``Start`` persistent pattern — and register under
    the membership ``epoch`` like every other persistent collective, so
    2-D-mesh membership changes fail in-flight FSDP starts exactly once
    and ``remesh`` rebuilds on the survivors.  Works on any mesh whose
    ``axis`` names the data dimension; other mesh axes (``model``)
    replicate the schedules, which is what keeps the 2-D (data × model)
    trainer path purely data-axis collectives."""

    def __init__(self, mesh, axis: str = "data", *, engine=None,
                 collectives=None, spec=None, algorithm: str = "ring",
                 chunks: int = 4, bucket_bytes: int = 1 << 25,
                 executor=None, round_batch: int | None = None,
                 epoch=None):
        from repro.collectives import nonblocking as NB
        if spec is None:
            spec = NB.CollectiveSpec(backend="user", algorithm=algorithm,
                                     chunks=chunks, round_batch=round_batch)
        self.mesh = mesh
        self.axis = axis
        self.axis_size = dict(mesh.shape)[axis]
        self._spec_pref = spec
        self.spec = spec.resolve(self.axis_size)
        self.bucket_bytes = bucket_bytes
        self.epoch = epoch
        self.remeshes = 0
        self._own_coll = collectives is None
        self.coll = collectives if collectives is not None else \
            NB.UserCollectives(engine, executor=executor, name="fsdp",
                               epoch=epoch)
        self._persistent: dict = {}
        debug.track_handle(self, "FsdpReducer")
        # prefetch-overlap accounting (totals across completed gathers)
        self.gathers = 0
        self.gather_blocked_s = 0.0
        self.gather_window_s = 0.0

    # -- persistent handles ------------------------------------------------
    def _handle(self, kind: str, ordinal: int, like):
        key = (kind, ordinal, tuple(like.shape), str(like.dtype))
        handle = self._persistent.get(key)
        if handle is None:
            init = self.coll.reduce_scatter_init if kind == "rs" \
                else self.coll.allgather_init
            handle = init(like, self.mesh, self.axis, spec=self.spec,
                          warmup=False, epoch=self.epoch)
            self._persistent[key] = handle
        return handle

    def _start_gather(self, ordinal: int, shard):
        handle = self._handle("ag", ordinal, shard)
        if handle.active is not None and not handle.active.is_complete:
            return self.coll.iallgather(shard, self.mesh, self.axis,
                                        spec=self.spec)
        return handle.start(shard)

    def _note_gather(self, gather: FsdpGather) -> None:
        self.gathers += 1
        self.gather_blocked_s += gather.blocked_s
        self.gather_window_s += gather.window_s

    @property
    def prefetch_overlap(self) -> float:
        """Aggregate overlap fraction across all completed gathers."""
        if self.gather_window_s <= 0.0:
            return 0.0
        return max(0.0, min(1.0, 1.0 - self.gather_blocked_s
                            / self.gather_window_s))

    # -- the two FSDP collectives -----------------------------------------
    def ireduce_scatter(self, flat_grads) -> FsdpReduction:
        """Issue one persistent reduce-scatter per flat grad bucket
        ``[n, W]``; returns immediately."""
        # close() clears the handle cache but nothing else marks the
        # reducer unusable — without the debug tracker a closed reducer
        # silently rebuilds fresh handles on a possibly-closed context
        debug.handle_check_open(self, "ireduce_scatter", kind="FsdpReducer")
        requests = []
        for bi, g in enumerate(flat_grads):
            handle = self._handle("rs", bi, g)
            if handle.active is not None and not handle.active.is_complete:
                requests.append(self.coll.ireduce_scatter(
                    g, self.mesh, self.axis, spec=self.spec))
            else:
                requests.append(handle.start(g))
        return FsdpReduction(requests)

    def igather(self, shards, after=None) -> FsdpGather:
        """Chained param prefetch over the shard stacks ``[n, W/n]``;
        see :class:`FsdpGather` for the two chain shapes."""
        debug.handle_check_open(self, "igather", kind="FsdpReducer")
        return FsdpGather(self, shards, after=after)

    def future(self, arrays):
        """A compute future (device-readiness request) on the reducer's
        own collective stream — the right upstream for ``igather``'s
        ``after=`` chain, since waiting the gather progresses exactly
        this stream."""
        from repro.core.futures import jax_future
        return jax_future(self.coll.engine, arrays, self.coll.stream)

    def gather(self, shards, timeout: float | None = None):
        """Blocking convenience: chained issue + engine-driven wait."""
        return self.igather(shards).wait(timeout=timeout)

    # -- lifecycle ---------------------------------------------------------
    def remesh(self, mesh, axis: str | None = None) -> "FsdpReducer":
        """Adopt the survivors' mesh: close the stale handles (payload
        shapes carry the old axis size), re-resolve the spec for the new
        axis size, and let fresh handles build lazily.  The *caller*
        re-shards params/optimizer state for the new axis size (shard
        widths change) — ``FsdpLayout`` + ``shard_params`` on the
        gathered tree."""
        debug.handle_event(self, "rebuild", kind="FsdpReducer",
                           complete_probe=lambda: True)
        for handle in self._persistent.values():
            handle.close()
        self._persistent.clear()
        self.mesh = mesh
        if axis is not None:
            self.axis = axis
        self.axis_size = dict(mesh.shape)[self.axis]
        self.spec = self._spec_pref.resolve(self.axis_size)
        self.remeshes += 1
        return self

    def close(self) -> None:
        debug.handle_event(self, "close", kind="FsdpReducer")
        for handle in self._persistent.values():
            handle.close()
        self._persistent.clear()
        if self._own_coll:
            self.coll.close()


# ---------------------------------------------------------------------------
# Collective matmul (all-gather / reduce-scatter fused into the GEMM loop)
# ---------------------------------------------------------------------------

def collective_matmul_ag(x: jax.Array, w: jax.Array, axis: str) -> jax.Array:
    """y = all_gather(x, axis) @ w — without materializing the gather.

    x: [m_local, K]; w: [K, n_local].  Each of the P steps multiplies the
    resident chunk while ppermute ships the next — compute hides the
    collective (the paper's overlap goal, expressed structurally).
    Returns [P*m_local, n_local].
    """
    n = S._axis_size(axis)
    idx = S._axis_index(axis)
    if n == 1:
        return x @ w
    m = x.shape[0]
    out = jnp.zeros((n, m, w.shape[-1]), x.dtype)
    perm = [(i, (i + 1) % n) for i in range(n)]
    cur, pos = x, idx
    for step in range(n):
        part = cur @ w                               # compute resident chunk
        oh = jax.nn.one_hot(pos, n, dtype=part.dtype)
        out = out + oh[:, None, None] * part[None]
        if step != n - 1:
            cur = jax.lax.ppermute(cur, axis, perm)  # ship next chunk
            pos = (pos - 1) % n
    return out.reshape(n * m, w.shape[-1])


def collective_matmul_rs(x: jax.Array, w: jax.Array, axis: str) -> jax.Array:
    """y = reduce_scatter(x @ all-partitioned w) — matmul chunks feed the
    ring as they finish.  x: [M, k_local]; w: [k_local, N] with the
    contraction sharded; output rows scattered: [M/P rows... ] —
    formulated here as: compute x @ w (partial sums), ring-reduce-scatter
    over rows so rank r keeps rows r·(M/P):(r+1)·(M/P) fully reduced."""
    n = S._axis_size(axis)
    if n == 1:
        return x @ w
    partial_y = x @ w                                 # [M, N] partial sums
    M = partial_y.shape[0]
    assert M % n == 0
    # reduce-scatter over leading dim: reuse last-dim helper via transpose
    yt = jnp.moveaxis(partial_y, 0, -1)               # [N, M]
    red = S.ring_reduce_scatter(yt, axis)             # [N, M/P]
    return jnp.moveaxis(red, -1, 0)                   # [M/P, N]


def ag_matmul_reference(x, w, axis):
    return jax.lax.all_gather(x, axis, tiled=True) @ w
