"""User-space nonblocking point-to-point on the progress engine.

The collectives stack (PR 3/4/7) built allreduce-family schedules as
chunk-pipelined ``ppermute`` rounds driven by continuations.  This
module is the same machinery one level down: MPI's *point-to-point*
layer — ``isend``/``irecv`` pairs and persistent ``Send_init``/
``Recv_init`` channels — realized as **single-hop jitted shard_map
ppermute rounds** on the PR-4 ``_RoundSchedule``/``_Plan`` machinery.

SPMD matching.  On a mesh every rank runs the same program, so a
"message from rank s to rank s+1" is one ring-hop program over the
axis: the payload is the stacked ``[n, ...]`` array (rank s's slice in
row s), and after the hop row ``s+1`` holds what rank s sent.  The hop
program *is* the rendezvous — but the MPI-shaped halves still exist as
separate handles:

* ``isend(x, ...)`` posts the send half.  If a matching receive is
  already posted the hop issues immediately; otherwise the send parks
  on the *pending-send* queue (MPI's unexpected-message queue).  The
  returned handle completes when the transfer has retired — the send
  buffer is reusable.
* ``irecv(like, ...)`` posts the receive half, matching pending sends
  (or parking on the posted-receive queue).  Its handle completes with
  the received stacked array.

Matching is FIFO per ``(mesh, axis, tag, direction)`` — MPI's
non-overtaking rule for a (communicator, tag, source) triple.

Persistent channels.  Pipeline-parallel activation handoffs are the
ideal ``*_init`` + ``Start`` case: the same shape and dtype every tick.
``send_init``/``recv_init`` return the two views of one
:class:`P2PChannel`, whose single-hop plan rides a
:class:`~repro.collectives.nonblocking.PersistentCollective` — the hop
program compiles once (warmup start on zeros), ``start(payload)`` pays
split+dispatch only, starts are executor-driven when the p2p stream is
adopted, and the handle registers with a
:class:`~repro.collectives.nonblocking.MembershipEpoch` so PR-7 fault
tolerance covers p2p: epoch invalidation fails the in-flight hop with a
retryable ``MembershipError`` and the channel refuses starts until
``rebuild(mesh)``.
"""
from __future__ import annotations

import collections
import threading
import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.collectives import nonblocking as NB
from repro.collectives import schedules as S
from repro.core import debug
from repro.collectives.nonblocking import (CollectiveRequest, MembershipEpoch,
                                           PersistentCollective,
                                           UserCollectives, _Plan,
                                           _identity_schedule, _payload_bytes)


# ---------------------------------------------------------------------------
# The hop schedule: ONE jitted shard_map ppermute round
# ---------------------------------------------------------------------------

class _SpecRoundSchedule(NB._RoundSchedule):
    """A ``_RoundSchedule`` whose programs shard trailing dims too.

    The base class jits with ``in_specs=P(axis)`` (leading dim only).
    Pipeline activations on a 2-D (data x stage) mesh are additionally
    sharded over the data axis, so the hop program takes an explicit
    PartitionSpec.  Everything else — stage tuple, compiled-view cache,
    the shared schedule cache — behaves identically."""

    __slots__ = ("spec",)

    def __init__(self, mesh, axis, stages, spec):
        super().__init__(mesh, axis, stages)
        self.spec = spec

    def compiled(self, round_batch: int = 1) -> NB._Schedule:
        sched = self._compiled.get(1)
        if sched is None:
            progs = [jax.jit(compat.shard_map(
                st.fn, mesh=self.mesh, in_specs=self.spec,
                out_specs=self.spec)) for st in self.stages]
            sched = NB._Schedule(progs)
            self._compiled[1] = sched
        return sched


def _hop_schedule(mesh, axis: str, n: int, reverse: bool, spec):
    """Single ring-hop round over ``axis`` (forward: rank i -> i+1;
    reverse: the opposite ICI direction), from the shared schedule
    cache.  ``donate=False``: the hop input is the caller's payload."""
    spec_key = None if spec is None else tuple(spec)
    key = ("p2p_hop", mesh, axis, n, reverse, spec_key)

    def build():
        perm = S.ring_perm(n, reverse=reverse)

        def hop(v):
            return jax.lax.ppermute(v, axis, perm)

        stages = [NB._RoundStage(hop, donate=False)]
        if spec is None:
            return NB._RoundSchedule(mesh, axis, stages)
        return _SpecRoundSchedule(mesh, axis, stages, spec)

    return NB._cached(key, build)


def _plan_sendrecv(mesh, axis: str, shape, dtype, *, reverse: bool = False,
                   spec=None) -> _Plan:
    """Issue-invariant plan for one matched send/recv hop: single chunk,
    identity split/join, one round."""
    n = NB._axis_len(mesh, axis)
    if len(shape) < 1 or shape[0] != n:
        raise ValueError(
            f"p2p payload must stack one slice per rank: leading dim "
            f"{shape[0] if shape else '?'} != axis size {n} "
            f"(shape {tuple(shape)})")
    nbytes = _payload_bytes(shape, dtype)
    if n == 1:
        sched = _identity_schedule(mesh, axis)
    else:
        sched = _hop_schedule(mesh, axis, n, reverse, spec)
    return _Plan("sendrecv", "ring_hop" + ("-" if reverse else "+"),
                 tuple(shape), dtype, mesh, axis, [sched],
                 lambda x: [x], NB._first, nbytes, 1)


# ---------------------------------------------------------------------------
# Persistent channels (MPI Send_init / Recv_init + Start)
# ---------------------------------------------------------------------------

class PersistentSend:
    """Send view of a :class:`P2PChannel` (MPI ``Send_init``)."""

    __slots__ = ("channel",)

    def __init__(self, channel: "P2PChannel"):
        self.channel = channel

    def start(self, payload) -> CollectiveRequest:
        """MPI_Start on the send half: issue the hop for ``payload``.
        Completes when the transfer has retired (buffer reusable)."""
        return self.channel._start_send(payload)

    @property
    def starts(self) -> int:
        return self.channel.starts

    def close(self) -> None:
        self.channel.close()


class PersistentRecv:
    """Receive view of a :class:`P2PChannel` (MPI ``Recv_init``)."""

    __slots__ = ("channel",)

    def __init__(self, channel: "P2PChannel"):
        self.channel = channel

    def start(self) -> CollectiveRequest:
        """MPI_Start on the receive half: returns a handle completing
        with the received stacked array.  Matches the channel's hops
        FIFO — posted early, it parks until the matching send starts."""
        return self.channel._start_recv()

    @property
    def starts(self) -> int:
        return self.channel.recv_starts

    def close(self) -> None:
        self.channel.close()


class P2PChannel:
    """One persistent matched send/recv pair over a fixed-shape hop.

    Wraps a :class:`PersistentCollective` built from the single-hop
    plan, so warmup compilation, executor-driven starts, the
    one-outstanding-start invariant and membership awareness all carry
    over.  ``send``/``recv`` are the MPI-shaped views; send starts and
    recv starts match FIFO (the recv posted for hop k completes with
    hop k's payload)."""

    def __init__(self, ctx: "P2P", plan: _Plan, *, warmup: bool = True,
                 epoch: "MembershipEpoch | None" = None):
        # on rebuild the stacked leading dim follows the survivors'
        # axis length (row i is rank i's message), so only the trailing
        # message shape carries over
        replan = lambda m, a: _plan_sendrecv(          # noqa: E731
            m, a, (NB._axis_len(m, a),) + plan.shape[1:], plan.dtype,
            reverse=plan.algorithm.endswith("-"),
            spec=getattr(plan.schedules[0], "spec", None))
        self.ctx = ctx
        self.persistent = PersistentCollective(
            ctx, plan, warmup=warmup,
            epoch=epoch if epoch is not None else ctx.epoch, replan=replan)
        self.send = PersistentSend(self)
        self.recv = PersistentRecv(self)
        self.starts = 0
        self.recv_starts = 0
        self._lock = threading.Lock()
        # hops issued but not yet claimed by a recv start / recvs posted
        # before their hop — the two MPI matching queues, channel-local
        self._unclaimed: collections.deque = collections.deque()
        self._waiting: collections.deque = collections.deque()
        debug.track_handle(self, "P2PChannel")

    @property
    def stale(self) -> bool:
        return self.persistent.stale

    def _start_send(self, payload) -> CollectiveRequest:
        hop = self.persistent.start(payload)
        self.starts += 1
        sreq = self.ctx._overlay_request("send")
        with self._lock:
            rreq = self._waiting.popleft() if self._waiting else None
            if rreq is None:
                self._unclaimed.append(hop)
        if rreq is not None:
            self.ctx._wire_pair(hop, sreq, rreq)
        else:
            self.ctx._wire_pair(hop, sreq, None)
        return sreq

    def _start_recv(self) -> CollectiveRequest:
        # the recv half never touches the persistent handle, so (unlike
        # send) nothing guards it in production: a recv posted on a
        # closed channel would park forever — the debug tracker raises
        debug.handle_check_open(self, "recv.start", kind="P2PChannel")
        rreq = self.ctx._overlay_request("recv")
        self.recv_starts += 1
        with self._lock:
            hop = self._unclaimed.popleft() if self._unclaimed else None
            if hop is None:
                self._waiting.append(rreq)
        if hop is not None:
            self.ctx._wire_pair(hop, None, rreq)
        return rreq

    def cancel(self) -> None:
        self.persistent.cancel()

    def rebuild(self, mesh, axis: str | None = None, *,
                warmup: bool = False) -> "P2PChannel":
        """Adopt the survivors' mesh after a membership change (see
        :meth:`PersistentCollective.rebuild`); unmatched halves from the
        dead epoch are dropped."""
        self.persistent.rebuild(mesh, axis, warmup=warmup)
        debug.handle_event(self, "rebuild", kind="P2PChannel",
                           complete_probe=lambda: True)
        with self._lock:
            self._unclaimed.clear()
            self._waiting.clear()
        return self

    def close(self) -> None:
        debug.handle_event(self, "close", kind="P2PChannel")
        self.persistent.close()

    def __repr__(self):
        return (f"P2PChannel({self.persistent.plan.algorithm}, "
                f"shape={self.persistent.plan.shape}, "
                f"starts={self.starts})")


# ---------------------------------------------------------------------------
# The p2p issue context
# ---------------------------------------------------------------------------

def _resolve_spec_partition(spec, partition):
    """Normalize the p2p kwarg pair to (CollectiveSpec, PartitionSpec).

    Historically p2p's ``spec=`` meant the payload PartitionSpec; every
    other factory now takes a :class:`CollectiveSpec` under that name,
    so the PartitionSpec moved to ``partition=``.  A PartitionSpec (or
    tuple) arriving via ``spec=`` still works for one release with a
    once-per-process DeprecationWarning.  A single ring hop has no
    algorithm/chunking degrees of freedom, so of a CollectiveSpec only
    the backend is meaningful — ``native`` is rejected eagerly (these
    channels *are* the user backend)."""
    if spec is not None and not isinstance(spec, NB.CollectiveSpec):
        if "P2P.spec" not in NB._legacy_kwargs_warned:
            NB._legacy_kwargs_warned.add("P2P.spec")
            warnings.warn(
                "p2p spec= now takes a CollectiveSpec like every other "
                "collective factory; pass the payload PartitionSpec as "
                "partition= (the old spelling works one more release)",
                DeprecationWarning, stacklevel=4)
        if partition is None:
            partition = spec
        spec = None
    if spec is not None and not spec.user:
        raise ValueError(
            "p2p channels run on the user backend only; got "
            f"spec.backend={spec.backend!r}")
    return spec, partition


class P2P(UserCollectives):
    """Issue context for user-space nonblocking point-to-point.

    Extends :class:`UserCollectives` — same dedicated stream,
    continuation queue, counters and close/drain lifecycle — with the
    p2p surface: ``isend``/``irecv`` matched pairs and
    ``send_init``/``recv_init``/``channel_init`` persistent channels.

    Extra counters: ``sends``/``recvs`` (halves posted), ``matched``
    (pairs that met), ``unexpected`` (sends that arrived before their
    receive was posted — MPI's unexpected-message path).
    """

    def __init__(self, engine=None, *, executor=None, stream=None,
                 policy: str = NB.INLINE, name: str = "",
                 epoch: "MembershipEpoch | None" = None):
        super().__init__(engine, executor=executor, stream=stream,
                         policy=policy, name=name or "p2p", epoch=epoch)
        self._match_lock = threading.Lock()
        # (mesh, axis, tag, reverse) -> deque — the two matching queues
        self._pending_sends: dict = {}
        self._posted_recvs: dict = {}
        self._channels: dict = {}
        self.sends = 0
        self.recvs = 0
        self.matched = 0
        self.unexpected = 0

    # -- one-shot matched pairs -------------------------------------------
    def isend(self, x, mesh, axis: str, *, tag: Any = 0,
              reverse: bool = False, spec=None,
              partition=None) -> CollectiveRequest:
        """Post the send half of a matched pair: ``x`` is the stacked
        ``[n, ...]`` payload (rank i's message in row i); each rank's
        slice ships one hop along the ring (``reverse`` flips the
        direction).  Returns a send handle that completes (value None)
        once the transfer retires.  The hop dispatches when the
        matching ``irecv`` is posted — in either order.  ``partition``
        is the payload PartitionSpec (see
        :func:`_resolve_spec_partition`)."""
        self._check_open()
        spec, partition = _resolve_spec_partition(spec, partition)
        key = (mesh, axis, tag, bool(reverse), _spec_key(partition))
        sreq = self._overlay_request("send")
        self.sends += 1
        with self._match_lock:
            recvs = self._posted_recvs.get(key)
            rreq = recvs.popleft() if recvs else None
            if rreq is None:
                self._pending_sends.setdefault(
                    key, collections.deque()).append((x, sreq))
                self.unexpected += 1
        if rreq is not None:
            self._match(key, x, sreq, rreq, partition)
        return sreq

    def irecv(self, like, mesh, axis: str, *, tag: Any = 0,
              reverse: bool = False, spec=None,
              partition=None) -> CollectiveRequest:
        """Post the receive half (``like`` fixes shape/dtype — an array
        or ShapeDtypeStruct).  Returns a handle completing with the
        received stacked array (row i+1 = what rank i sent).  Matches
        pending sends FIFO, else parks on the posted-receive queue."""
        self._check_open()
        del like  # shape/dtype ride with the send payload in SPMD
        spec, partition = _resolve_spec_partition(spec, partition)
        key = (mesh, axis, tag, bool(reverse), _spec_key(partition))
        rreq = self._overlay_request("recv")
        self.recvs += 1
        with self._match_lock:
            sends = self._pending_sends.get(key)
            pair = sends.popleft() if sends else None
            if pair is None:
                self._posted_recvs.setdefault(
                    key, collections.deque()).append(rreq)
        if pair is not None:
            x, sreq = pair
            self._match(key, x, sreq, rreq, partition)
        return rreq

    def sendrecv(self, x, mesh, axis: str, *, reverse: bool = False,
                 spec=None, partition=None) -> CollectiveRequest:
        """One-shot fused pair: issue the hop now, return the receive
        handle (the common SPMD case where one driver is both sides)."""
        self._check_open()
        spec, partition = _resolve_spec_partition(spec, partition)
        plan = _plan_sendrecv(mesh, axis, tuple(x.shape),
                              getattr(x, "dtype", jnp.float32),
                              reverse=reverse, spec=partition)
        return self._issue_plan(plan, x)

    # -- persistent channels ----------------------------------------------
    def channel_init(self, like, mesh, axis: str, *, tag: Any = 0,
                     reverse: bool = False, spec=None, partition=None,
                     warmup: bool = True,
                     epoch: "MembershipEpoch | None" = None) -> P2PChannel:
        """Build (or fetch) the persistent channel for this signature.
        One channel per (mesh, axis, tag, direction, shape, dtype):
        ``send_init`` and ``recv_init`` with the same signature return
        views of the same channel — that is the match."""
        self._check_open()
        spec, partition = _resolve_spec_partition(spec, partition)
        shape = tuple(like.shape)
        dtype = getattr(like, "dtype", jnp.float32)
        key = (mesh, axis, tag, bool(reverse), _spec_key(partition),
               shape, jnp.dtype(dtype))
        chan = self._channels.get(key)
        if chan is None:
            plan = _plan_sendrecv(mesh, axis, shape, dtype,
                                  reverse=reverse, spec=partition)
            chan = P2PChannel(self, plan, warmup=warmup, epoch=epoch)
            self._channels[key] = chan
        return chan

    def send_init(self, like, mesh, axis: str, *, tag: Any = 0,
                  reverse: bool = False, spec=None, partition=None,
                  warmup: bool = True,
                  epoch: "MembershipEpoch | None" = None) -> PersistentSend:
        """MPI ``Send_init``: persistent send half for fixed-shape
        payloads like ``like``.  ``start(payload)`` re-issues the
        pre-compiled hop."""
        return self.channel_init(like, mesh, axis, tag=tag, reverse=reverse,
                                 spec=spec, partition=partition,
                                 warmup=warmup, epoch=epoch).send

    def recv_init(self, like, mesh, axis: str, *, tag: Any = 0,
                  reverse: bool = False, spec=None, partition=None,
                  warmup: bool = True,
                  epoch: "MembershipEpoch | None" = None) -> PersistentRecv:
        """MPI ``Recv_init``: the matching persistent receive half."""
        return self.channel_init(like, mesh, axis, tag=tag, reverse=reverse,
                                 spec=spec, partition=partition,
                                 warmup=warmup, epoch=epoch).recv

    # -- machinery ---------------------------------------------------------
    def _overlay_request(self, op: str) -> CollectiveRequest:
        """A send/recv handle overlaying a hop request: same stream
        affinity and parking ``wait()`` as any collective request."""
        return CollectiveRequest(self.engine, self.stream, self.queue, op,
                                 "ring_hop", 1, 1, ctx=self)

    def _match(self, key, x, sreq, rreq, spec) -> None:
        mesh, axis, _tag, reverse, _sk = key
        self.matched += 1
        try:
            plan = _plan_sendrecv(mesh, axis, tuple(x.shape),
                                  getattr(x, "dtype", jnp.float32),
                                  reverse=reverse, spec=spec)
            hop = self._issue_plan(plan, x)
        except BaseException as exc:  # noqa: BLE001
            for req in (sreq, rreq):
                self._fail_overlay(req, exc)
            return
        self._wire_pair(hop, sreq, rreq)

    def _wire_pair(self, hop: CollectiveRequest,
                   sreq: Optional[CollectiveRequest],
                   rreq: Optional[CollectiveRequest]) -> None:
        """Complete the overlay handles off the hop's completion: the
        send side with None (buffer retired), the receive side with the
        hopped array.  Failure (including a membership invalidation of
        the underlying persistent hop) propagates to both."""

        def _done(h):
            if rreq is not None:
                self._complete_overlay(rreq, h.value())
            if sreq is not None:
                self._complete_overlay(sreq, None)

        def _err(h):
            exc = h.exception or RuntimeError("p2p hop failed")
            for req in (sreq, rreq):
                if req is not None:
                    self._fail_overlay(req, exc)

        self.queue.attach(hop, _done, on_error=_err)

    @staticmethod
    def _complete_overlay(req: CollectiveRequest, value) -> None:
        with req._fail_lock:
            if not req.is_complete:
                req.rounds_done = 1
                req.complete(value)

    @staticmethod
    def _fail_overlay(req: CollectiveRequest, exc: BaseException) -> None:
        with req._fail_lock:
            if not req.is_complete:
                req.fail(exc)

    def close(self, *, drain: bool = True,
              timeout: float | None = 30.0) -> None:
        for chan in self._channels.values():
            chan.close()
        super().close(drain=drain, timeout=timeout)


def _spec_key(spec):
    return None if spec is None else tuple(spec)


def default_p2p(engine=None, *, executor=None, **kw) -> P2P:
    """Module-default p2p context (one per engine, like
    ``default_collectives``)."""
    eng = engine if engine is not None else NB.global_engine()
    ctx = getattr(eng, "_default_p2p", None)
    if ctx is None or ctx._closed:
        ctx = P2P(eng, executor=executor, **kw)
        eng._default_p2p = ctx
    return ctx


# ---------------------------------------------------------------------------
# Canonical module-level factories (mirror nonblocking's *_init family):
# ``<op>_init(like, mesh, axis, *, spec=None, epoch=None, stream=None,
# engine=None, ...)`` on the per-engine default context.
# ---------------------------------------------------------------------------

def channel_init(like, mesh, axis: str, *, spec=None, tag: Any = 0,
                 reverse: bool = False, partition=None, warmup: bool = True,
                 epoch: "MembershipEpoch | None" = None, stream=None,
                 engine=None) -> P2PChannel:
    """Persistent matched send/recv channel on the default p2p context.
    ``spec`` is a :class:`~repro.collectives.nonblocking.CollectiveSpec`
    (user backend only); the payload PartitionSpec goes in
    ``partition=``."""
    ctx = default_p2p(engine, stream=stream) if stream is not None \
        else default_p2p(engine)
    return ctx.channel_init(like, mesh, axis, tag=tag, reverse=reverse,
                            spec=spec, partition=partition, warmup=warmup,
                            epoch=epoch)


def send_init(like, mesh, axis: str, *, spec=None, tag: Any = 0,
              reverse: bool = False, partition=None, warmup: bool = True,
              epoch: "MembershipEpoch | None" = None, stream=None,
              engine=None) -> PersistentSend:
    """MPI ``Send_init`` on the default p2p context."""
    return channel_init(like, mesh, axis, spec=spec, tag=tag, reverse=reverse,
                        partition=partition, warmup=warmup, epoch=epoch,
                        stream=stream, engine=engine).send


def recv_init(like, mesh, axis: str, *, spec=None, tag: Any = 0,
              reverse: bool = False, partition=None, warmup: bool = True,
              epoch: "MembershipEpoch | None" = None, stream=None,
              engine=None) -> PersistentRecv:
    """MPI ``Recv_init`` on the default p2p context."""
    return channel_init(like, mesh, axis, spec=spec, tag=tag, reverse=reverse,
                        partition=partition, warmup=warmup, epoch=epoch,
                        stream=stream, engine=engine).recv
