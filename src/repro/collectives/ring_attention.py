"""Ring attention — context parallelism via the user-level ppermute
schedule (the paper's §4.7 technique applied to the attention hot spot).

When an architecture's head count does not divide the tensor axis
(qwen2-0.5b: 14, smollm: 15, granite: 24 vs a 16-way axis), Megatron-
style head TP degenerates to fully replicated attention.  Ring attention
shards the *sequence* instead: each device holds S/P of q/k/v; kv blocks
circulate around the ring (one ppermute per step) while every device
accumulates online-softmax partials for its local q block.  Exact same
math as full attention; compute shards P ways for ANY head count.

This is the device-side twin of the paper's user-level allreduce: an
explicit schedule of point-to-point permutes replacing an opaque
collective — with the paper's computation/communication overlap built in
structurally (step i's GEMMs are dataflow-independent of step i+1's
ppermute, so the XLA scheduler overlaps them).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

NEG_INF = -1e30


def _ring_body(q, k, v, axis: str, *, causal: bool, logit_cap: float = 0.0):
    """Inside shard_map. q,k,v local: [B, S_loc, H, hd] (S global-sharded).
    Returns local attention output [B, S_loc, H, hd]."""
    n = compat.axis_size(axis)
    idx = jax.lax.axis_index(axis)
    B, S_loc, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    qf = (q.astype(jnp.float32) * scale).astype(q.dtype)
    q_pos = idx * S_loc + jnp.arange(S_loc)                 # [S_loc]

    perm = [(i, (i + 1) % n) for i in range(n)]
    m = jnp.full((B, H, S_loc), NEG_INF, jnp.float32)
    l = jnp.zeros((B, H, S_loc), jnp.float32)
    acc = jnp.zeros((B, S_loc, H, hd), jnp.float32)
    k_cur, v_cur = k, v
    for step in range(n):
        src = (idx - step) % n                              # kv block origin
        k_pos = src * S_loc + jnp.arange(S_loc)             # [S_loc]
        k_r = jnp.repeat(k_cur, G, axis=2)                  # [B,S_loc,H,hd]
        v_r = jnp.repeat(v_cur, G, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, k_r,
                       preferred_element_type=jnp.float32)
        if logit_cap:
            s = jnp.tanh(s / logit_cap) * logit_cap
        if causal:
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask[None, None], s, NEG_INF)
        m_c = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_c)
        m_safe = jnp.maximum(m_new, NEG_INF / 2)
        p = jnp.exp(s - m_safe[..., None])
        corr = jnp.exp(jnp.minimum(m - m_new, 0.0))
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v_r.dtype), v_r,
                        preferred_element_type=jnp.float32)
        acc = acc * corr.transpose(0, 2, 1)[..., None] + pv
        m = m_new
        if step != n - 1:
            k_cur = jax.lax.ppermute(k_cur, axis, perm)
            v_cur = jax.lax.ppermute(v_cur, axis, perm)
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# custom_vjp ring: explicit backward schedule (flash bwd over the ring)
# ---------------------------------------------------------------------------
#
# The naive AD of the fwd ring replays the whole permute chain and saves
# per-step score tensors; the explicit schedule below instead saves only
# (q, k, v, o, m, l) and runs ONE backward ring where dk/dv accumulators
# ride along with the circulating k/v blocks — the paper's point that a
# user-level schedule with full context beats the opaque default.

def _ring_fwd_stats(q, k, v, axis, causal, logit_cap):
    """Like _ring_body but also returns softmax stats (m, l)."""
    n = compat.axis_size(axis)
    idx = jax.lax.axis_index(axis)
    B, S_loc, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    qf = (q.astype(jnp.float32) * scale).astype(q.dtype)
    q_pos = idx * S_loc + jnp.arange(S_loc)
    perm = [(i, (i + 1) % n) for i in range(n)]
    m = jnp.full((B, H, S_loc), NEG_INF, jnp.float32)
    l = jnp.zeros((B, H, S_loc), jnp.float32)
    acc = jnp.zeros((B, S_loc, H, hd), jnp.float32)
    k_cur, v_cur = k, v
    for step in range(n):
        src = (idx - step) % n
        k_pos = src * S_loc + jnp.arange(S_loc)
        k_r = jnp.repeat(k_cur, G, axis=2)
        v_r = jnp.repeat(v_cur, G, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, k_r,
                       preferred_element_type=jnp.float32)
        if logit_cap:
            s = jnp.tanh(s / logit_cap) * logit_cap
        if causal:
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.maximum(m_new, NEG_INF / 2)
        p = jnp.exp(s - m_safe[..., None])
        corr = jnp.exp(jnp.minimum(m - m_new, 0.0))
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v_r.dtype), v_r,
                        preferred_element_type=jnp.float32)
        acc = acc * corr.transpose(0, 2, 1)[..., None] + pv
        m = m_new
        if step != n - 1:
            k_cur = jax.lax.ppermute(k_cur, axis, perm)
            v_cur = jax.lax.ppermute(v_cur, axis, perm)
    out = (acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]).astype(q.dtype)
    return out, m, l


def make_ring_attention_vjp(axis: str, causal: bool, logit_cap: float):
    """Build the custom_vjp ring fn for fixed (axis, causal, cap).

    NOTE: logit_cap > 0 (grok) falls back to AD because tanh softcap
    changes the backward algebra; cap==0 is the common case.
    """

    @jax.custom_vjp
    def ring(q, k, v):
        out, _, _ = _ring_fwd_stats(q, k, v, axis, causal, logit_cap)
        return out

    def fwd(q, k, v):
        out, m, l = _ring_fwd_stats(q, k, v, axis, causal, logit_cap)
        return out, (q, k, v, out, m, l)

    def bwd(res, do):
        q, k, v, o, m, l = res
        n = compat.axis_size(axis)
        idx = jax.lax.axis_index(axis)
        B, S_loc, H, hd = q.shape
        KVH = k.shape[2]
        G = H // KVH
        scale = 1.0 / math.sqrt(hd)
        qf = q.astype(jnp.float32) * scale
        dof = do.astype(jnp.float32)
        # D = rowsum(do ⊙ o)  [B,H,Sq]
        Drow = jnp.einsum("bqhd,bqhd->bhq", dof, o.astype(jnp.float32))
        l_safe = jnp.maximum(l, 1e-30)
        q_pos = idx * S_loc + jnp.arange(S_loc)
        perm = [(i, (i + 1) % n) for i in range(n)]

        dq = jnp.zeros((B, S_loc, H, hd), jnp.float32)
        dk_ring = jnp.zeros((B, S_loc, KVH, hd), jnp.float32)
        dv_ring = jnp.zeros((B, S_loc, KVH, hd), jnp.float32)
        k_cur, v_cur = k, v
        for step in range(n):
            src = (idx - step) % n
            k_pos = src * S_loc + jnp.arange(S_loc)
            k_r = jnp.repeat(k_cur, G, axis=2).astype(jnp.float32)
            v_r = jnp.repeat(v_cur, G, axis=2).astype(jnp.float32)
            s = jnp.einsum("bqhd,bkhd->bhqk", qf, k_r)
            if causal:
                mask = q_pos[:, None] >= k_pos[None, :]
                s = jnp.where(mask[None, None], s, NEG_INF)
            p = jnp.exp(s - jnp.maximum(m, NEG_INF / 2)[..., None]) \
                / l_safe[..., None]                       # [B,H,Sq,Sk]
            dv_blk = jnp.einsum("bhqk,bqhd->bkhd", p, dof)  # per full head
            dp = jnp.einsum("bqhd,bkhd->bhqk", dof, v_r)
            ds = p * (dp - Drow[..., None])               # [B,H,Sq,Sk]
            dq = dq + jnp.einsum("bhqk,bkhd->bqhd", ds, k_r) * scale
            dk_blk = jnp.einsum("bhqk,bqhd->bkhd", ds, qf)  # scale folded in qf
            # fold GQA: sum full-head grads into kv heads
            dv_blk = dv_blk.reshape(B, S_loc, KVH, G, hd).sum(axis=3)
            dk_blk = dk_blk.reshape(B, S_loc, KVH, G, hd).sum(axis=3)
            dk_ring = dk_ring + dk_blk
            dv_ring = dv_ring + dv_blk
            # rotate kv and their grad accumulators together
            k_cur = jax.lax.ppermute(k_cur, axis, perm)
            v_cur = jax.lax.ppermute(v_cur, axis, perm)
            dk_ring = jax.lax.ppermute(dk_ring, axis, perm)
            dv_ring = jax.lax.ppermute(dv_ring, axis, perm)
        # after n permutes each grad block is back home
        return (dq.astype(q.dtype), dk_ring.astype(k.dtype),
                dv_ring.astype(v.dtype))

    ring.defvjp(fwd, bwd)
    return ring


def ring_attention(q, k, v, *, causal: bool = True, axis: str = "model",
                   logit_cap: float = 0.0, batch_axes: tuple = ("pod", "data")):
    """shard_map wrapper. q,k,v: [B,S,H,hd] with S sharded over `axis` and
    B over `batch_axes`; heads replicated.  Falls back to plain full
    attention when no mesh context / axis size 1."""
    mesh = compat.current_mesh()
    if mesh is None or mesh.empty or axis not in mesh.shape \
            or mesh.shape[axis] == 1 or q.shape[1] % mesh.shape[axis] != 0:
        from repro.models.layers import attention
        return attention(q, k, v, causal=causal, logit_cap=logit_cap)
    b_axes = tuple(a for a in batch_axes if a in mesh.shape) or None
    spec = P(b_axes, axis, None, None)
    if logit_cap:
        body = partial(_ring_body, axis=axis, causal=causal,
                       logit_cap=logit_cap)
    else:
        body = make_ring_attention_vjp(axis, causal, 0.0)
    return compat.shard_map(
        lambda q_, k_, v_: body(q_, k_, v_),
        mesh=mesh, in_specs=(spec, spec, spec), out_specs=spec)(q, k, v)
