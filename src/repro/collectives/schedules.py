"""User-level collective algorithms as explicit ppermute schedules.

Paper §4.7 builds an allreduce *in user space* from point-to-point sends
plus the progress engine, and shows it matches (even beats) the native
implementation because it can exploit context the library cannot.

The TPU analogue: inside an SPMD program the "native collective" is the
opaque ``psum``/``all_gather`` HLO op scheduled by XLA; the "user-level"
version is the same algorithm written as explicit ``ppermute`` steps in
``shard_map``.  The poll-function state machine of Listing 1.8 becomes
the unrolled dataflow of the schedule — each ``mask <<= 1`` round is one
ppermute+combine step.

Implemented schedules (validated against the native op in tests):

* ``recursive_doubling_allreduce`` — the paper's Listing 1.8 algorithm
  (log2 P steps, full vector each step; latency-optimal for small data).
* ``ring_reduce_scatter`` / ``ring_all_gather`` / ``ring_allreduce`` —
  bandwidth-optimal on torus ICI (2(P-1)/P × bytes on the slowest link).
* ``bidirectional_ring_allreduce`` — both ICI directions at once, halving
  per-link traffic (v5e-torus-friendly variant).
* ``recursive_halving_doubling_allreduce`` — ring bandwidth in 2 log2 P
  latency (small-message cross-pod reductions).
* ``bruck_alltoall`` — log2 P-step all-to-all for MoE dispatch.

All functions run INSIDE ``shard_map`` over the given axis.  Rank-
dependent chunk selection uses one-hot arithmetic (every rank executes
the same SPMD program; `axis_index` is a traced value).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat


def _axis_size(axis: str) -> int:
    return compat.axis_size(axis)


def _axis_index(axis: str):
    return jax.lax.axis_index(axis)


def ring_perm(n: int, *, reverse: bool = False) -> list:
    """The ppermute permutation for one ring hop over ``n`` ranks.

    Forward is ``[(i, (i+1) % n)]`` (each rank sends to its successor);
    ``reverse=True`` is the opposite ICI direction.  Shared by the ring
    collective schedules, the pipeline stage handoff, and the p2p layer.
    """
    d = -1 if reverse else 1
    return [(i, (i + d) % n) for i in range(n)]


def _take_chunk(chunks: jax.Array, pos, n: int) -> jax.Array:
    """chunks: [..., n, d]; pos: traced scalar -> [..., d]."""
    oh = jax.nn.one_hot(pos, n, dtype=chunks.dtype)
    shape = (1,) * (chunks.ndim - 2) + (n, 1)
    return jnp.sum(chunks * oh.reshape(shape), axis=-2)


def _set_chunk(out: jax.Array, cur: jax.Array, pos, n: int) -> jax.Array:
    """out: [..., n, d]; write cur at block pos (one-hot masked add)."""
    oh = jax.nn.one_hot(pos, n, dtype=cur.dtype)
    shape = (1,) * (out.ndim - 2) + (n, 1)
    return out + oh.reshape(shape) * cur[..., None, :]


# ---------------------------------------------------------------------------
# Recursive doubling (paper Listing 1.8)
# ---------------------------------------------------------------------------

def recursive_doubling_allreduce(x: jax.Array, axis: str) -> jax.Array:
    """The paper's user-level allreduce: XOR-partner exchange, log2 P
    rounds.  Requires power-of-two axis size (as the paper asserts)."""
    n = _axis_size(axis)
    if n & (n - 1):
        raise ValueError(f"recursive doubling requires power-of-two size, got {n}")
    mask = 1
    while mask < n:
        perm = [(i, i ^ mask) for i in range(n)]
        partner = jax.lax.ppermute(x, axis, perm)
        x = x + partner
        mask <<= 1
    return x


# ---------------------------------------------------------------------------
# Ring schedules (bandwidth-optimal on torus ICI)
# ---------------------------------------------------------------------------

def _pad_last(x: jax.Array, n: int):
    D = x.shape[-1]
    if D % n:
        pad = n - D % n
        return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]), D
    return x, D


def ring_reduce_scatter(x: jax.Array, axis: str, *, reverse: bool = False) -> jax.Array:
    """P-1 neighbour steps; returns this rank's reduced [..., D/P] chunk."""
    n = _axis_size(axis)
    if n == 1:
        return x
    idx = _axis_index(axis)
    D = x.shape[-1]
    assert D % n == 0, (D, n)
    chunks = jnp.reshape(x, x.shape[:-1] + (n, D // n))
    direction = -1 if reverse else 1
    perm = ring_perm(n, reverse=reverse)
    # Invariant: after step s, rank r holds the partial sum of chunk
    # (r - d·(1+s)) ... i.e. start with own chunk (r - d) and add chunk
    # (r - d·(1+s)) each step; after n-1 steps rank r holds chunk r fully
    # reduced — which is where ring_all_gather expects it.
    acc = _take_chunk(chunks, (idx - direction) % n, n)
    for step in range(1, n):
        acc = jax.lax.ppermute(acc, axis, perm)
        acc = acc + _take_chunk(chunks, (idx - direction * (1 + step)) % n, n)
    return acc


def ring_all_gather(x: jax.Array, axis: str, *, reverse: bool = False) -> jax.Array:
    """All-gather local chunk [..., d] -> [..., P*d] in P-1 ring steps."""
    n = _axis_size(axis)
    if n == 1:
        return x
    idx = _axis_index(axis)
    d = x.shape[-1]
    direction = -1 if reverse else 1
    perm = ring_perm(n, reverse=reverse)
    out = jnp.zeros(x.shape[:-1] + (n, d), x.dtype)
    cur, pos = x, idx
    for step in range(n):
        out = _set_chunk(out, cur, pos, n)
        if step != n - 1:
            cur = jax.lax.ppermute(cur, axis, perm)
            pos = (pos - direction) % n
    return jnp.reshape(out, x.shape[:-1] + (n * d,))


def ring_allreduce(x: jax.Array, axis: str, *, reverse: bool = False) -> jax.Array:
    """reduce-scatter + all-gather: the bandwidth-optimal allreduce."""
    n = _axis_size(axis)
    if n == 1:
        return x
    xp, D = _pad_last(x, n)
    red = ring_reduce_scatter(xp, axis, reverse=reverse)
    full = ring_all_gather(red, axis, reverse=reverse)
    return full[..., :D]


def bidirectional_ring_allreduce(x: jax.Array, axis: str) -> jax.Array:
    """Split the vector and run opposing rings concurrently, using both
    ICI directions of the torus axis — per-link traffic halves."""
    n = _axis_size(axis)
    if n == 1:
        return x
    D = x.shape[-1]
    half = D // 2
    lo = ring_allreduce(x[..., :half], axis, reverse=False)
    hi = ring_allreduce(x[..., half:], axis, reverse=True)
    return jnp.concatenate([lo, hi], axis=-1)


# ---------------------------------------------------------------------------
# Recursive halving/doubling (latency-optimal at ring bandwidth)
# ---------------------------------------------------------------------------

def recursive_halving_doubling_allreduce(x: jax.Array, axis: str) -> jax.Array:
    """Reduce-scatter by recursive halving, then all-gather by recursive
    doubling: total traffic 2·(P-1)/P·bytes (like the ring) in 2·log2 P
    steps (like the tree) — the right schedule for latency-sensitive
    medium-size cross-pod reductions."""
    n = _axis_size(axis)
    if n & (n - 1):
        raise ValueError("requires power-of-two size")
    if n == 1:
        return x
    xp, D = _pad_last(x, n)
    idx = _axis_index(axis)
    cur = xp
    mask = n >> 1
    while mask >= 1:
        width = cur.shape[-1] // 2
        perm = [(i, i ^ mask) for i in range(n)]
        lo, hi = cur[..., :width], cur[..., width:]
        keep_hi = ((idx // mask) % 2) == 1          # bit `mask` set
        send = jnp.where(keep_hi, lo, hi)           # ship the half we drop
        recv = jax.lax.ppermute(send, axis, perm)
        mine = jnp.where(keep_hi, hi, lo)
        cur = mine + recv
        mask >>= 1
    # all-gather by doubling (inverse order)
    mask = 1
    while mask < n:
        perm = [(i, i ^ mask) for i in range(n)]
        recv = jax.lax.ppermute(cur, axis, perm)
        keep_hi = ((idx // mask) % 2) == 1
        lo = jnp.where(keep_hi, recv, cur)
        hi = jnp.where(keep_hi, cur, recv)
        cur = jnp.concatenate([lo, hi], axis=-1)
        mask <<= 1
    return cur[..., :D]


def recursive_halving_reduce_scatter(x: jax.Array, axis: str) -> jax.Array:
    """Reduce-scatter by recursive halving — the first phase of
    :func:`recursive_halving_doubling_allreduce` standing alone: log2 P
    rounds, the payload halves each round.  The halving order consumes
    rank bits MSB-first, so the block a rank finishes with is exactly its
    own contiguous rank-r block — the same output placement as
    ``ring_reduce_scatter`` / tiled ``psum_scatter``."""
    n = _axis_size(axis)
    if n & (n - 1):
        raise ValueError("requires power-of-two size")
    if n == 1:
        return x
    assert x.shape[-1] % n == 0, (x.shape[-1], n)
    idx = _axis_index(axis)
    cur = x
    mask = n >> 1
    while mask >= 1:
        width = cur.shape[-1] // 2
        perm = [(i, i ^ mask) for i in range(n)]
        lo, hi = cur[..., :width], cur[..., width:]
        keep_hi = ((idx // mask) % 2) == 1
        send = jnp.where(keep_hi, lo, hi)
        recv = jax.lax.ppermute(send, axis, perm)
        mine = jnp.where(keep_hi, hi, lo)
        cur = mine + recv
        mask >>= 1
    return cur


def recursive_doubling_all_gather(x: jax.Array, axis: str) -> jax.Array:
    """All-gather by recursive doubling — the second phase of
    :func:`recursive_halving_doubling_allreduce` standing alone.
    Starting from rank r holding block r, log2 P concat rounds reassemble
    the full vector in native rank order (matches tiled
    ``all_gather``)."""
    n = _axis_size(axis)
    if n & (n - 1):
        raise ValueError("requires power-of-two size")
    if n == 1:
        return x
    idx = _axis_index(axis)
    cur = x
    mask = 1
    while mask < n:
        perm = [(i, i ^ mask) for i in range(n)]
        recv = jax.lax.ppermute(cur, axis, perm)
        keep_hi = ((idx // mask) % 2) == 1
        lo = jnp.where(keep_hi, recv, cur)
        hi = jnp.where(keep_hi, cur, recv)
        cur = jnp.concatenate([lo, hi], axis=-1)
        mask <<= 1
    return cur


# ---------------------------------------------------------------------------
# Bruck all-to-all (MoE dispatch)
# ---------------------------------------------------------------------------

def bruck_alltoall(x: jax.Array, axis: str) -> jax.Array:
    """All-to-all over the leading block dim in ceil(log2 P) rounds.

    x: [P, ...] of blocks; returns y with y[j] on rank i = x[i] of rank j
    (the standard MPI_Alltoall block transpose).
    """
    n = _axis_size(axis)
    if n == 1:
        return x
    idx = _axis_index(axis)
    # Phase 1: local rotation — block k moves to slot (k - idx) mod n
    x = jnp.take(x, (jnp.arange(n) + idx) % n, axis=0)
    # Phase 2: log rounds; round `step` ships blocks with bit set in slot id
    step = 1
    while step < n:
        # a block in slot t must travel +t hops total; round `step` moves
        # slots whose bit `step` is set one hop of +step.
        perm = [(i, (i + step) % n) for i in range(n)]
        move = ((jnp.arange(n) // step) % 2).astype(bool)
        moved = jax.lax.ppermute(x, axis, perm)
        x = jnp.where(move.reshape((n,) + (1,) * (x.ndim - 1)), moved, x)
        step <<= 1
    # Phase 3: inverse rotation — slot k receives block (idx - k) mod n
    x = jnp.take(x, (idx - jnp.arange(n)) % n, axis=0)
    return x


# ---------------------------------------------------------------------------
# Convenience wrappers (tests / benchmarks)
# ---------------------------------------------------------------------------

ALGORITHMS = {
    "ring": ring_allreduce,
    "bidir": bidirectional_ring_allreduce,
    "recursive_doubling": recursive_doubling_allreduce,
    "halving_doubling": recursive_halving_doubling_allreduce,
}

# algorithms whose XOR-partner exchange only works for power-of-two sizes
POW2_ONLY = frozenset({"recursive_doubling", "halving_doubling"})


def resolve_algorithm(algorithm: str, axis_size: int, *,
                      fallback: str = "ring") -> str:
    """Eager validation of (algorithm, axis size) — call *before* tracing.

    Unknown names raise immediately; power-of-two-only algorithms on a
    non-power-of-two axis fall back to ``fallback`` with a clear warning
    instead of raising an opaque ``ValueError`` from inside jit."""
    if algorithm not in ALGORITHMS:
        raise ValueError(f"unknown allreduce algorithm {algorithm!r}; "
                         f"options: {sorted(ALGORITHMS)}")
    if algorithm in POW2_ONLY and axis_size & (axis_size - 1):
        import warnings
        warnings.warn(
            f"{algorithm} allreduce requires a power-of-two axis size, "
            f"got {axis_size}; falling back to {fallback!r}",
            RuntimeWarning, stacklevel=3)
        return fallback
    return algorithm


# rs/ag only decompose for the algorithms that *contain* a reduce-scatter
# or all-gather phase: the ring, and recursive halving/doubling (the
# halving phase IS a reduce-scatter, the doubling phase IS an
# all-gather).  The others are allreduce-shaped end to end.
RS_AG_ALGORITHMS = frozenset({"ring", "halving_doubling"})


def resolve_rs_ag_algorithm(algorithm: str, axis_size: int, *,
                            op: str = "reduce_scatter") -> str:
    """Eager (algorithm, axis size) validation for reduce-scatter /
    all-gather decompositions: unknown names raise, power-of-two-only
    algorithms fall back to ring on other sizes, and algorithm names with
    no rs/ag phase (``bidir``, ``recursive_doubling``) fall back to ring
    with a warning rather than failing deep inside a round program."""
    algorithm = resolve_algorithm(algorithm, axis_size)
    if algorithm not in RS_AG_ALGORITHMS:
        import warnings
        warnings.warn(
            f"{algorithm} has no {op} decomposition (options: "
            f"{sorted(RS_AG_ALGORITHMS)}); falling back to 'ring'",
            RuntimeWarning, stacklevel=3)
        return "ring"
    return algorithm


# ---------------------------------------------------------------------------
# Round batching (persistent schedules; see collectives/nonblocking.py)
# ---------------------------------------------------------------------------

# Payload-size breakpoints for the automatic round-batch factor.  Below
# SMALL the per-dispatch latency dominates total time (the fig-14 small-
# payload gap), so every round of a chunk fuses into ONE program; up to
# LARGE two dispatches keep a little pipelining; above it the bandwidth
# regime needs per-round dispatch so chunk c+1's round r can overlap
# chunk c's round r+1 on the collective stream.
ROUND_BATCH_SMALL_BYTES = 4 << 20        # <= 4 MiB: fuse everything
ROUND_BATCH_LARGE_BYTES = 64 << 20       # <= 64 MiB: two dispatches


def fuse_rounds(fns):
    """Compose consecutive round bodies into one program body.

    Each ``fn`` is a carry -> carry function written to run inside
    ``shard_map``; the fusion is plain sequential composition, so the
    fused program executes the exact same op sequence in the exact same
    order as the unfused rounds — per-algorithm chunk layouts (and float
    summation order) are preserved bit-identically, only the dispatch
    count changes."""
    fns = tuple(fns)
    if not fns:
        raise ValueError("fuse_rounds on empty round list")
    if len(fns) == 1:
        return fns[0]

    def fused(carry):
        for fn in fns:
            carry = fn(carry)
        return carry

    return fused


def auto_round_batch(payload_bytes: int, num_rounds: int) -> int:
    """Pick the round-batch factor from the payload size.

    Small payloads collapse to 1–2 dispatches per chunk (per-operation
    setup cost is the whole story); large payloads keep per-round
    dispatch so the chunk pipeline can overlap rounds across chunks."""
    if num_rounds <= 1:
        return 1
    if payload_bytes <= ROUND_BATCH_SMALL_BYTES:
        return num_rounds                       # one dispatch per chunk
    if payload_bytes <= ROUND_BATCH_LARGE_BYTES:
        return -(-num_rounds // 2)              # two dispatches per chunk
    return 1                                    # full per-round pipelining


def allreduce_under_shard_map(x, mesh, axis: str, algorithm: str = "ring"):
    """Allreduce `x` (sharded on `axis`'s data dim) with a user schedule;
    output is the allreduced value, still sharded the same way — directly
    comparable to ``jax.lax.psum`` in tests and the Fig-13 benchmark.

    The (algorithm, axis size) pair is validated eagerly: power-of-two-
    only algorithms fall back to ring with a warning on other sizes."""
    algorithm = resolve_algorithm(algorithm, dict(mesh.shape)[axis])
    fn = ALGORITHMS[algorithm]

    def body(xs):
        return fn(xs, axis)

    return compat.shard_map(body, mesh=mesh, in_specs=P(axis),
                            out_specs=P(axis))(x)
