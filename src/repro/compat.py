"""Version-tolerant wrappers for jax APIs that moved between releases.

The repo targets the current public surface (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.set_mesh``,
``jax.sharding.get_abstract_mesh``); older installed versions (0.4.x)
keep the same machinery under ``jax.experimental`` / ``jax._src.mesh``.
Everything that needs one of these goes through this module so the
version probe lives in exactly one place.
"""
from __future__ import annotations

import contextlib

import jax

# -- shard_map ---------------------------------------------------------------
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # pre-0.5: public home was jax.experimental, knob was check_rep
    from jax.experimental.shard_map import shard_map as _shard_map_experimental

    def shard_map(f, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map_experimental(f, **kwargs)


# -- axis queries ------------------------------------------------------------
def axis_size(axis_name) -> int:
    """``jax.lax.axis_size`` (static size of a named mapped axis); on
    0.4.x resolved from the tracing-time axis environment."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    from jax._src import core
    return core.get_axis_env().axis_size(axis_name)


def pcast(x, axis_names, *, to: str = "varying"):
    """``jax.lax.pcast`` (explicit varying/unvarying marking inside
    shard_map).  Older jax treats everything inside shard_map as
    device-varying already, so the cast is the identity there."""
    fn = getattr(jax.lax, "pcast", None)
    if fn is not None:
        return fn(x, axis_names, to=to)
    return x


# -- mesh construction -------------------------------------------------------
def _auto_axis_types(n: int):
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return None
    return (axis_type.Auto,) * n


def make_mesh(axis_shapes, axis_names) -> jax.sharding.Mesh:
    """jax.make_mesh with Auto axis types where the kwarg exists."""
    axis_shapes, axis_names = tuple(axis_shapes), tuple(axis_names)
    types = _auto_axis_types(len(axis_names))
    if types is not None:
        return jax.make_mesh(axis_shapes, axis_names, axis_types=types)
    return jax.make_mesh(axis_shapes, axis_names)


# -- current-mesh context ----------------------------------------------------
def current_mesh():
    """The mesh set by ``set_mesh`` (or None outside any mesh context).

    Returns whatever mesh object is usable as ``shard_map``'s ``mesh=``
    argument on this jax version: the abstract mesh on ≥0.5, the
    concrete mesh inside a ``with mesh`` / ``set_mesh`` block on 0.4.x.
    """
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        return getter()
    from jax._src import mesh as mesh_lib
    concrete = mesh_lib.thread_resources.env.physical_mesh
    if concrete is not None and not concrete.empty:
        return concrete
    abstract_getter = getattr(mesh_lib, "get_abstract_mesh", None)
    abstract_cls = getattr(jax.sharding, "AbstractMesh", None)
    if abstract_getter is not None and abstract_cls is not None:
        abstract = abstract_getter()
        # early 0.4.x returns a sentinel tuple when no mesh is set
        if isinstance(abstract, abstract_cls):
            return abstract
    return None


@contextlib.contextmanager
def set_mesh(mesh: jax.sharding.Mesh):
    """``with jax.set_mesh(mesh)``, or its 0.4.x equivalent: enter the
    concrete-mesh resource context AND publish the abstract mesh so
    ``current_mesh`` readers see it during tracing."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        with setter(mesh):
            yield mesh
        return
    from jax._src import mesh as mesh_lib
    with mesh_lib.set_abstract_mesh(mesh.abstract_mesh), mesh:
        yield mesh
