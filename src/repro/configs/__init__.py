"""Architecture configs (one module per assigned architecture) + registry."""
from repro.configs.base import (
    ModelConfig,
    MoEConfig,
    SSMConfig,
    register,
    get_config,
    list_configs,
)
from repro.configs import shapes  # noqa: F401

# Import every architecture module so registration side effects run.
from repro.configs import (  # noqa: F401
    qwen2_0_5b,
    qwen2_5_3b,
    smollm_360m,
    llama3_405b,
    granite_moe_3b_a800m,
    grok1_314b,
    zamba2_1_2b,
    whisper_tiny,
    pixtral_12b,
    mamba2_1_3b,
)

__all__ = [
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "register",
    "get_config",
    "list_configs",
    "shapes",
]
