"""Model configuration dataclasses + architecture registry."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Sequence


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_d_ff: int
    capacity_factor: float = 1.25
    # tokens are dispatched in groups to bound the dispatch-tensor size
    group_size: int = 4096
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    chunk_size: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1
    a_init_range: tuple[float, float] = (1.0, 16.0)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None    # default d_model // num_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    rms_norm_eps: float = 1e-6
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    # hybrid (zamba2-style): a shared-parameter attention block is applied
    # after every `shared_attn_every` ssm blocks, with per-site LoRA deltas.
    shared_attn_every: int = 0
    shared_attn_lora_rank: int = 0
    # encoder-decoder (whisper-style)
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_frames: int = 0         # stub audio frontend sequence length
    learned_pos_embed: bool = False  # decoder learned positions (whisper)
    max_position_embeddings: int = 1 << 20
    # modality frontend stub: model consumes precomputed embeddings appended
    # to the token embeddings (pixtral patch embeds)
    frontend_stub: str | None = None  # None | "audio" | "vision"
    # attention implementation: "xla" (chunked online-softmax) |
    # "xla_blockskip" (causal lower-triangular block schedule, ~2× fewer
    # attention FLOPs) | "pallas"
    attention_impl: str = "xla"
    # pad attention heads up to a multiple of this so the head dim shards
    # over the tensor axis (zero-padded weights receive exactly zero
    # gradient — model is mathematically unchanged; see EXPERIMENTS §Perf)
    pad_heads_to: int = 0
    attention_chunk: int = 1024
    decode_chunk: int = 4096
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # remat: "none" | "full" | "dots" | "subblock" | "attn_only"
    remat_policy: str = "full"
    # LM loss: "plain" ([B,S,V] f32 logits) | "chunked_vocab" (online-
    # softmax over vocab blocks; avoids the full logits materialization)
    loss_impl: str = "plain"
    loss_vocab_chunk: int = 8192
    # KV cache storage: "bf16" | "int8" (per-position-channel scales;
    # halves the decode cache stream — the dominant decode memory term)
    kv_cache_dtype: str = "bf16"
    # sharding rule overrides for this arch (logical axis -> candidates)
    sharding_overrides: Mapping[str, Sequence[tuple[str, ...]]] | None = None
    # long-context applicability (full-attention archs skip long_500k)
    supports_long_context: bool = False
    # logit softcap (grok uses 30.0)
    logit_softcap: float = 0.0

    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    # Analytical parameter / FLOP counts (used for roofline MODEL_FLOPS)
    # ------------------------------------------------------------------
    def param_count(self) -> int:
        from repro.models import registry
        return registry.param_count(self)

    def active_param_count(self) -> int:
        from repro.models import registry
        return registry.param_count(self, active_only=True)


_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str, **overrides: Any) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    cfg = _REGISTRY[name]()
    if overrides:
        cfg = cfg.with_overrides(**overrides)
    return cfg


def list_configs() -> list[str]:
    return sorted(_REGISTRY)
