"""granite-moe-3b-a800m — 32L d_model=1536 24H (GQA kv=8) per-expert
d_ff=512 vocab=49155, MoE 40 experts top-8.

[hf:ibm-granite/granite-3.0-3b-a800m-base; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("granite-moe-3b-a800m")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        num_layers=32,
        d_model=1536,
        num_heads=24,
        num_kv_heads=8,
        d_ff=512,               # per-expert ffn width
        vocab_size=49155,
        qkv_bias=False,
        tie_embeddings=True,
        rope_theta=10000.0,
        rms_norm_eps=1e-6,
        moe=MoEConfig(
            num_experts=40,
            top_k=8,
            expert_d_ff=512,
            capacity_factor=1.25,
            group_size=512,
        ),
    )
