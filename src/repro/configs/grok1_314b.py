"""grok-1-314b — 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2.

[hf:xai-org/grok-1; unverified]
"""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("grok-1-314b")
def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b",
        family="moe",
        num_layers=64,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=32768,             # per-expert ffn width
        vocab_size=131072,
        qkv_bias=False,
        tie_embeddings=False,
        rope_theta=10000.0,
        rms_norm_eps=1e-5,
        logit_softcap=30.0,
        moe=MoEConfig(
            num_experts=8,
            top_k=2,
            expert_d_ff=32768,
            capacity_factor=1.25,
            group_size=1024,
        ),
    )
