"""llama3-405b — 126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.

GQA, 128k vocab. [arXiv:2407.21783; unverified]
"""
from repro.configs.base import ModelConfig, register


@register("llama3-405b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b",
        family="dense",
        num_layers=126,
        d_model=16384,
        num_heads=128,
        num_kv_heads=8,
        d_ff=53248,
        vocab_size=128256,
        qkv_bias=False,
        tie_embeddings=False,
        rope_theta=500_000.0,
        rms_norm_eps=1e-5,
        remat_policy="full",
    )
