"""mamba2-1.3b — 48L d_model=2048, attention-free, vocab=50280,
ssm_state=128. SSD (state-space duality) blocks.

[arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig, SSMConfig, register


@register("mamba2-1.3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        num_layers=48,
        d_model=2048,
        num_heads=0,              # attention-free
        num_kv_heads=0,
        d_ff=0,                   # no separate MLP; SSD block carries the FFN
        vocab_size=50280,
        tie_embeddings=True,
        rms_norm_eps=1e-5,
        ssm=SSMConfig(d_state=128, expand=2, head_dim=64, chunk_size=256),
        supports_long_context=True,
    )
