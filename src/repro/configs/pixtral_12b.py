"""pixtral-12b — 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.

Pixtral-ViT vision frontend is a STUB (``input_specs()`` provides
precomputed patch embeddings); this config is the mistral-nemo-style
multimodal decoder backbone. [hf:mistralai/Pixtral-12B-2409; unverified]
"""
from repro.configs.base import ModelConfig, register


@register("pixtral-12b")
def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b",
        family="vlm",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        qkv_bias=False,
        tie_embeddings=False,
        rope_theta=1_000_000_000.0,
        rms_norm_eps=1e-5,
        frontend_stub="vision",
    )
