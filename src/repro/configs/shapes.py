"""Assigned input shapes + ShapeDtypeStruct ``input_specs`` builders.

Shapes (LM transformer family — seq_len × global_batch):

* ``train_4k``     seq_len=4 096,   global_batch=256   (training)
* ``prefill_32k``  seq_len=32 768,  global_batch=32    (inference-prefill)
* ``decode_32k``   seq_len=32 768,  global_batch=128   (inference-decode:
  one new token against a KV cache of seq_len)
* ``long_500k``    seq_len=524 288, global_batch=1     (long-context decode;
  SSM/hybrid archs only — pure full-attention archs skip, see DESIGN.md)

``decode_*`` / ``long_*`` lower ``serve_step``; the others lower
``train_step`` (``prefill_32k`` lowers ``prefill_step``).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_shape(name: str) -> ShapeSpec:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether (arch × shape) is an assigned cell; reason when not."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "long_500k requires sub-quadratic attention (SSM/hybrid only)"
    return True, ""


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    No device allocation: these are fed to ``jax.jit(...).lower()``.
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        S_text = S
        batch: dict[str, jax.ShapeDtypeStruct] = {}
        if cfg.frontend_stub == "vision":
            # one image (precomputed patch embeddings) per sequence; total
            # sequence length stays at the assigned S
            from repro.models.transformer import VISION_PATCHES
            S_text = S - VISION_PATCHES
            batch["vision_embeds"] = sds((B, VISION_PATCHES, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = sds((B, S_text), jnp.int32)
        if cfg.is_encoder_decoder:
            batch["encoder_embeds"] = sds(
                (B, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
        if shape.kind == "train":
            batch["labels"] = sds((B, S_text), jnp.int32)
        return batch
    if shape.kind == "decode":
        # one new token against a cache of S (cache specs are built by the
        # model module itself; inputs are just the token + position)
        batch = {
            "tokens": sds((B, 1), jnp.int32),
            "pos": sds((B,), jnp.int32),
        }
        return batch
    raise ValueError(shape.kind)
