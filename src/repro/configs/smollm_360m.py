"""smollm-360m — 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.

Llama-architecture small model. [hf:HuggingFaceTB/SmolLM-360M; hf]
"""
from repro.configs.base import ModelConfig, register


@register("smollm-360m")
def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m",
        family="dense",
        num_layers=32,
        d_model=960,
        num_heads=15,
        num_kv_heads=5,
        d_ff=2560,
        vocab_size=49152,
        qkv_bias=False,
        tie_embeddings=True,
        rope_theta=10000.0,
        rms_norm_eps=1e-5,
    )
