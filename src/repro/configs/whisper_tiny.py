"""whisper-tiny — enc-dec, 4L encoder + 4L decoder, d_model=384 6H (kv=6)
d_ff=1536 vocab=51865. Conv audio frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings [B, frames, d_model].

[arXiv:2212.04356; unverified]
"""
from repro.configs.base import ModelConfig, register


@register("whisper-tiny")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="audio",
        num_layers=4,                 # decoder layers
        num_encoder_layers=4,
        d_model=384,
        num_heads=6,
        num_kv_heads=6,
        d_ff=1536,
        vocab_size=51865,
        qkv_bias=True,                # whisper uses biased q/v projections
        tie_embeddings=True,
        is_encoder_decoder=True,
        encoder_frames=1500,          # 30 s of audio after conv frontend
        learned_pos_embed=True,
        frontend_stub="audio",
        rms_norm_eps=1e-5,
        max_position_embeddings=65536,   # covers decode_32k positions
    )
