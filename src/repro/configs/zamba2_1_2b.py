"""zamba2-1.2b — 38 Mamba2 layers d_model=2048, shared full-attention block
(32H kv=32, d_ff=8192) applied every 6 SSM blocks with per-site LoRA,
vocab=32000, ssm_state=64.

Hybrid Mamba2 + shared attention. [arXiv:2411.15242; hf]
"""
from repro.configs.base import ModelConfig, SSMConfig, register


@register("zamba2-1.2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        num_layers=38,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=32000,
        qkv_bias=False,
        tie_embeddings=True,
        rope_theta=10000.0,
        rms_norm_eps=1e-5,
        ssm=SSMConfig(d_state=64, expand=2, head_dim=64, chunk_size=256),
        shared_attn_every=6,
        shared_attn_lora_rank=128,
        supports_long_context=True,
    )
