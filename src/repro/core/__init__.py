"""The paper's progress extensions, as a Python/JAX runtime layer."""
from repro.core.engine import (
    DONE,
    NOPROGRESS,
    PENDING,
    AsyncThing,
    ProgressEngine,
    Stream,
    Subsystem,
    global_engine,
    reset_global_engine,
)
from repro.core.request import (
    CancelledError,
    CompletionCounter,
    GeneralizedRequest,
    PollRequest,
    Request,
    request_of,
)
from repro.core.executor import ProgressExecutor
from repro.core.task_class import TaskGraph, TaskQueue
from repro.core.events import CompletionWatcher, EventQueue
from repro.core.futures import chain, io_future, jax_future
from repro.core.continuations import (
    DEFERRED,
    INLINE,
    Continuation,
    ContinuationQueue,
)
from repro.core import stats
from repro.core import debug
from repro.core.debug import (
    HANDLES,
    LOCK_GRAPH,
    HandleTracker,
    LifecycleError,
    LockOrderError,
    LockOrderGraph,
    OrderedLock,
    debug_enabled,
    set_debug,
)

__all__ = [
    "DONE", "NOPROGRESS", "PENDING",
    "AsyncThing", "ProgressEngine", "Stream", "Subsystem",
    "global_engine", "reset_global_engine",
    "CancelledError", "CompletionCounter", "GeneralizedRequest",
    "PollRequest", "Request", "request_of",
    "ProgressExecutor",
    "TaskGraph", "TaskQueue",
    "CompletionWatcher", "EventQueue",
    "INLINE", "DEFERRED", "Continuation", "ContinuationQueue",
    "chain", "io_future", "jax_future",
    "stats",
    "debug", "debug_enabled", "set_debug",
    "OrderedLock", "LockOrderError", "LockOrderGraph", "LOCK_GRAPH",
    "HandleTracker", "LifecycleError", "HANDLES",
]
