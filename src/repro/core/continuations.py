"""MPI Continuations on the progress engine (paper §4.6 direction).

*Callback-based Completion Notification using MPI Continuations*
(Schuchart et al.) attaches callbacks to requests so completion *pushes*
into the application instead of being pulled by wait/test loops; the
MPICH-extensions prototyping work (Zhou et al.) folds the same idea into
the stream/progress machinery this repo reproduces.  This module is that
layer for ``repro.core``: a ``ContinuationQueue`` watches requests from
one poll hook (task-class style, one sweep per progress call) and runs
the attached continuation exactly once per request, under one of the two
execution policies both papers distinguish:

* ``INLINE``   — the continuation executes on the progress thread, inside
  the sweep that observed completion (lowest latency; the callback must
  be lightweight, it runs in the progress path);
* ``DEFERRED`` — completion only moves the continuation to a *ready*
  list; the queue's owner drains it outside the progress path
  (``drain(max_items)`` gives bounded-drain backpressure).  A
  ``ProgressExecutor`` can adopt a deferred queue so its workers drain
  between polls (§4.4 composition).

Failure continuations are first-class: a request that completed via
``Request.fail`` routes to ``on_error`` (falling back to the normal
callback, which can inspect ``request.failed``/``request.exception``).
``then``/``when_all``/``when_any``/``node`` chain continuations so DAG
dependencies (TaskGraph nodes) become completion-driven instead of
polled.

Exactly-once: a continuation lives in exactly one container (pending →
ready → gone); the move happens under the queue lock and execution only
after removal, so concurrent sweeps/drains can never fire it twice.
"""
from __future__ import annotations

import collections
import threading
from typing import Any, Callable, Iterable, Optional

from repro.core import debug
from repro.core.engine import DONE, NOPROGRESS, ProgressEngine, Stream
from repro.core.request import CompletionCounter, PollRequest, Request

INLINE = "inline"
DEFERRED = "deferred"
POLICIES = (INLINE, DEFERRED)


class Continuation:
    """One attached callback. ``request`` may be any request-like object
    exposing ``is_complete`` (and optionally ``failed``) — ``Request``,
    ``PollRequest``, ``CompletionCounter``, a wait-set gate, ..."""

    __slots__ = ("request", "callback", "on_error")

    def __init__(self, request, callback, on_error=None):
        self.request = request
        self.callback = callback
        self.on_error = on_error


class ContinuationQueue:
    """Attach continuations to requests; fire them on completion.

    Registers (lazily) ONE async task on ``stream`` that sweeps pending
    continuations with side-effect-free ``is_complete`` reads — the same
    Fig-12 cost model as ``CompletionWatcher`` — and returns ``DONE``
    whenever nothing is pending, so an idle queue costs the engine
    nothing at all (no perpetual task, no idle spins).

    Counters (snapshotted by ``repro.core.stats``):

    * ``enqueued`` — continuations attached
    * ``executed`` — continuations run (success or failure path)
    * ``deferred`` — continuations that went through the ready list
    * ``failed``   — failure-path executions + callbacks that raised
    """

    def __init__(self, engine: ProgressEngine,
                 stream: Optional[Stream] = None, *,
                 policy: str = DEFERRED, name: str = "cont"):
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, got {policy!r}")
        self.engine = engine
        self.stream = stream
        self.policy = policy
        self.name = name
        self._lock = debug.make_lock("ContinuationQueue._lock")
        self._pending: list[Continuation] = []
        self._ready: collections.deque[Continuation] = collections.deque()
        # thread idents currently inside drain(): a continuation body
        # calling drain() on its own queue would recurse through
        # _execute forever (or deadlock on backpressure) — detect and
        # raise instead
        self._draining: set[int] = set()
        self._registered = False
        self._closed = False
        self.enqueued = 0
        self.executed = 0
        self.deferred = 0
        self.failed = 0
        self.cancelled = 0
        # bounded: a recurring failure on a long-lived queue must not
        # accumulate exception objects (and their frames) forever
        self.callback_errors: collections.deque[BaseException] = \
            collections.deque(maxlen=256)
        engine.continuation_queues.append(self)

    # -- attachment --------------------------------------------------------
    def attach(self, request, callback: Callable[[Any], None],
               on_error: Callable[[Any], None] | None = None) -> Continuation:
        """Fire ``callback(request)`` exactly once when ``request``
        completes; if it completed via ``fail``, fire ``on_error(request)``
        instead (when given).  A request already complete at attach time
        fires immediately (INLINE, on this thread) or on the next drain
        (DEFERRED) — it never gets lost."""
        cont = Continuation(request, callback, on_error)
        run_now = False
        with self._lock:
            if self._closed:
                raise RuntimeError(f"continuation queue {self.name!r} is closed")
            self.enqueued += 1
            if request.is_complete:
                if self.policy == INLINE:
                    run_now = True
                else:
                    self._ready.append(cont)
                    self.deferred += 1
            else:
                self._pending.append(cont)
                if not self._registered:
                    self._registered = True
                    self.engine.async_start(self._poll, None, self.stream)
        if run_now:
            self._execute(cont)
        return cont

    def attach_counter(self, counter: CompletionCounter,
                       callback: Callable[[Any], None],
                       on_error: Callable[[Any], None] | None = None) -> Continuation:
        """Continuation on a wait-set aggregate: fires once when every
        request behind the ``CompletionCounter`` has completed."""
        return self.attach(counter, callback, on_error)

    # -- chaining ----------------------------------------------------------
    def then(self, request, fn: Callable[[Any], Any], *,
             on_error: Callable[[BaseException], Any] | None = None) -> Request:
        """Chain: returns a Request that completes with ``fn(value)`` once
        ``request`` completes.  Failures propagate (the returned request
        fails with the same exception) unless ``on_error`` recovers by
        returning a substitute value; ``fn`` raising fails the result."""
        out = Request(tag="then")

        def _fire(req):
            exc = getattr(req, "exception", None)
            if getattr(req, "failed", False) and exc is not None:
                if on_error is None:
                    out.fail(exc)
                    return
                try:
                    out.complete(on_error(exc))
                except BaseException as e:  # noqa: BLE001
                    out.fail(e)
                return
            try:
                out.complete(fn(req.value()))
            except BaseException as e:  # noqa: BLE001
                out.fail(e)

        self.attach(request, _fire)
        return out

    def when_all(self, requests: Iterable[Request]) -> Request:
        """Request completing with ``[r.value() ...]`` once ALL complete;
        fails with the first (by index) failed request's exception."""
        reqs = list(requests)
        out = Request(tag="when_all")
        if not reqs:
            out.complete([])
            return out
        gate = CompletionCounter(reqs).as_request()

        def _fire(_):
            bad = next((r for r in reqs if r.failed), None)
            if bad is not None:
                out.fail(bad.exception)
            else:
                out.complete([r.value() for r in reqs])

        self.attach(gate, _fire)
        return out

    def when_any(self, requests: Iterable[Request]) -> Request:
        """Request completing with ``(index, request)`` of the first
        completed member (lowest index wins ties, like ``wait_any``)."""
        reqs = list(requests)
        if not reqs:
            raise ValueError("when_any on empty request list")
        out = Request(tag="when_any")
        gate = PollRequest(lambda: any(r.is_complete for r in reqs),
                           tag="when_any_gate")

        def _fire(_):
            i, r = next((i, r) for i, r in enumerate(reqs) if r.is_complete)
            if r.failed:
                out.fail(r.exception)
            else:
                out.complete((i, r))

        self.attach(gate, _fire)
        return out

    def node(self, fn: Callable[..., Any],
             deps: Iterable[Request] = ()) -> Request:
        """A TaskGraph node as a continuation chain: run
        ``fn(*dep_values)`` once every dependency completes.  A failed
        dependency fails the node (transitively, through chains of
        ``node``/``then``) without ever running ``fn`` — the same
        propagation contract as ``TaskGraph``, but completion-driven."""
        deps = list(deps)
        if not deps:
            root = Request(tag="node_root")
            root.complete(())
            return self.then(root, lambda _: fn())
        return self.then(self.when_all(deps), lambda vals: fn(*vals))

    # -- the detection sweep ----------------------------------------------
    def _poll(self, thing) -> str:
        with self._lock:
            fired, still = [], []
            for c in self._pending:           # one O(n) partition, not
                if c.request.is_complete:     # per-item list.remove
                    fired.append(c)
                else:
                    still.append(c)
            if fired:
                self._pending = still
                if self.policy == DEFERRED:
                    self._ready.extend(fired)
                    self.deferred += len(fired)
                    fired = []
            alive = bool(self._pending)
            if not alive:
                self._registered = False
        for c in fired:                      # INLINE: run on this thread
            self._execute(c)
        return NOPROGRESS if alive else DONE

    # -- deferred drain ----------------------------------------------------
    def drain(self, max_items: int | None = None) -> int:
        """Execute up to ``max_items`` ready continuations (all if None)
        on the calling thread.  Bounded drains are the backpressure knob:
        a latency-sensitive owner drains a few per iteration instead of
        being flooded by a completion burst.

        Re-entrancy is an error: a continuation body calling ``drain()``
        on its own queue raises RuntimeError (recorded in
        ``callback_errors`` by the enclosing ``_execute``) instead of
        recursing unboundedly — chain follow-up work with ``then``/
        ``attach`` and let the *outer* drain run it."""
        me = threading.get_ident()
        with self._lock:
            if me in self._draining:
                raise RuntimeError(
                    f"re-entrant drain on continuation queue "
                    f"{self.name!r}: a continuation body called drain() "
                    f"on the queue executing it — attach follow-up work "
                    f"instead of draining inline")
            self._draining.add(me)
        n = 0
        try:
            while max_items is None or n < max_items:
                with self._lock:
                    if not self._ready:
                        break
                    cont = self._ready.popleft()
                self._execute(cont)
                n += 1
        finally:
            with self._lock:
                self._draining.discard(me)
        return n

    def _execute(self, cont: Continuation) -> None:
        req = cont.request
        req_failed = bool(getattr(req, "failed", False))
        fn = cont.on_error if (req_failed and cont.on_error is not None) \
            else cont.callback
        if req_failed:
            self.failed += 1
        try:
            fn(req)
        except BaseException as exc:  # noqa: BLE001
            # a continuation must never wedge the progress path or a
            # drain loop: record, count (once per continuation), continue
            if not req_failed:
                self.failed += 1
            self.callback_errors.append(exc)
        finally:
            self.executed += 1

    # -- introspection / lifecycle ----------------------------------------
    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._pending)

    @property
    def ready(self) -> int:
        with self._lock:
            return len(self._ready)

    def close(self, *, run_ready: bool = True) -> None:
        """Deterministic shutdown: refuse new attachments, run (or drop)
        everything already ready, and cancel pending continuations whose
        requests never completed (counted in ``cancelled``).  The
        detection task notices the empty pending list and retires on the
        next sweep."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self.cancelled += len(self._pending)
            self._pending.clear()
            if not run_ready:
                self.cancelled += len(self._ready)
                self._ready.clear()
        if run_ready:
            self.drain()
        try:
            self.engine.continuation_queues.remove(self)
        except ValueError:
            pass

    def __repr__(self):
        return (f"ContinuationQueue({self.name!r}, policy={self.policy}, "
                f"pending={self.pending}, ready={self.ready})")
