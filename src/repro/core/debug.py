"""Opt-in runtime invariant checkers for the progress stack (REPRO_DEBUG=1).

The static pass in ``repro.analysis.progress_lint`` proves progress-safety
rules where call order is visible in a function body; this module is the
runtime half, for the orderings only an execution can exhibit:

* **Lock order** — :func:`make_lock` hands out plain ``threading.Lock``
  in production and an :class:`OrderedLock` under ``REPRO_DEBUG=1``.
  Ordered locks report every acquisition to a process-wide
  :class:`LockOrderGraph` (the DAG of *outer lock -> inner lock* edges,
  per thread); an acquisition that would close a cycle raises
  :class:`LockOrderError` **before** blocking, so an AB/BA inversion is
  caught on first sight without needing the deadlock interleaving to
  actually fire.  The observed DAG can be snapshotted, persisted and
  diffed (:meth:`LockOrderGraph.snapshot`, :func:`diff_order`) so tests
  pin the engine's acquisition order and flag drift.

* **Handle lifecycle** — the MPI persistent-request state machine
  (``*_init -> start -> complete -> (rebuild) -> close``) is declared
  once in :data:`LIFECYCLE_TRANSITIONS` / :data:`LIFECYCLE_VIOLATIONS`
  and enforced twice: statically by the lint (which loads this table)
  and dynamically by :class:`HandleTracker`, a weak-keyed side table of
  per-handle states fed by hooks in ``PersistentCollective``,
  ``P2PChannel`` and ``FsdpReducer``.  Illegal events (double-start,
  start-after-invalidate-without-rebuild, wait-without-start,
  use-after-close) raise :class:`LifecycleError`.

Everything here is stdlib-only and dormant unless ``REPRO_DEBUG`` is set
(or a test flips :func:`set_debug`): ``make_lock`` returns an untouched
``threading.Lock`` and the hook helpers are a single ``if`` on the hot
path, so the production tax is one truthiness check per event.
"""
from __future__ import annotations

import json
import os
import threading
import weakref

_DEBUG = os.environ.get("REPRO_DEBUG", "") not in ("", "0", "false", "False")


def debug_enabled() -> bool:
    return _DEBUG


def set_debug(on: bool) -> bool:
    """Flip the checkers at runtime (tests); returns the previous value.

    Lock instrumentation is chosen at *construction* time — only objects
    built after the flip pick up :class:`OrderedLock`s — while the
    lifecycle hooks consult the flag on every event."""
    global _DEBUG
    prev = _DEBUG
    _DEBUG = bool(on)
    return prev


class LockOrderError(RuntimeError):
    """An acquisition would close a cycle in the lock-order graph."""


class LifecycleError(RuntimeError):
    """A persistent handle received an event its state forbids."""


# ---------------------------------------------------------------------------
# Lock-order graph
# ---------------------------------------------------------------------------

class LockOrderGraph:
    """Process-wide acquisition DAG: edge ``A -> B`` means some thread
    acquired ``B`` while holding ``A``.  Edges accumulate over the whole
    run; a new edge whose reverse path already exists is a potential
    deadlock regardless of whether the two threads ever actually race,
    which is exactly why the check happens *before* blocking."""

    def __init__(self):
        self._mu = threading.Lock()
        self._edges: dict[str, set[str]] = {}
        self._witness: dict[tuple[str, str], int] = {}  # edge -> count
        self._tls = threading.local()

    def _held(self) -> list[str]:
        held = getattr(self._tls, "held", None)
        if held is None:
            held = self._tls.held = []
        return held

    def _path(self, src: str, dst: str) -> list[str] | None:
        """DFS path src -> dst over recorded edges (caller holds _mu)."""
        stack, seen = [(src, [src])], {src}
        while stack:
            node, path = stack.pop()
            if node == dst:
                return path
            for nxt in self._edges.get(node, ()):
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append((nxt, path + [nxt]))
        return None

    def check(self, name: str) -> None:
        """Record ``held -> name`` edges; raise on cycle formation.

        Runs before the underlying lock blocks: the inversion is
        reported the first time the reversed order is *attempted*, not
        when two threads finally interleave into the deadlock."""
        held = self._held()
        if not held:
            return
        with self._mu:
            for outer in held:
                if outer == name:
                    continue          # re-acquire: the Lock itself deadlocks
                back = self._path(name, outer)
                if back is not None:
                    cycle = " -> ".join([outer] + back)
                    raise LockOrderError(
                        f"lock-order inversion: acquiring {name!r} while "
                        f"holding {outer!r}, but the established order is "
                        f"{cycle} (cycle).  One of the two call paths must "
                        f"release before acquiring, or the order must be "
                        f"made consistent.")
                edge = (outer, name)
                if edge not in self._witness:
                    self._edges.setdefault(outer, set()).add(name)
                self._witness[edge] = self._witness.get(edge, 0) + 1

    def push(self, name: str) -> None:
        self._held().append(name)

    def pop(self, name: str) -> None:
        held = self._held()
        for i in range(len(held) - 1, -1, -1):  # non-LIFO release is legal
            if held[i] == name:
                del held[i]
                return

    # -- persistence / diffing --------------------------------------------
    def snapshot(self) -> dict[str, list[str]]:
        """The observed order as ``{outer: [inner, ...]}``, sorted."""
        with self._mu:
            return {k: sorted(v) for k, v in sorted(self._edges.items())}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2, sort_keys=True)

    def reset(self) -> None:
        with self._mu:
            self._edges.clear()
            self._witness.clear()


def load_order(path: str) -> dict[str, list[str]]:
    with open(path) as f:
        return {k: sorted(v) for k, v in json.load(f).items()}


def diff_order(prev: dict[str, list[str]],
               cur: dict[str, list[str]]) -> dict[str, list[tuple[str, str]]]:
    """Edge-level diff of two snapshots: ``{"added": [...], "removed":
    [...]}`` — tests persist the observed order and fail on drift."""
    def edges(d):
        return {(a, b) for a, bs in d.items() for b in bs}
    p, c = edges(prev), edges(cur)
    return {"added": sorted(c - p), "removed": sorted(p - c)}


LOCK_GRAPH = LockOrderGraph()


class OrderedLock:
    """``threading.Lock`` wrapper reporting to the shared order graph.

    Same interface as ``Lock`` (``acquire``/``release``/context manager/
    ``locked``); the cycle check precedes the blocking acquire."""

    __slots__ = ("name", "_lock", "_graph")

    def __init__(self, name: str, graph: LockOrderGraph | None = None):
        self.name = name
        self._lock = threading.Lock()
        self._graph = graph if graph is not None else LOCK_GRAPH

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        self._graph.check(self.name)
        got = self._lock.acquire(blocking, timeout)
        if got:
            self._graph.push(self.name)
        return got

    def release(self) -> None:
        self._lock.release()
        self._graph.pop(self.name)

    def locked(self) -> bool:
        return self._lock.locked()

    def __enter__(self) -> "OrderedLock":
        self.acquire()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.release()

    def __repr__(self):
        return f"OrderedLock({self.name!r}, locked={self.locked()})"


def make_lock(name: str):
    """A hot-path lock: plain ``threading.Lock`` in production, an
    :class:`OrderedLock` on the shared graph under ``REPRO_DEBUG=1``.
    ``name`` should be ``Class._attr`` — order is tracked per *role*,
    not per instance, matching how deadlocks are reasoned about."""
    if _DEBUG:
        return OrderedLock(name)
    return threading.Lock()


# ---------------------------------------------------------------------------
# Handle lifecycle state machine
# ---------------------------------------------------------------------------

IDLE, ACTIVE, STALE, CLOSED = "idle", "active", "stale", "closed"

# The declared machine (MPI persistent-request semantics).  This table is
# the single source of truth: repro.analysis.progress_lint loads it for
# the static pass and HandleTracker enforces it at runtime.
LIFECYCLE_TRANSITIONS: dict[tuple[str, str], str] = {
    (IDLE, "start"): ACTIVE,
    (ACTIVE, "complete"): IDLE,       # wait()/cancel()/fail retired the start
    (ACTIVE, "wait"): IDLE,
    (IDLE, "invalidate"): STALE,
    (ACTIVE, "invalidate"): STALE,    # the in-flight start is failed
    (STALE, "invalidate"): STALE,
    (CLOSED, "invalidate"): CLOSED,   # the epoch may still hold a weakref
    (IDLE, "rebuild"): IDLE,
    (STALE, "rebuild"): IDLE,
    (IDLE, "close"): CLOSED,
    (ACTIVE, "close"): CLOSED,
    (STALE, "close"): CLOSED,
    (CLOSED, "close"): CLOSED,        # close is idempotent
}

# Illegal (state, event) pairs with their canonical names; anything in
# neither table is reported as a generic illegal event.
LIFECYCLE_VIOLATIONS: dict[tuple[str, str], str] = {
    (ACTIVE, "start"): "double-start",
    (STALE, "start"): "start-after-invalidate-without-rebuild",
    (CLOSED, "start"): "use-after-close",
    (CLOSED, "rebuild"): "use-after-close",
    (CLOSED, "wait"): "use-after-close",
    (CLOSED, "cancel"): "use-after-close",
    (ACTIVE, "rebuild"): "rebuild-with-active-start",
    (IDLE, "wait"): "wait-without-start",
    (STALE, "wait"): "wait-without-start",
}


class HandleTracker:
    """Weak-keyed per-handle lifecycle states.

    Handles register on construction (:meth:`track`) and report events
    from their public entry points; an event the declared machine
    forbids raises :class:`LifecycleError`.  The side table is weak so
    tracking never extends a handle's lifetime.

    Completion is observed lazily: nothing pushes an event when a start
    retires on a progress thread, so ``event(..., complete_probe=...)``
    lets an ACTIVE handle settle to IDLE first when the probe confirms
    the tracked start is complete (exactly the restartability rule
    ``PersistentCollective.start`` implements)."""

    def __init__(self):
        self._mu = threading.Lock()
        self._entries: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()
        self.violations = 0

    def track(self, handle, kind: str, state: str = IDLE) -> None:
        with self._mu:
            self._entries[handle] = [state, kind]

    def state(self, handle) -> str | None:
        with self._mu:
            entry = self._entries.get(handle)
            return entry[0] if entry is not None else None

    def event(self, handle, ev: str, *, kind: str = "handle",
              complete_probe=None, racing_invalidate: bool = False) -> str:
        """Apply ``ev`` to ``handle``; returns the new state.

        ``racing_invalidate=True`` tolerates the one benign interleaving
        production permits: a ``start`` that passed its epoch-version
        check before the invalidation hook landed may observe STALE here
        — the epoch fails that start through the request ``_fail_lock``,
        so the tracker transitions to ACTIVE instead of flagging it."""
        with self._mu:
            entry = self._entries.get(handle)
            if entry is None:
                entry = self._entries[handle] = [IDLE, kind]
            state = entry[0]
            if (state == ACTIVE and complete_probe is not None
                    and complete_probe()):
                state = entry[0] = IDLE
            if state == STALE and ev == "start" and racing_invalidate:
                entry[0] = ACTIVE
                return ACTIVE
            nxt = LIFECYCLE_TRANSITIONS.get((state, ev))
            if nxt is None:
                why = LIFECYCLE_VIOLATIONS.get(
                    (state, ev), f"illegal event {ev!r} in state {state!r}")
                self.violations += 1
                raise LifecycleError(
                    f"{entry[1]} lifecycle violation: {why} (event {ev!r} "
                    f"in state {state!r})")
            entry[0] = nxt
            return nxt

    def check_open(self, handle, op: str, *, kind: str = "handle") -> None:
        """Raise use-after-close for ``op`` on a CLOSED handle (for entry
        points that are not themselves lifecycle events)."""
        with self._mu:
            entry = self._entries.get(handle)
            if entry is not None and entry[0] == CLOSED:
                self.violations += 1
                raise LifecycleError(
                    f"{entry[1]} lifecycle violation: use-after-close "
                    f"({op!r} on a closed handle)")

    def reset(self) -> None:
        with self._mu:
            self._entries = weakref.WeakKeyDictionary()
            self.violations = 0


HANDLES = HandleTracker()


# -- hook helpers (the only calls production code makes) --------------------

def track_handle(handle, kind: str, state: str = IDLE) -> None:
    if _DEBUG:
        HANDLES.track(handle, kind, state)


def handle_event(handle, ev: str, **kw) -> None:
    if _DEBUG:
        HANDLES.event(handle, ev, **kw)


def handle_check_open(handle, op: str, *, kind: str = "handle") -> None:
    if _DEBUG:
        HANDLES.check_open(handle, op, kind=kind)
