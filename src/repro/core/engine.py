"""The collated, interoperable progress engine (paper §2.6, §3).

Paper API                      →  here
---------------------------------------------------------------
MPIX_Stream_create             →  Stream() / engine.stream()
MPIX_Stream_progress(stream)   →  engine.progress(stream)
MPIX_Async_start(fn, st, strm) →  engine.async_start(fn, st, stream)
MPIX_Async_spawn               →  AsyncThing.spawn(...)
MPIX_Async_get_state           →  AsyncThing.state
MPIX_ASYNC_DONE / NOPROGRESS   →  DONE / NOPROGRESS (PENDING alias)
subsystem hooks (Listing 1.1)  →  engine.register_subsystem(...)

Semantics faithfully kept:

* A Stream is a *serial execution context*: tasks attached to one stream
  are polled by at most one thread at a time (per-stream lock), and two
  different streams NEVER contend on a shared lock — the fix for the
  MPI_THREAD_MULTIPLE global-lock pathology the paper measures (§4.4).
* ``progress`` collates: subsystem hooks run in registration (priority)
  order and, like MPICH's Listing 1.1, later (expensive) subsystems are
  skipped once progress was made (short-circuit), controllable per call.
* ``spawn`` from inside a poll_fn defers enqueueing until after the poll
  sweep — no recursion, no queue mutation under iteration (§3.3).
* Poll functions must be lightweight; completion events can be emitted
  via ``repro.core.events`` instead of doing heavy work inline (§4.2).
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Any, Callable, Iterable, Optional

# poll_fn return codes (paper: MPIX_ASYNC_DONE / MPIX_ASYNC_NOPROGRESS)
DONE = "done"
NOPROGRESS = "noprogress"
PENDING = NOPROGRESS  # alias: the paper text uses PENDING in §3.3


class AsyncThing:
    """Opaque handle passed to poll functions (MPIX_Async_thing).

    Combines the user state (``MPIX_Async_get_state``) with the
    implementation-side context, and provides ``spawn`` (MPIX_Async_spawn):
    children are buffered and enqueued only after the current poll sweep,
    avoiding recursion and re-entrant queue mutation.
    """

    __slots__ = ("state", "poll_fn", "stream", "_spawned", "engine")

    def __init__(self, engine: "ProgressEngine", poll_fn, state, stream: "Stream"):
        self.engine = engine
        self.poll_fn = poll_fn
        self.state = state
        self.stream = stream
        self._spawned: list[AsyncThing] = []

    def spawn(self, poll_fn, state, stream: Optional["Stream"] = None) -> "AsyncThing":
        child = AsyncThing(self.engine, poll_fn, state,
                           stream if stream is not None else self.stream)
        self._spawned.append(child)
        return child


class Stream:
    """MPIX_Stream: a serial context with its own task list and lock."""

    _ids = itertools.count()

    def __init__(self, name: str = "", engine: "ProgressEngine" = None):
        self.id = next(Stream._ids)
        self.name = name or f"stream{self.id}"
        self.engine = engine
        self._lock = threading.Lock()
        self._tasks: list[AsyncThing] = []
        self._incoming: list[AsyncThing] = []
        self._incoming_lock = threading.Lock()
        self.polls = 0           # statistics
        self.completions = 0

    def _enqueue(self, thing: AsyncThing) -> None:
        # cross-thread additions land in _incoming; the polling thread
        # absorbs them — keeps the hot poll loop free of contention.
        with self._incoming_lock:
            self._incoming.append(thing)

    @property
    def pending(self) -> int:
        with self._incoming_lock:
            inc = len(self._incoming)
        return len(self._tasks) + inc

    def _poll_once(self) -> int:
        """One collated sweep over this stream's tasks. Returns #completed."""
        if not self._lock.acquire(blocking=False):
            # another thread is progressing this serial context; in the
            # paper's model this cannot happen (streams are serial), but
            # we make it safe rather than corrupt the task list.
            self._lock.acquire()
        try:
            with self._incoming_lock:
                if self._incoming:
                    self._tasks.extend(self._incoming)
                    self._incoming.clear()
            completed = 0
            spawned: list[AsyncThing] = []
            keep: list[AsyncThing] = []
            for thing in self._tasks:
                self.polls += 1
                rc = thing.poll_fn(thing)
                if thing._spawned:
                    spawned.extend(thing._spawned)
                    thing._spawned = []
                if rc == DONE:
                    completed += 1
                    self.completions += 1
                else:
                    keep.append(thing)
            self._tasks = keep
            # deferred enqueue of spawned children (MPIX_Async_spawn)
            for child in spawned:
                if child.stream is self:
                    self._tasks.append(child)
                else:
                    child.stream._enqueue(child)
            return completed
        finally:
            self._lock.release()


class Subsystem:
    """A progress hook à la MPICH Listing 1.1 (datatype engine /
    collectives / shmem / netmod).  ``poll`` returns True if progress was
    made.  ``cheap`` subsystems are always polled; expensive ones are
    skipped when an earlier subsystem already made progress."""

    def __init__(self, name: str, poll: Callable[[], bool], cheap: bool = True,
                 priority: int = 0):
        self.name = name
        self.poll = poll
        self.cheap = cheap
        self.priority = priority

    def __repr__(self):
        return f"Subsystem({self.name!r}, cheap={self.cheap})"


class ProgressEngine:
    """One engine per process (the paper's thesis: ONE progress engine
    collating every async subsystem, instead of one thread per library)."""

    def __init__(self):
        self.default_stream = Stream("default", self)   # MPIX_STREAM_NULL
        self._streams: list[Stream] = [self.default_stream]
        self._subsystems: list[Subsystem] = []
        self._lock = threading.Lock()

    # -- streams ---------------------------------------------------------
    def stream(self, name: str = "") -> Stream:
        s = Stream(name, self)
        with self._lock:
            self._streams.append(s)
        return s

    def free_stream(self, stream: Stream) -> None:
        if stream.pending:
            raise RuntimeError(f"{stream.name} has pending tasks")
        with self._lock:
            self._streams.remove(stream)

    # -- MPIX_Async ------------------------------------------------------
    def async_start(self, poll_fn: Callable[[AsyncThing], str],
                    extra_state: Any = None,
                    stream: Optional[Stream] = None) -> AsyncThing:
        s = stream if stream is not None else self.default_stream
        thing = AsyncThing(self, poll_fn, extra_state, s)
        s._enqueue(thing)
        return thing

    # -- subsystems (Listing 1.1) ------------------------------------------
    def register_subsystem(self, name: str, poll: Callable[[], bool],
                           cheap: bool = True, priority: int = 0) -> Subsystem:
        sub = Subsystem(name, poll, cheap, priority)
        with self._lock:
            self._subsystems.append(sub)
            self._subsystems.sort(key=lambda x: x.priority)
        return sub

    def unregister_subsystem(self, sub: Subsystem) -> None:
        with self._lock:
            self._subsystems.remove(sub)

    # -- progress ----------------------------------------------------------
    def progress(self, stream: Optional[Stream] = None, *,
                 skip_expensive_on_progress: bool = True) -> int:
        """MPIX_Stream_progress.

        Polls (a) the async tasks of ``stream`` (or the default stream)
        and (b) the registered subsystem hooks in priority order with the
        MPICH short-circuit: once progress is made, remaining *expensive*
        subsystems are skipped this round.
        """
        s = stream if stream is not None else self.default_stream
        made = s._poll_once()
        for sub in self._subsystems:
            if made and skip_expensive_on_progress and not sub.cheap:
                continue
            try:
                if sub.poll():
                    made += 1
            except Exception:
                # a subsystem failure must not take down global progress
                raise
        return made

    def progress_all(self) -> int:
        """Progress every stream (used by shutdown/finalize paths)."""
        made = 0
        with self._lock:
            streams = list(self._streams)
        for s in streams:
            made += s._poll_once()
        for sub in self._subsystems:
            if sub.poll():
                made += 1
        return made

    # -- waiting -----------------------------------------------------------
    def wait(self, request, stream: Optional[Stream] = None,
             timeout: float | None = None) -> Any:
        """MPI_Wait: drive progress until ``request.is_complete``."""
        t0 = time.monotonic()
        while not request.is_complete:
            self.progress(stream)
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError(f"wait timed out after {timeout}s")
        return request.value()

    def wait_all(self, requests: Iterable, stream: Optional[Stream] = None,
                 timeout: float | None = None) -> list:
        reqs = list(requests)
        t0 = time.monotonic()
        while not all(r.is_complete for r in reqs):
            self.progress(stream)
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError(f"wait_all timed out after {timeout}s")
        return [r.value() for r in reqs]

    def drain(self, stream: Optional[Stream] = None,
              timeout: float | None = None) -> None:
        """MPI_Finalize behaviour (Listing 1.2): progress until no pending
        tasks remain on the stream (or all streams if None)."""
        t0 = time.monotonic()
        if stream is not None:
            while stream.pending:
                self.progress(stream)
                if timeout is not None and time.monotonic() - t0 > timeout:
                    raise TimeoutError("drain timed out")
            return
        while any(s.pending for s in self._streams):
            self.progress_all()
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError("drain timed out")


# Process-global engine (most applications want exactly one).
_global_engine: ProgressEngine | None = None
_global_lock = threading.Lock()


def global_engine() -> ProgressEngine:
    global _global_engine
    if _global_engine is None:
        with _global_lock:
            if _global_engine is None:
                _global_engine = ProgressEngine()
    return _global_engine


def reset_global_engine() -> None:
    global _global_engine
    with _global_lock:
        _global_engine = None
