"""The collated, interoperable progress engine (paper §2.6, §3).

Paper API                      →  here
---------------------------------------------------------------
MPIX_Stream_create             →  Stream() / engine.stream()
MPIX_Stream_progress(stream)   →  engine.progress(stream)
MPIX_Async_start(fn, st, strm) →  engine.async_start(fn, st, stream)
MPIX_Async_spawn               →  AsyncThing.spawn(...)
MPIX_Async_get_state           →  AsyncThing.state
MPIX_ASYNC_DONE / NOPROGRESS   →  DONE / NOPROGRESS (PENDING alias)
subsystem hooks (Listing 1.1)  →  engine.register_subsystem(...)
MPI_Waitany / MPI_Waitsome     →  engine.wait_any / engine.wait_some
progress threads (§4.4)        →  repro.core.executor.ProgressExecutor
completion counting (§4.5)     →  repro.core.request.CompletionCounter
progress statistics (§4.1)     →  repro.core.stats.collect(engine)

Semantics faithfully kept:

* A Stream is a *serial execution context*: tasks attached to one stream
  are polled by at most one thread at a time (per-stream lock), and two
  different streams NEVER contend on a shared lock — the fix for the
  MPI_THREAD_MULTIPLE global-lock pathology the paper measures (§4.4).
* ``progress`` collates: subsystem hooks run in registration (priority)
  order and, like MPICH's Listing 1.1, later (expensive) subsystems are
  skipped once progress was made (short-circuit), controllable per call.
* A failing subsystem is isolated — unregistered and recorded on
  ``engine.subsystem_errors`` — rather than poisoning every subsequent
  ``progress`` call; pass ``strict=True`` to re-raise instead.
* ``spawn`` from inside a poll_fn defers enqueueing until after the poll
  sweep — no recursion, no queue mutation under iteration (§3.3).
* Poll functions must be lightweight; completion events can be emitted
  via ``repro.core.events`` instead of doing heavy work inline (§4.2).
* The wait family (``wait``/``wait_all``/``wait_any``/``wait_some``)
  drives progress from the calling thread — unless a running
  ``ProgressExecutor`` is attached, in which case callers yield the CPU
  and let the background workers make progress (§4.4 + §4.5).
"""
from __future__ import annotations

import itertools
import threading
import time
import warnings
from typing import Any, Callable, Iterable, Optional

# poll_fn return codes (paper: MPIX_ASYNC_DONE / MPIX_ASYNC_NOPROGRESS)
DONE = "done"
NOPROGRESS = "noprogress"
PENDING = NOPROGRESS  # alias: the paper text uses PENDING in §3.3

# How long a waiting thread sleeps per check when a background executor
# owns progress (keeps waiters off the stream locks entirely).
_WAIT_YIELD_S = 20e-6


class AsyncThing:
    """Opaque handle passed to poll functions (MPIX_Async_thing).

    Combines the user state (``MPIX_Async_get_state``) with the
    implementation-side context, and provides ``spawn`` (MPIX_Async_spawn):
    children are buffered and enqueued only after the current poll sweep,
    avoiding recursion and re-entrant queue mutation.
    """

    __slots__ = ("state", "poll_fn", "stream", "_spawned", "engine")

    def __init__(self, engine: "ProgressEngine", poll_fn, state, stream: "Stream"):
        self.engine = engine
        self.poll_fn = poll_fn
        self.state = state
        self.stream = stream
        self._spawned: list[AsyncThing] = []

    def spawn(self, poll_fn, state, stream: Optional["Stream"] = None) -> "AsyncThing":
        child = AsyncThing(self.engine, poll_fn, state,
                           stream if stream is not None else self.stream)
        self._spawned.append(child)
        return child


class Stream:
    """MPIX_Stream: a serial context with its own task list and lock."""

    _ids = itertools.count()

    def __init__(self, name: str = "", engine: "ProgressEngine" = None):
        self.id = next(Stream._ids)
        self.name = name or f"stream{self.id}"
        self.engine = engine
        self._lock = threading.Lock()
        self._tasks: list[AsyncThing] = []
        self._incoming: list[AsyncThing] = []
        self._incoming_lock = threading.Lock()
        self.polls = 0           # statistics (see repro.core.stats)
        self.completions = 0
        self.contention = 0      # _poll_once found the lock already held
        self.idle_spins = 0      # sweeps that polled tasks, completed none
        self.task_errors: list[BaseException] = []

    def _enqueue(self, thing: AsyncThing) -> None:
        # cross-thread additions land in _incoming; the polling thread
        # absorbs them — keeps the hot poll loop free of contention.
        with self._incoming_lock:
            self._incoming.append(thing)

    @property
    def pending(self) -> int:
        with self._incoming_lock:
            inc = len(self._incoming)
        return len(self._tasks) + inc

    def _poll_once(self) -> int:
        """One collated sweep over this stream's tasks. Returns #completed.

        A poll_fn that raises is dropped from the stream (recorded in
        ``task_errors``) before the exception propagates — a broken task
        must not wedge the serial context by re-raising every sweep.
        """
        if not self._lock.acquire(blocking=False):
            # another thread is progressing this serial context; in the
            # paper's model this cannot happen (streams are serial), but
            # we make it safe rather than corrupt the task list.
            self.contention += 1
            self._lock.acquire()
        try:
            with self._incoming_lock:
                if self._incoming:
                    self._tasks.extend(self._incoming)
                    self._incoming.clear()
            completed = 0
            polled = 0
            spawned: list[AsyncThing] = []
            keep: list[AsyncThing] = []
            try:
                for i, thing in enumerate(self._tasks):
                    self.polls += 1
                    polled += 1
                    try:
                        rc = thing.poll_fn(thing)
                    except BaseException as exc:
                        # drop the broken task, keep the rest intact
                        self.task_errors.append(exc)
                        keep.extend(self._tasks[i + 1:])
                        raise
                    if thing._spawned:
                        spawned.extend(thing._spawned)
                        thing._spawned = []
                    if rc == DONE:
                        completed += 1
                        self.completions += 1
                    else:
                        keep.append(thing)
            finally:
                self._tasks = keep
                # deferred enqueue of spawned children (MPIX_Async_spawn)
                for child in spawned:
                    if child.stream is self:
                        self._tasks.append(child)
                    else:
                        child.stream._enqueue(child)
                if polled and not completed:
                    self.idle_spins += 1
            return completed
        finally:
            self._lock.release()


class Subsystem:
    """A progress hook à la MPICH Listing 1.1 (datatype engine /
    collectives / shmem / netmod).  ``poll`` returns True if progress was
    made.  ``cheap`` subsystems are always polled; expensive ones are
    skipped when an earlier subsystem already made progress.  A
    ``strict`` subsystem raises *on purpose* (watchdogs, exhausted data
    sources): its exceptions re-raise out of ``progress`` instead of
    being swallowed by isolation — else a deliberate crash signal would
    degrade into an infinite wait."""

    def __init__(self, name: str, poll: Callable[[], bool], cheap: bool = True,
                 priority: int = 0, strict: bool = False):
        self.name = name
        self.poll = poll
        self.cheap = cheap
        self.priority = priority
        self.strict = strict
        self.polls = 0           # statistics (see repro.core.stats)
        self.progressed = 0
        self.errors = 0
        self.last_error: BaseException | None = None

    def __repr__(self):
        return f"Subsystem({self.name!r}, cheap={self.cheap})"


class ProgressEngine:
    """One engine per process (the paper's thesis: ONE progress engine
    collating every async subsystem, instead of one thread per library)."""

    def __init__(self):
        self.default_stream = Stream("default", self)   # MPIX_STREAM_NULL
        self._streams: list[Stream] = [self.default_stream]
        self._subsystems: list[Subsystem] = []
        self._lock = threading.Lock()
        # MPICH-style progress critical section: subsystem hooks are never
        # executed by two threads at once (hooks are not required to be
        # thread-safe); contenders skip instead of blocking, so stream
        # polling stays lock-free across threads (§4.4)
        self._sub_poll_lock = threading.Lock()
        self._executor = None          # attached ProgressExecutor, if any
        # (subsystem_name, exception) pairs from isolated failures
        self.subsystem_errors: list[tuple[str, BaseException]] = []
        # live ContinuationQueue objects (self-registered; see
        # repro.core.continuations) — snapshotted by repro.core.stats
        self.continuation_queues: list = []

    # -- streams ---------------------------------------------------------
    def stream(self, name: str = "") -> Stream:
        s = Stream(name, self)
        with self._lock:
            self._streams.append(s)
        return s

    def free_stream(self, stream: Stream) -> None:
        if stream.pending:
            raise RuntimeError(f"{stream.name} has pending tasks")
        with self._lock:
            self._streams.remove(stream)

    # -- MPIX_Async ------------------------------------------------------
    def async_start(self, poll_fn: Callable[[AsyncThing], str],
                    extra_state: Any = None,
                    stream: Optional[Stream] = None) -> AsyncThing:
        s = stream if stream is not None else self.default_stream
        thing = AsyncThing(self, poll_fn, extra_state, s)
        s._enqueue(thing)
        return thing

    # -- subsystems (Listing 1.1) ------------------------------------------
    def register_subsystem(self, name: str, poll: Callable[[], bool],
                           cheap: bool = True, priority: int = 0,
                           strict: bool = False) -> Subsystem:
        sub = Subsystem(name, poll, cheap, priority, strict)
        with self._lock:
            self._subsystems.append(sub)
            self._subsystems.sort(key=lambda x: x.priority)
        return sub

    def unregister_subsystem(self, sub: Subsystem) -> None:
        with self._lock:
            if sub in self._subsystems:
                self._subsystems.remove(sub)

    def poll_subsystems(self, *, progressed: bool = False,
                        skip_expensive_on_progress: bool = True,
                        strict: bool = False) -> int:
        """One pass over the subsystem hooks in priority order.

        A hook that raises is *isolated*: unregistered, the error recorded
        on ``subsystem_errors`` (and the Subsystem itself) with a warning,
        and polling continues — a broken library must not take down global
        progress.  With ``strict=True`` the exception re-raises after
        isolation.

        Hooks run inside a try-lock critical section: if another thread is
        already polling the subsystems this call returns 0 immediately
        (that thread IS making the progress) — hooks never execute
        concurrently, so they need no thread safety of their own.
        """
        if not self._sub_poll_lock.acquire(blocking=False):
            return 0
        try:
            with self._lock:
                subs = list(self._subsystems)
            made = 0
            for sub in subs:
                if ((progressed or made) and skip_expensive_on_progress
                        and not sub.cheap):
                    continue
                sub.polls += 1
                try:
                    if sub.poll():
                        made += 1
                        sub.progressed += 1
                except Exception as exc:
                    sub.errors += 1
                    sub.last_error = exc
                    self.subsystem_errors.append((sub.name, exc))
                    self.unregister_subsystem(sub)
                    if strict or sub.strict:
                        raise
                    warnings.warn(
                        f"progress subsystem {sub.name!r} raised "
                        f"{exc!r}; unregistered (see "
                        f"engine.subsystem_errors)", RuntimeWarning)
            return made
        finally:
            self._sub_poll_lock.release()

    # -- progress ----------------------------------------------------------
    def progress(self, stream: Optional[Stream] = None, *,
                 skip_expensive_on_progress: bool = True,
                 strict: bool = False) -> int:
        """MPIX_Stream_progress.

        Polls (a) the async tasks of ``stream`` (or the default stream)
        and (b) the registered subsystem hooks in priority order with the
        MPICH short-circuit: once progress is made, remaining *expensive*
        subsystems are skipped this round.  Subsystem failures are
        isolated (see ``poll_subsystems``) unless ``strict=True``.
        """
        s = stream if stream is not None else self.default_stream
        made = s._poll_once()
        made += self.poll_subsystems(
            progressed=made > 0,
            skip_expensive_on_progress=skip_expensive_on_progress,
            strict=strict)
        return made

    def progress_all(self, *, strict: bool = False) -> int:
        """Progress every stream (used by shutdown/finalize paths)."""
        made = 0
        with self._lock:
            streams = list(self._streams)
        for s in streams:
            made += s._poll_once()
        made += self.poll_subsystems(skip_expensive_on_progress=False,
                                     strict=strict)
        return made

    # -- executor attachment (§4.4) ----------------------------------------
    def attach_executor(self, executor) -> None:
        """Background ProgressExecutor announces itself: wait loops stop
        self-progressing and yield to the worker threads instead."""
        self._executor = executor

    def detach_executor(self, executor) -> None:
        if self._executor is executor:
            self._executor = None

    @property
    def executor(self):
        return self._executor

    def _advance(self, stream: Optional[Stream]) -> None:
        """One unit of forward motion for a wait loop: drive progress from
        this thread, or — when a running executor owns the target stream —
        just yield so the workers can.

        A stream the executor does NOT own is still progressed inline
        (only the stream, not the subsystems — worker 0 already polls
        those): waiting on an unadopted stream must never deadlock."""
        ex = self._executor
        if ex is not None and ex.running:
            target = stream if stream is not None else self.default_stream
            if ex.owns(target):
                time.sleep(_WAIT_YIELD_S)
            elif ex.poll_subsystems:
                if target._poll_once() == 0:
                    time.sleep(_WAIT_YIELD_S)   # don't burn a core idling
            else:
                self.progress(stream)
        else:
            self.progress(stream)

    # -- waiting -----------------------------------------------------------
    def wait(self, request, stream: Optional[Stream] = None,
             timeout: float | None = None) -> Any:
        """MPI_Wait: drive progress until ``request.is_complete``."""
        t0 = time.monotonic()
        while not request.is_complete:
            self._advance(stream)
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError(f"wait timed out after {timeout}s")
        return request.value()

    def wait_all(self, requests: Iterable, stream: Optional[Stream] = None,
                 timeout: float | None = None) -> list:
        reqs = list(requests)
        t0 = time.monotonic()
        while not all(r.is_complete for r in reqs):
            self._advance(stream)
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError(f"wait_all timed out after {timeout}s")
        return [r.value() for r in reqs]

    def wait_any(self, requests: Iterable, stream: Optional[Stream] = None,
                 timeout: float | None = None) -> tuple[int, Any]:
        """MPI_Waitany: block until *one* request completes.

        Returns ``(index, request)`` of the first request observed
        complete (requests already complete on entry win immediately, in
        list order — MPI's deterministic-tiebreak behaviour).
        """
        reqs = list(requests)
        if not reqs:
            raise ValueError("wait_any on empty request list")
        t0 = time.monotonic()
        while True:
            for i, r in enumerate(reqs):
                if r.is_complete:
                    return i, r
            self._advance(stream)
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError(f"wait_any timed out after {timeout}s")

    def wait_some(self, requests: Iterable, stream: Optional[Stream] = None,
                  min_count: int = 1,
                  timeout: float | None = None) -> list[int]:
        """MPI_Waitsome: block until ≥ ``min_count`` requests complete.

        Returns the indices of *all* requests complete at return time, in
        the order their completion was first observed (so index order
        reflects completion order across progress sweeps, the property
        event-driven consumers rely on).
        """
        reqs = list(requests)
        if min_count > len(reqs):
            raise ValueError(f"min_count={min_count} > {len(reqs)} requests")
        t0 = time.monotonic()
        done_order: list[int] = []
        seen = set()
        while True:
            for i, r in enumerate(reqs):
                if i not in seen and r.is_complete:
                    seen.add(i)
                    done_order.append(i)
            if len(done_order) >= min_count:
                return done_order
            self._advance(stream)
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError(f"wait_some timed out after {timeout}s")

    def drain(self, stream: Optional[Stream] = None,
              timeout: float | None = None) -> None:
        """MPI_Finalize behaviour (Listing 1.2): progress until no pending
        tasks remain on the stream (or all streams if None)."""
        t0 = time.monotonic()
        if stream is not None:
            while stream.pending:
                self.progress(stream)
                if timeout is not None and time.monotonic() - t0 > timeout:
                    raise TimeoutError("drain timed out")
            return
        while True:
            # snapshot under the lock: a task/continuation may free_stream
            # (or stream()) mid-sweep, and iterating the live list would
            # blow up with "list changed size during iteration"
            with self._lock:
                streams = list(self._streams)
            if not any(s.pending for s in streams):
                return
            self.progress_all()
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError("drain timed out")


# Process-global engine (most applications want exactly one).
_global_engine: ProgressEngine | None = None
_global_lock = threading.Lock()


def global_engine() -> ProgressEngine:
    global _global_engine
    if _global_engine is None:
        with _global_lock:
            if _global_engine is None:
                _global_engine = ProgressEngine()
    return _global_engine


def reset_global_engine() -> None:
    global _global_engine
    with _global_lock:
        _global_engine = None
