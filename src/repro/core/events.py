"""Request-completion events (paper §4.5, Listing 1.6).

The MPIX Async interface has no native callbacks; the paper shows the
"poor man's" version — a progress hook that sweeps registered requests
with ``MPIX_Request_is_complete`` and fires callbacks.  Overhead is one
atomic read per pending request per progress call (paper Fig 12), which
is negligible below a few hundred requests.

Heavy handlers should be deferred: ``EventQueue`` collects completion
events inside the hook and lets the application drain them outside the
progress path (the paper's §4.2 recommendation).
"""
from __future__ import annotations

import collections
import threading
from typing import Any, Callable, Optional

from repro.core.engine import DONE, NOPROGRESS, ProgressEngine, Stream
from repro.core.request import Request


class CompletionWatcher:
    """Fire ``callback(request)`` when each registered request completes."""

    def __init__(self, engine: ProgressEngine, stream: Optional[Stream] = None):
        self.engine = engine
        self.stream = stream
        self._lock = threading.Lock()
        self._watched: list[tuple[Request, Callable]] = []
        self._registered = False

    def watch(self, request: Request, callback: Callable[[Request], None]) -> None:
        with self._lock:
            self._watched.append((request, callback))
            if not self._registered:
                self._registered = True
                self.engine.async_start(self._poll, None, self.stream)

    def _poll(self, thing) -> str:
        with self._lock:
            watched = list(self._watched)
        fired = []
        for req, cb in watched:
            if req.is_complete:               # the Fig-12 query loop
                cb(req)
                fired.append((req, cb))
        if fired:
            with self._lock:
                for item in fired:
                    self._watched.remove(item)
        with self._lock:
            if not self._watched:
                self._registered = False
                return DONE
        return NOPROGRESS

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._watched)


class EventQueue:
    """Deferred event delivery: hooks enqueue, application drains.

    Keeps poll functions lightweight (paper §4.2: 'enqueue events and
    postpone the heavy work outside of the progress callbacks')."""

    def __init__(self):
        self._q: collections.deque = collections.deque()
        self._lock = threading.Lock()

    def emit(self, event: Any) -> None:
        with self._lock:
            self._q.append(event)

    def drain(self, max_events: int | None = None) -> list:
        out = []
        with self._lock:
            while self._q and (max_events is None or len(out) < max_events):
                out.append(self._q.popleft())
        return out

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)
