"""Multi-threaded progress executor (paper §4.4, Listing 1.2).

The paper's fix for the MPI_THREAD_MULTIPLE pathology is per-stream
serial contexts: many threads can drive progress concurrently as long as
no two threads poll the *same* stream.  ``ProgressExecutor`` packages
that pattern: it owns N worker threads, each responsible for a disjoint
set of streams, so the serve/train layers share one pool of progress
threads instead of each hand-rolling a ``while: engine.progress()`` loop.

Design points:

* **Ownership, not locking.**  A stream is assigned to exactly one
  worker; workers never poll each other's streams, so the per-stream
  lock is uncontended (Fig 11, not Fig 9).  ``Stream.contention`` stays
  zero unless an outside thread also calls ``engine.progress`` on an
  adopted stream.
* **Work stealing.**  A worker whose streams have all gone idle for
  ``steal_after`` consecutive sweeps takes one stream from the most
  loaded worker — ownership *moves*, preserving the serial-context
  invariant (the steal is an assignment change, never concurrent
  polling).
* **Subsystems on worker 0.**  Registered subsystem hooks (Listing 1.1)
  are polled by exactly one worker, keeping the MPICH short-circuit
  meaningful and sparing hooks from needing thread safety.
* **Finalize semantics** (Listing 1.2): ``drain`` spins until every
  adopted stream — including cross-thread ``_incoming`` backlogs — is
  empty; ``shutdown(drain=True)`` drains first, then joins the workers.
"""
from __future__ import annotations

import threading
import time
from typing import Optional

from repro.core import debug
from repro.core.engine import ProgressEngine, Stream
from repro.core.stats import WorkerStats


class _Worker:
    """One progress thread plus the streams it owns."""

    def __init__(self, index: int):
        self.index = index
        self.streams: list[Stream] = []
        self.queues: list = []          # adopted ContinuationQueues
        self.thread: threading.Thread | None = None
        self.thread_ident: int | None = None   # set by the worker loop
        self.sweeps = 0
        self.idle_spins = 0
        self.steals = 0
        self.drained = 0                # continuations executed by this worker
        self.idle_streak = 0


class ProgressExecutor:
    """N worker threads driving progress for assigned streams.

    Usage::

        ex = ProgressExecutor(engine, num_workers=2)
        s1, s2 = ex.stream("a"), ex.stream("b")   # create + adopt
        ex.start()
        ... engine.async_start(poll, None, s1) ...
        ex.shutdown(drain=True)                   # Listing 1.2 finalize

    Also usable as a context manager (``with ProgressExecutor(...)``):
    enter starts the workers, exit drains and shuts down.
    """

    def __init__(self, engine: ProgressEngine, num_workers: int = 2, *,
                 poll_subsystems: bool = True, steal: bool = True,
                 steal_after: int = 16, idle_sleep_s: float = 20e-6,
                 drain_continuations: bool = True,
                 continuation_max_drain: int = 64):
        if num_workers < 1:
            raise ValueError("need at least one worker")
        self.engine = engine
        self.num_workers = num_workers
        self.poll_subsystems = poll_subsystems
        self.steal = steal
        self.steal_after = steal_after
        self.idle_sleep_s = idle_sleep_s
        # adopted ContinuationQueues (deferred policy) are drained by their
        # owning worker between polls, at most continuation_max_drain per
        # sweep — the paper-recommended place to run completion callbacks
        # without a dedicated callback thread (bounded => backpressure)
        self.drain_continuations = drain_continuations
        self.continuation_max_drain = continuation_max_drain
        self._workers = [_Worker(i) for i in range(num_workers)]
        self._assign_lock = debug.make_lock("ProgressExecutor._assign_lock")
        self._stop = threading.Event()
        self._running = False
        self.errors: list[tuple[str, BaseException]] = []

    # -- stream assignment -------------------------------------------------
    def stream(self, name: str = "") -> Stream:
        """Create a new engine stream and adopt it (least-loaded worker)."""
        s = self.engine.stream(name)
        self.adopt(s)
        return s

    def adopt(self, stream: Stream, worker: Optional[int] = None) -> int:
        """Assign ``stream`` to a worker (least-loaded unless given).
        Returns the worker index."""
        with self._assign_lock:
            for w in self._workers:
                if stream in w.streams:
                    raise ValueError(f"{stream.name} already adopted")
            if worker is None:
                w = min(self._workers, key=lambda w: len(w.streams))
            else:
                w = self._workers[worker]
            w.streams.append(stream)
            return w.index

    def release(self, stream: Stream) -> None:
        """Remove ``stream`` from the executor (caller drives it again)."""
        with self._assign_lock:
            for w in self._workers:
                if stream in w.streams:
                    w.streams.remove(stream)
                    return
        raise ValueError(f"{stream.name} not adopted by this executor")

    def streams(self) -> list[Stream]:
        with self._assign_lock:
            return [s for w in self._workers for s in w.streams]

    def owns(self, stream: Stream) -> bool:
        with self._assign_lock:
            return any(stream in w.streams for w in self._workers)

    def worker_thread_idents(self) -> set[int]:
        """Thread idents of the live worker loops.  Lets callers (and
        the executor-driven-start tests) distinguish "dispatched by a
        progress worker" from "dispatched on the caller's thread"."""
        return {w.thread_ident for w in self._workers
                if w.thread_ident is not None}

    # -- continuation-queue assignment -------------------------------------
    def adopt_queue(self, queue, worker: Optional[int] = None) -> int:
        """Assign a (deferred-policy) ContinuationQueue to a worker: that
        worker becomes the queue's owner thread and drains it between
        polls.  Returns the worker index."""
        with self._assign_lock:
            for w in self._workers:
                if queue in w.queues:
                    raise ValueError(f"{queue.name} already adopted")
            if worker is None:
                w = min(self._workers, key=lambda w: len(w.queues))
            else:
                w = self._workers[worker]
            w.queues.append(queue)
            return w.index

    def release_queue(self, queue) -> None:
        with self._assign_lock:
            for w in self._workers:
                if queue in w.queues:
                    w.queues.remove(queue)
                    return
        raise ValueError(f"{queue.name} not adopted by this executor")

    def queues(self) -> list:
        with self._assign_lock:
            return [q for w in self._workers for q in w.queues]

    # -- lifecycle ---------------------------------------------------------
    @property
    def running(self) -> bool:
        return self._running

    def start(self) -> "ProgressExecutor":
        if self._running:
            return self
        self._stop.clear()
        for w in self._workers:
            w.thread = threading.Thread(
                target=self._worker_loop, args=(w,),
                name=f"progress-worker-{w.index}", daemon=True)
        self._running = True
        self.engine.attach_executor(self)
        for w in self._workers:
            w.thread.start()
        return self

    def drain(self, timeout: float | None = None) -> None:
        """Listing 1.2 finalize: block until every adopted stream has zero
        pending tasks (``pending`` includes the cross-thread ``_incoming``
        backlog, so late ``async_start`` calls are absorbed too).

        Works whether or not the workers are running: with workers up, it
        just waits; with workers down, it progresses the streams inline.
        """
        t0 = time.monotonic()
        while True:
            streams = self.streams()
            queues = self.queues()
            if (not any(s.pending for s in streams)
                    and not any(q.ready for q in queues)):
                return
            if self._running:
                if not self.drain_continuations:
                    # workers are not draining queues; the drainer must,
                    # or adopted-queue readiness could never reach zero
                    for q in queues:
                        q.drain()
                time.sleep(self.idle_sleep_s)
            else:
                for s in streams:
                    s._poll_once()
                for q in queues:
                    q.drain()
                if self.poll_subsystems:
                    self.engine.poll_subsystems()
            if timeout is not None and time.monotonic() - t0 > timeout:
                raise TimeoutError(
                    "executor drain timed out; pending: "
                    + "; ".join([f"{s.name}={s.pending}"
                                 for s in streams if s.pending]
                                + [f"{q.name}.ready={q.ready}"
                                   for q in queues if q.ready]))

    def shutdown(self, drain: bool = True,
                 timeout: float | None = None) -> None:
        """Stop the workers (optionally draining first, per Listing 1.2).

        The workers are stopped and the executor detached even when the
        drain times out — a wedged task must not leak spinning threads."""
        try:
            if drain:
                self.drain(timeout)
        finally:
            self._stop.set()
            for w in self._workers:
                if w.thread is not None:
                    w.thread.join(timeout)
                    w.thread = None
            self._running = False
            self.engine.detach_executor(self)

    def __enter__(self) -> "ProgressExecutor":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.shutdown(drain=exc_type is None)

    # -- worker loop -------------------------------------------------------
    def _worker_loop(self, w: _Worker) -> None:
        w.thread_ident = threading.get_ident()
        while not self._stop.is_set():
            with self._assign_lock:
                streams = list(w.streams)
            made = 0
            for s in streams:
                try:
                    made += s._poll_once()
                except BaseException as exc:  # noqa: BLE001
                    # the broken task was already dropped by _poll_once;
                    # record and keep the worker alive — even SystemExit
                    # from a poll_fn must not silently kill the worker
                    # (its streams would starve with no error anywhere)
                    self.errors.append((s.name, exc))
            if w.index == 0 and self.poll_subsystems:
                try:
                    made += self.engine.poll_subsystems()
                except BaseException as exc:  # noqa: BLE001
                    # a strict subsystem re-raises on purpose; on a worker
                    # thread that must not silently kill the thread (its
                    # streams would starve) — record it where callers look
                    self.errors.append(("subsystems", exc))
            if self.drain_continuations:
                with self._assign_lock:
                    queues = list(w.queues)
                for q in queues:
                    n = q.drain(self.continuation_max_drain)
                    made += n
                    w.drained += n
            w.sweeps += 1
            if made:
                w.idle_streak = 0
            else:
                w.idle_spins += 1
                w.idle_streak += 1
                if (self.steal and w.idle_streak >= self.steal_after
                        and self._try_steal(w)):
                    w.steals += 1
                    w.idle_streak = 0
                else:
                    # idle: yield the core instead of burning it
                    time.sleep(self.idle_sleep_s)

    def _try_steal(self, thief: _Worker) -> bool:
        """Move one stream from the most loaded worker to ``thief``.

        Ownership transfer happens under the assignment lock; the victim
        worker snapshots its stream list per sweep, so after this returns
        the stolen stream is polled by exactly one thread (at worst one
        final already-snapshotted sweep overlaps, which the per-stream
        lock makes safe and visible via ``Stream.contention``).
        """
        with self._assign_lock:
            victim = max((v for v in self._workers if v is not thief),
                        key=lambda v: len(v.streams), default=None)
            if victim is None or not victim.streams:
                return False
            # only steal when it improves balance — and never from a
            # single-stream victim: that stream already has a dedicated
            # worker, so moving it just ping-pongs ownership between idle
            # workers (handoff overlap shows up as stream contention)
            if len(victim.streams) < 2 or len(victim.streams) <= len(thief.streams):
                return False
            # prefer a stream with work queued; else take the last one
            stolen = next((s for s in victim.streams if s.pending),
                          victim.streams[-1])
            victim.streams.remove(stolen)
            thief.streams.append(stolen)
            return True

    # -- statistics --------------------------------------------------------
    def worker_stats(self) -> list[WorkerStats]:
        with self._assign_lock:
            return [WorkerStats(w.index, w.sweeps, w.idle_spins, w.steals,
                                [s.name for s in w.streams], w.drained)
                    for w in self._workers]
