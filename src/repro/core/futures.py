"""Bridging JAX async dispatch + host I/O into the progress engine.

JAX is the "NIC" here: ``jit(f)(x)`` returns immediately and the TPU/CPU
runtime executes asynchronously; ``Array.is_ready()`` is the completion-
queue poll.  ``jax_future`` turns a dispatched computation into a
``Request``; ``io_future`` wraps a thread-pool task (storage/network I/O)
— both are then progressed by the ONE collated engine rather than by
per-subsystem wait loops (the paper's interoperable-progress thesis).
"""
from __future__ import annotations

import concurrent.futures
import threading
from typing import Any, Callable, Optional

import jax

from repro.core.engine import DONE, NOPROGRESS, ProgressEngine, Stream
from repro.core.request import Request


def _arrays_ready(arrays) -> bool:
    return all(a.is_ready() for a in jax.tree.leaves(arrays)
               if hasattr(a, "is_ready"))


def jax_future(engine: ProgressEngine, arrays: Any,
               stream: Optional[Stream] = None,
               on_complete: Callable[[Any], None] | None = None) -> Request:
    """Request completing when every array in the pytree is device-ready.

    Non-blocking: uses ``Array.is_ready()`` (never ``block_until_ready``)
    so the engine can interleave other subsystems while the device runs.
    The watched arrays ride along as the task's ``state`` so waiters that
    *choose* to block (e.g. ``CollectiveRequest.wait`` parking on an
    in-flight round instead of burning a core polling) can reach them.
    """
    req = Request(tag="jax")

    def poll(thing) -> str:
        if _arrays_ready(arrays):
            if on_complete is not None:
                on_complete(arrays)
            req.complete(arrays)
            return DONE
        return NOPROGRESS

    engine.async_start(poll, arrays, stream)
    return req


# One small pool for genuinely-blocking host I/O (file writes, RPCs).
# The progress engine polls futures; the pool threads never touch JAX.
_io_pool: concurrent.futures.ThreadPoolExecutor | None = None
_io_lock = threading.Lock()


def io_pool() -> concurrent.futures.ThreadPoolExecutor:
    global _io_pool
    if _io_pool is None:
        with _io_lock:
            if _io_pool is None:
                _io_pool = concurrent.futures.ThreadPoolExecutor(
                    max_workers=4, thread_name_prefix="repro-io")
    return _io_pool


def io_future(engine: ProgressEngine, fn: Callable[[], Any],
              stream: Optional[Stream] = None,
              on_complete: Callable[[Any], None] | None = None) -> Request:
    """Run ``fn`` on the I/O pool; completion surfaces via the engine."""
    req = Request(tag="io")
    fut = io_pool().submit(fn)

    def poll(thing) -> str:
        if fut.done():
            try:
                value = fut.result()
            except BaseException as e:  # noqa: BLE001
                req.fail(e)
                return DONE
            if on_complete is not None:
                on_complete(value)
            req.complete(value)
            return DONE
        return NOPROGRESS

    engine.async_start(poll, None, stream)
    return req


def chain(engine: ProgressEngine, stages: list[Callable[[Any], Any]],
          stream: Optional[Stream] = None, initial: Any = None) -> Request:
    """Multi-wait-block task (paper Fig 1c / Fig 3c): each stage is
    launched when the previous completes, entirely inside poll_fn —
    the 'small block of code after each wait block' the paper identifies
    as the essence of progress (§2.4)."""
    req = Request(tag="chain")
    state = {"i": 0, "fut": None, "value": initial}

    def poll(thing) -> str:
        if state["fut"] is None:
            if state["i"] >= len(stages):
                req.complete(state["value"])
                return DONE
            stage = stages[state["i"]]
            state["fut"] = io_pool().submit(stage, state["value"])
            return NOPROGRESS
        if state["fut"].done():
            try:
                state["value"] = state["fut"].result()
            except BaseException as e:  # noqa: BLE001
                req.fail(e)
                return DONE
            state["fut"] = None
            state["i"] += 1
        return NOPROGRESS

    engine.async_start(poll, None, stream)
    return req
