"""Requests and generalized requests.

``Request.is_complete`` is the paper's ``MPIX_Request_is_complete``: a
single atomic-flag read with NO side effects — it never invokes progress,
so tasks can poll their dependencies without contending with the progress
engine (paper §3.4).

``GeneralizedRequest`` reproduces MPI generalized requests (§4.6): a
waitable handle whose completion is signalled from inside a poll
function via ``complete()`` (the ``MPI_Grequest_complete`` analogue).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Iterable, Optional


class CancelledError(RuntimeError):
    """Failure a cancelled request completes with (MPI_Cancel semantics):
    ``MPI_Wait`` on a cancelled request must *return*, not spin — here,
    ``engine.wait`` raises this instead of timing out."""


class Request:
    """Completion handle. The flag is a plain attribute — CPython attribute
    loads are atomic, mirroring the paper's 'an atomic read instruction'."""

    __slots__ = ("_complete", "_value", "_exc", "tag")

    def __init__(self, tag: str = ""):
        self._complete = False
        self._value: Any = None
        self._exc: Optional[BaseException] = None
        self.tag = tag

    @property
    def is_complete(self) -> bool:
        """MPIX_Request_is_complete: side-effect free, never progresses."""
        return self._complete

    @property
    def exception(self) -> Optional[BaseException]:
        """The failure, if this request completed via ``fail`` (else None).
        Side-effect free, like ``is_complete`` — dependency trackers use
        it to propagate failures without calling ``value()``."""
        return self._exc

    @property
    def failed(self) -> bool:
        return self._complete and self._exc is not None

    def wait(self, engine, stream=None, timeout: float | None = None) -> Any:
        """Convenience: ``engine.wait(self)`` (MPI_Wait on this handle)."""
        return engine.wait(self, stream=stream, timeout=timeout)

    def complete(self, value: Any = None) -> None:
        self._value = value
        self._complete = True      # publish after value (GIL ordering)

    def fail(self, exc: BaseException) -> None:
        self._exc = exc
        self._complete = True

    def value(self) -> Any:
        if not self._complete:
            raise RuntimeError("request not complete; use engine.wait()")
        if self._exc is not None:
            raise self._exc
        return self._value


class GeneralizedRequest(Request):
    """MPI_Grequest_start analogue: user callbacks + external completion.

    query_fn/free_fn/cancel_fn mirror the MPI interface; like MPI (and as
    the paper critiques), the generalized request has NO progress of its
    own — pair it with ``engine.async_start`` which provides the missing
    progression mechanism (paper §4.6).
    """

    __slots__ = ("query_fn", "free_fn", "cancel_fn", "extra_state", "_cancelled")

    def __init__(self,
                 query_fn: Callable[[Any], Any] | None = None,
                 free_fn: Callable[[Any], None] | None = None,
                 cancel_fn: Callable[[Any, bool], None] | None = None,
                 extra_state: Any = None):
        super().__init__(tag="grequest")
        self.query_fn = query_fn
        self.free_fn = free_fn
        self.cancel_fn = cancel_fn
        self.extra_state = extra_state
        self._cancelled = False

    def complete(self, value: Any = None) -> None:  # MPI_Grequest_complete
        if self._complete:
            # already complete — e.g. cancelled; MPI_Grequest_complete on
            # a cancelled request must not resurrect it as successful
            return
        if self.query_fn is not None:
            value = self.query_fn(self.extra_state)
        super().complete(value)

    @property
    def cancelled(self) -> bool:
        return self._cancelled

    def cancel(self) -> None:
        """MPI_Cancel: inform the callback, then *complete* the request
        (with a ``CancelledError`` failure) if it has not completed yet —
        MPI_Cancel + MPI_Wait semantics: a wait on a cancelled request
        returns instead of spinning until timeout."""
        if self.cancel_fn is not None:
            self.cancel_fn(self.extra_state, self._complete)
        if not self._complete:
            self._cancelled = True
            self.fail(CancelledError(f"grequest {self.tag!r} cancelled"))

    def free(self) -> None:
        if self.free_fn is not None:
            self.free_fn(self.extra_state)


class CompletionCounter:
    """Wait-set aggregate (paper §4.5 / MPI Continuations idiom): counts
    completions across a set of requests with one atomic-read sweep.

    Unlike ``engine.wait_all`` this is a passive observable — task-runtime
    schedulers poll ``remaining`` (one ``is_complete`` read per request,
    the Fig-12 cost model) and release dependents when it hits zero.
    ``as_request()`` adapts the counter back into a waitable ``Request``
    so counters compose with ``wait``/``wait_any``/``TaskGraph`` deps.
    """

    def __init__(self, requests: Iterable["Request"] = ()):
        self._lock = threading.Lock()
        self._reqs: list[Request] = []
        for r in requests:
            self.add(r)

    def add(self, request: "Request") -> "CompletionCounter":
        with self._lock:
            self._reqs.append(request)
        return self

    @property
    def total(self) -> int:
        with self._lock:
            return len(self._reqs)

    @property
    def completed(self) -> int:
        with self._lock:
            reqs = list(self._reqs)
        return sum(1 for r in reqs if r.is_complete)

    @property
    def remaining(self) -> int:
        # one snapshot for both counts: total and completed from separate
        # lock acquisitions could interleave with add() and go negative
        with self._lock:
            reqs = list(self._reqs)
        return sum(1 for r in reqs if not r.is_complete)

    @property
    def is_complete(self) -> bool:
        return self.remaining == 0

    @property
    def failed(self) -> list["Request"]:
        with self._lock:
            reqs = list(self._reqs)
        return [r for r in reqs if r.failed]

    def as_request(self) -> "PollRequest":
        return PollRequest(lambda: self.is_complete, tag="ccounter")


def request_of(fn: Callable[[], bool], tag: str = "") -> "PollRequest":
    return PollRequest(fn, tag)


class PollRequest(Request):
    """Request whose completion is determined by a user predicate."""

    __slots__ = ("_predicate",)

    def __init__(self, predicate: Callable[[], bool], tag: str = ""):
        super().__init__(tag)
        self._predicate = predicate

    @property
    def is_complete(self) -> bool:
        if not self._complete and self._predicate():
            self.complete()
        return self._complete
