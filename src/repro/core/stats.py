"""Progress statistics (paper §4.1/§4.4 instrumentation).

The paper's evaluation is built on three observables: progress *latency*
(benchmarks/_util.py measures that), lock *contention* between threads
sharing a serial context (Fig 9 vs Fig 11), and wasted *idle spins* —
sweeps that polled tasks but completed nothing.  This module snapshots
those counters from streams, subsystems and executor workers into plain
dataclasses so tests and benchmarks can assert on them (e.g. "two
workers on disjoint streams ⇒ zero cross-stream contention").

Counters are incremented without locks on the hot path: every mutation
happens either under the stream's serial-context lock or from the single
thread polling a subsystem, so plain ``+= 1`` is race-free in the same
way the paper's per-stream state is.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from repro.core.engine import ProgressEngine
    from repro.core.executor import ProgressExecutor


@dataclasses.dataclass
class StreamStats:
    name: str
    polls: int              # task poll_fn invocations
    completions: int        # tasks that returned DONE
    contention: int         # _poll_once found the serial lock held
    idle_spins: int         # sweeps that polled ≥1 task, completed 0
    task_errors: int        # poll_fns that raised (task dropped)
    pending: int


@dataclasses.dataclass
class SubsystemStats:
    name: str
    polls: int
    progressed: int         # polls that returned True
    errors: int             # polls that raised (subsystem unregistered)
    cheap: bool
    priority: int


@dataclasses.dataclass
class WorkerStats:
    index: int
    sweeps: int             # full passes over the worker's streams
    idle_spins: int         # sweeps with zero completions
    steals: int             # streams taken from another worker
    streams: list[str] = dataclasses.field(default_factory=list)
    drained: int = 0        # continuations executed between polls


@dataclasses.dataclass
class ContinuationStats:
    name: str
    policy: str             # "inline" | "deferred"
    enqueued: int           # continuations attached
    executed: int           # continuations run (success or failure path)
    deferred: int           # continuations routed through the ready list
    failed: int             # failure-path runs + callbacks that raised
    cancelled: int          # dropped unfired by close()
    pending: int            # attached, request not yet complete
    ready: int              # awaiting a drain


@dataclasses.dataclass
class SchedulerStats:
    """Continuous-batching scheduler counters (paged serve engine).

    ``admitted`` counts admissions *including re-admissions* of preempted
    requests, so ``admitted - preemptions`` is the number of distinct
    residencies that ran to completion/failure.  ``prefill_calls`` is the
    number of fused chunked-prefill dispatches (each feeds every
    mid-prefill lane one token) — the interleaving knob's observable."""
    admitted: int = 0          # (re-)admissions into a lane
    preemptions: int = 0       # evictions under block pressure
    prefill_calls: int = 0     # fused chunked-prefill dispatches
    peak_resident: int = 0     # max lanes occupied at once
    peak_backlog: int = 0      # max requests waiting for lanes/blocks

    def format(self) -> str:
        return (f"scheduler: {self.admitted} admitted "
                f"({self.preemptions} preemptions), "
                f"{self.prefill_calls} prefill chunks; peaks: "
                f"{self.peak_resident} resident, "
                f"{self.peak_backlog} backlogged")


@dataclasses.dataclass
class EngineStats:
    streams: list[StreamStats]
    subsystems: list[SubsystemStats]
    workers: list[WorkerStats]
    continuations: list[ContinuationStats] = dataclasses.field(
        default_factory=list)

    def stream(self, name: str) -> StreamStats:
        for s in self.streams:
            if s.name == name:
                return s
        raise KeyError(name)

    def subsystem(self, name: str) -> SubsystemStats:
        for s in self.subsystems:
            if s.name == name:
                return s
        raise KeyError(name)

    def continuation_queue(self, name: str) -> ContinuationStats:
        for c in self.continuations:
            if c.name == name:
                return c
        raise KeyError(name)

    @property
    def total_contention(self) -> int:
        return sum(s.contention for s in self.streams)

    @property
    def total_steals(self) -> int:
        return sum(w.steals for w in self.workers)


def collect(engine: "ProgressEngine",
            executor: Optional["ProgressExecutor"] = None) -> EngineStats:
    """Snapshot every counter the engine (and optional executor) keeps."""
    with engine._lock:
        streams = list(engine._streams)
        subsystems = list(engine._subsystems)
    queues = list(getattr(engine, "continuation_queues", ()))
    if executor is None:
        executor = getattr(engine, "_executor", None)
    stream_stats = [
        StreamStats(s.name, s.polls, s.completions, s.contention,
                    s.idle_spins, len(s.task_errors), s.pending)
        for s in streams
    ]
    sub_stats = [
        SubsystemStats(s.name, s.polls, s.progressed, s.errors,
                       s.cheap, s.priority)
        for s in subsystems
    ]
    worker_stats = []
    if executor is not None:
        worker_stats = executor.worker_stats()
    cont_stats = [
        ContinuationStats(q.name, q.policy, q.enqueued, q.executed,
                          q.deferred, q.failed, q.cancelled,
                          q.pending, q.ready)
        for q in queues
    ]
    return EngineStats(stream_stats, sub_stats, worker_stats, cont_stats)


def format_stats(stats: EngineStats) -> str:
    """Human-readable table (benchmarks / --verbose launchers)."""
    lines = ["stream             polls  compl  contend  idle  errs  pending"]
    for s in stats.streams:
        lines.append(f"{s.name:<18} {s.polls:>5}  {s.completions:>5}  "
                     f"{s.contention:>7}  {s.idle_spins:>4}  "
                     f"{s.task_errors:>4}  {s.pending:>7}")
    if stats.subsystems:
        lines.append("subsystem          polls  progressed  errors")
        for s in stats.subsystems:
            lines.append(f"{s.name:<18} {s.polls:>5}  {s.progressed:>10}  "
                         f"{s.errors:>6}")
    if stats.workers:
        lines.append("worker  sweeps  idle  steals  drained  streams")
        for w in stats.workers:
            lines.append(f"w{w.index:<5} {w.sweeps:>7}  {w.idle_spins:>4}  "
                         f"{w.steals:>6}  {w.drained:>7}  "
                         f"{','.join(w.streams)}")
    if stats.continuations:
        lines.append("cont-queue         policy    enq  exec  defer  fail  "
                     "cancel  pend  ready")
        for c in stats.continuations:
            lines.append(f"{c.name:<18} {c.policy:<8} {c.enqueued:>4}  "
                         f"{c.executed:>4}  {c.deferred:>5}  {c.failed:>4}  "
                         f"{c.cancelled:>6}  {c.pending:>4}  {c.ready:>5}")
    return "\n".join(lines)
