"""Task classes (paper §4.3): one poll hook progresses a whole queue.

Polling N independent tasks costs O(N) per progress call (paper Fig 7).
When tasks complete in order (streams / linear dependency chains), a
single registered poll function that only inspects the queue head keeps
the progress cost O(1) (paper Fig 10).  ``TaskQueue`` is that pattern;
``TaskGraph`` generalizes it to DAG dependencies, polling only *ready*
tasks.
"""
from __future__ import annotations

import collections
import threading
from typing import Any, Callable, Optional

from repro.core.engine import DONE, NOPROGRESS, AsyncThing, ProgressEngine, Stream
from repro.core.request import Request


class TaskQueue:
    """In-order task class: one poll_fn checks only the queue head.

    ``submit(ready_fn, on_complete)`` returns a Request.  ``ready_fn()``
    -> bool decides completion of the head task.
    """

    def __init__(self, engine: ProgressEngine, stream: Optional[Stream] = None,
                 name: str = "taskq"):
        self.engine = engine
        self.stream = stream
        self.name = name
        self._q: collections.deque = collections.deque()
        self._lock = threading.Lock()
        self._registered = False

    def submit(self, ready_fn: Callable[[], bool],
               on_complete: Callable[[], Any] | None = None) -> Request:
        req = Request(tag=self.name)
        with self._lock:
            self._q.append((ready_fn, on_complete, req))
            if not self._registered:
                self._registered = True
                self.engine.async_start(self._poll, None, self.stream)
        return req

    def _poll(self, thing: AsyncThing) -> str:
        # only the head is inspected: O(1) per progress call
        while True:
            with self._lock:
                if not self._q:
                    self._registered = False
                    return DONE
                ready_fn, on_complete, req = self._q[0]
            if not ready_fn():
                return NOPROGRESS
            value = on_complete() if on_complete is not None else None
            req.complete(value)
            with self._lock:
                self._q.popleft()

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._q)


class TaskGraph:
    """DAG task class: tasks poll only once their dependencies completed.

    The paper notes general-purpose dependency tracking belongs in the
    application's poll_fn, not the MPI library — this is that layer.
    A dependency that *fails* (``Request.fail``) fails its dependents
    with the same exception, transitively, without starting them.
    """

    def __init__(self, engine: ProgressEngine, stream: Optional[Stream] = None):
        self.engine = engine
        self.stream = stream
        self._lock = threading.Lock()
        self._tasks: dict[int, dict] = {}
        self._next_id = 0
        self._registered = False

    def add(self, ready_fn: Callable[[], bool],
            deps: list[Request] | None = None,
            on_complete: Callable[[], Any] | None = None,
            start_fn: Callable[[], None] | None = None) -> Request:
        """start_fn runs once when all deps are complete (task launch);
        ready_fn polls completion afterwards."""
        req = Request(tag="graph")
        with self._lock:
            tid = self._next_id
            self._next_id += 1
            self._tasks[tid] = {
                "ready": ready_fn, "deps": list(deps or ()),
                "done_cb": on_complete, "start": start_fn,
                "started": False, "req": req,
            }
            if not self._registered:
                self._registered = True
                self.engine.async_start(self._poll, None, self.stream)
        return req

    def _poll(self, thing: AsyncThing) -> str:
        with self._lock:
            items = list(self._tasks.items())
        finished = []
        for tid, t in items:
            failed_dep = next((d for d in t["deps"] if d.failed), None)
            if failed_dep is not None:
                # failure propagation: a failed dependency fails this task
                # (transitively — our request now reads as failed to ours'
                # dependents on the next sweep) without ever starting it
                t["req"].fail(failed_dep.exception)
                finished.append(tid)
                continue
            if any(not d.is_complete for d in t["deps"]):
                continue                      # dependencies pending: skip poll
            if not t["started"]:
                if t["start"] is not None:
                    t["start"]()
                t["started"] = True
            if t["ready"]():
                value = t["done_cb"]() if t["done_cb"] is not None else None
                t["req"].complete(value)
                finished.append(tid)
        if finished:
            with self._lock:
                for tid in finished:
                    self._tasks.pop(tid, None)
        with self._lock:
            if not self._tasks:
                self._registered = False
                return DONE
        return NOPROGRESS

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._tasks)
