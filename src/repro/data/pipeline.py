"""Data pipeline with engine-driven prefetch.

The pipeline is a *subsystem* of the progress engine (the paper's
"datatype engine" slot in Listing 1.1): a background fill task produces
batches into a bounded buffer; the trainer's ``next_batch`` never blocks
while the buffer is warm, and the buffer is refilled whenever *anyone*
drives progress — the data stall disappears into the compute phase.

The source here is a synthetic LM stream (seeded, reproducible, sharded
by host) — swap ``SyntheticLM`` for a real tokenized corpus reader; the
prefetch machinery is source-agnostic.
"""
from __future__ import annotations

import collections
import threading
from typing import Iterator, Optional

import numpy as np

from repro.core.engine import ProgressEngine, Stream
from repro.core.futures import io_pool


class SyntheticLM:
    """Deterministic synthetic token stream (zipf-ish unigram mix with
    induced bigram structure so models actually have something to learn)."""

    def __init__(self, vocab_size: int, seq_len: int, batch_size: int,
                 seed: int = 0, shard: int = 0, num_shards: int = 1):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = batch_size
        self.rng = np.random.RandomState(seed * num_shards + shard + 1)
        # fixed random bigram table: next ~ 0.5 uniform + 0.5 f(prev)
        self._succ = self.rng.randint(0, vocab_size, size=(vocab_size,))

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.sample()

    def sample(self) -> dict:
        B, S, V = self.batch, self.seq + 1, self.vocab
        toks = np.empty((B, S), np.int32)
        toks[:, 0] = self.rng.randint(0, V, size=B)
        for t in range(1, S):
            coin = self.rng.rand(B) < 0.5
            toks[:, t] = np.where(coin, self._succ[toks[:, t - 1]],
                                  self.rng.randint(0, V, size=B))
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class PrefetchPipeline:
    """Bounded prefetch buffer filled from the engine's progress loop."""

    def __init__(self, source, engine: ProgressEngine,
                 stream: Optional[Stream] = None, depth: int = 4):
        self.source = iter(source)
        self.engine = engine
        self.stream = stream
        self.depth = depth
        self._buf: collections.deque = collections.deque()
        self._lock = threading.Lock()
        self._fut = None
        self.stalls = 0          # times next_batch had to block
        self.fills = 0
        # strict: an exhausted/broken source (StopIteration from next())
        # must surface to next_batch's caller, not silently unregister
        # the fill hook and leave next_batch spinning on an empty buffer
        self._sub = engine.register_subsystem(
            "data-pipeline", self._poll, cheap=True, priority=1, strict=True)

    def _poll(self) -> bool:
        """Engine subsystem hook: keep the buffer full, one fill in flight."""
        with self._lock:
            depth_now = len(self._buf)
            fut = self._fut
        if fut is not None:
            if not fut.done():
                return False
            batch = fut.result()
            with self._lock:
                self._buf.append(batch)
                self._fut = None
            self.fills += 1
            return True
        if depth_now < self.depth:
            self._fut = io_pool().submit(lambda: next(self.source))
            return False
        return False

    def next_batch(self):
        while True:
            with self._lock:
                if self._buf:
                    return self._buf.popleft()
            self.stalls += 1
            self.engine.progress(self.stream)

    def close(self):
        self.engine.unregister_subsystem(self._sub)
