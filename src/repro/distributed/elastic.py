"""Elastic scaling: rebuild the mesh after membership changes and restore
the latest checkpoint resharded onto it.

Checkpoints store full (unsharded) arrays, so restoring onto a smaller
or larger mesh is a pure placement decision: recompute the sharding
rules against the new mesh and ``device_put`` accordingly.  Combined
with ``AsyncCheckpointer``'s atomic commits, a pod loss costs at most
the work since the last committed step.
"""
from __future__ import annotations

import math
from typing import Optional

import jax

from repro.launch.mesh import make_mesh
from repro.sharding import merged_rules, axis_rules, spec_tree
from jax.sharding import NamedSharding


def largest_pof2(n: int) -> int:
    if n < 1:
        raise ValueError(f"largest_pof2 needs n >= 1, got {n}")
    return 1 << (n.bit_length() - 1)


def plan_mesh(n_devices: int, *, prefer_model: int = 16) -> tuple[tuple, tuple]:
    """Pick a (data, model) mesh for an arbitrary surviving device count.

    Keeps the model axis at `prefer_model` when divisible (TP degree is a
    property of the model, not of the incident), otherwise the largest
    power-of-two that fits."""
    if n_devices < 1:
        # total membership loss is not a mesh-planning problem; surface
        # the survivor count instead of largest_pof2's shift-count error
        raise ValueError(
            f"plan_mesh: cannot build a mesh for {n_devices} surviving "
            f"device(s); at least 1 is required")
    n = largest_pof2(n_devices)
    model = prefer_model
    while model > 1 and n % model:
        model //= 2
    return (n // model, model), ("data", "model")


def remesh(n_devices: Optional[int] = None, prefer_model: int = 16):
    n = n_devices if n_devices is not None else len(jax.devices())
    if n < 1:
        raise ValueError(
            f"remesh: cannot rebuild a mesh for {n} surviving device(s); "
            f"at least 1 is required")
    shape, axes = plan_mesh(n, prefer_model=prefer_model)
    return make_mesh(shape, axes)


def reshard_restore(checkpointer, step: int, like_tree, axes_tree, new_mesh,
                    rules_overrides=None):
    """Restore checkpoint `step` with shardings recomputed for new_mesh."""
    rules = merged_rules(rules_overrides)
    with axis_rules(rules):
        specs = spec_tree(axes_tree, like_tree, new_mesh)
    shardings = jax.tree.map(
        lambda s: NamedSharding(new_mesh, s), specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    return checkpointer.restore(step, like_tree, shardings)
