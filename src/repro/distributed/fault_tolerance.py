"""Fault-tolerance monitors — clients of the progress engine.

At thousand-node scale the failure model is: slow chips (stragglers),
hung steps (deadlocked collective after a link flap), and dead hosts.
The monitors here are host-side subsystems polled by the SAME collated
progress loop as checkpointing and data (the paper's thesis: no private
watchdog threads):

* ``HeartbeatMonitor`` — every participant beats per step; a peer whose
  beat is older than ``timeout`` is flagged, triggering
  checkpoint-restart (driven by the trainer).
* ``StragglerDetector`` — EWMA of step durations; steps slower than
  ``threshold ×`` the EWMA are counted per source so schedulers can
  evict persistent stragglers.
* ``StepWatchdog`` — wall-clock bound on a single step; firing means the
  collective is presumed hung and restart-from-checkpoint is requested.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

from repro.core.engine import ProgressEngine, Stream


class HeartbeatMonitor:
    def __init__(self, engine: ProgressEngine, peers: list[str],
                 timeout: float = 60.0, on_failure: Callable[[str], None] = None,
                 clock=time.monotonic):
        self.peers = {p: clock() for p in peers}
        self.timeout = timeout
        self.on_failure = on_failure or (lambda p: None)
        self.failed: set[str] = set()
        self.clock = clock
        self._sub = engine.register_subsystem(
            "heartbeat", self._poll, cheap=True, priority=2)

    def beat(self, peer: str) -> None:
        self.peers[peer] = self.clock()
        self.failed.discard(peer)

    def _poll(self) -> bool:
        now = self.clock()
        fired = False
        for peer, last in self.peers.items():
            if peer not in self.failed and now - last > self.timeout:
                self.failed.add(peer)
                self.on_failure(peer)
                fired = True
        return fired

    @property
    def alive(self) -> list[str]:
        return [p for p in self.peers if p not in self.failed]


class StragglerDetector:
    def __init__(self, threshold: float = 1.5, alpha: float = 0.1):
        self.threshold = threshold
        self.alpha = alpha
        self.ewma: float | None = None
        self.flagged: dict[str, int] = {}
        self.history: list[tuple[str, float, bool]] = []

    def record(self, source: str, duration: float) -> bool:
        """Returns True if this step was a straggler."""
        is_straggler = (self.ewma is not None
                        and duration > self.threshold * self.ewma)
        if is_straggler:
            self.flagged[source] = self.flagged.get(source, 0) + 1
        # EWMA excludes outliers so one straggler doesn't poison the mean
        if not is_straggler:
            self.ewma = (duration if self.ewma is None
                         else (1 - self.alpha) * self.ewma + self.alpha * duration)
        self.history.append((source, duration, is_straggler))
        return is_straggler

    def persistent_stragglers(self, min_count: int = 3) -> list[str]:
        return [s for s, n in self.flagged.items() if n >= min_count]


class StepWatchdog:
    def __init__(self, engine: ProgressEngine, limit: float = 300.0,
                 on_hang: Callable[[], None] = None, clock=time.monotonic):
        self.limit = limit
        self.on_hang = on_hang or (lambda: None)
        self.clock = clock
        self._armed_at: float | None = None
        self.fired = 0
        # strict: firing the watchdog (on_hang raising) must abort the
        # run loudly, not be isolated into a silent unregister + hang
        self._sub = engine.register_subsystem(
            "watchdog", self._poll, cheap=True, priority=3, strict=True)

    def arm(self) -> None:
        self._armed_at = self.clock()

    def disarm(self) -> None:
        self._armed_at = None

    def _poll(self) -> bool:
        if self._armed_at is not None and \
                self.clock() - self._armed_at > self.limit:
            self._armed_at = None
            self.fired += 1
            self.on_hang()
            return True
        return False
