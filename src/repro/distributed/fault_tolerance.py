"""Fault-tolerance monitors — clients of the progress engine.

At thousand-node scale the failure model is: slow chips (stragglers),
hung steps (deadlocked collective after a link flap), and dead hosts.
The monitors here are host-side subsystems polled by the SAME collated
progress loop as checkpointing and data (the paper's thesis: no private
watchdog threads):

* ``HeartbeatMonitor`` — every participant beats per step; a peer whose
  beat is older than ``timeout`` is flagged, triggering
  checkpoint-restart (driven by the trainer).
* ``StragglerDetector`` — EWMA of step durations; steps slower than
  ``threshold ×`` the EWMA are counted per source so schedulers can
  evict persistent stragglers.  Both ledgers are bounded deques/maps —
  a monitor that lives for a million steps must not grow with them.
* ``StepWatchdog`` — wall-clock bound on a single step; firing means the
  collective is presumed hung and restart-from-checkpoint is requested.

Both ``HeartbeatMonitor`` and ``StepWatchdog`` optionally carry a
``MembershipEpoch`` (``collectives.nonblocking``): a dead peer or a hung
step invalidates the epoch from the monitor's subsystem poll, which
fails in-flight persistent-collective starts with a retryable
``MembershipError`` and marks their handles stale — the trainer/serve
engine observe the error, rebuild plans on the surviving mesh, and
resume.  The epoch is duck-typed (anything with ``invalidate(survivors=,
reason=)``) so this module keeps no import edge into the collectives.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Callable, Optional

from repro.core.engine import ProgressEngine, Stream


class HeartbeatMonitor:
    """``beat()`` is called from worker/request threads; ``_poll`` runs
    on whichever thread sweeps the engine's subsystems (often an
    executor worker).  Both paths take ``_lock``: without it a beat
    landing between ``_poll`` reading the stale timestamp and flagging
    the peer would leave the peer marked failed *forever* (the discard
    ran before the add).  Under the lock, flag-vs-beat is a clean
    ordering: whichever runs second wins, and a flagged peer's next beat
    revives it."""

    def __init__(self, engine: ProgressEngine, peers: list[str],
                 timeout: float = 60.0, on_failure: Callable[[str], None] = None,
                 clock=time.monotonic, epoch=None, devices_per_peer: int = 1):
        self.timeout = timeout
        self.on_failure = on_failure or (lambda p: None)
        self.failed: set[str] = set()
        self.clock = clock
        self.epoch = epoch
        self.devices_per_peer = devices_per_peer
        self._lock = threading.Lock()
        self.peers = {p: clock() for p in peers}
        self._sub = engine.register_subsystem(
            "heartbeat", self._poll, cheap=True, priority=2)

    def beat(self, peer: str) -> None:
        with self._lock:
            self.peers[peer] = self.clock()
            self.failed.discard(peer)

    def _poll(self) -> bool:
        now = self.clock()
        newly_dead = []
        with self._lock:
            for peer, last in self.peers.items():
                if peer not in self.failed and now - last > self.timeout:
                    self.failed.add(peer)
                    newly_dead.append(peer)
            survivors = len(self.peers) - len(self.failed)
        # callbacks outside the lock: on_failure/invalidate may run
        # arbitrary user code (and a listener calling alive/beat back
        # into this monitor must not deadlock)
        for peer in newly_dead:
            self.on_failure(peer)
        if newly_dead and self.epoch is not None:
            self.epoch.invalidate(
                survivors=survivors * self.devices_per_peer,
                reason=f"heartbeat timeout: {', '.join(newly_dead)}")
        return bool(newly_dead)

    @property
    def alive(self) -> list[str]:
        with self._lock:
            return [p for p in self.peers if p not in self.failed]


class StragglerDetector:
    def __init__(self, threshold: float = 1.5, alpha: float = 0.1,
                 history_maxlen: int = 1024):
        self.threshold = threshold
        self.alpha = alpha
        self.ewma: float | None = None
        # bounded ledgers (PR-2's bounded error-ledger discipline): the
        # step history is a ring, and the flagged map holds at most
        # `history_maxlen` sources (least-recently-flagged evicted)
        self.history_maxlen = history_maxlen
        self.flagged: "collections.OrderedDict[str, int]" = \
            collections.OrderedDict()
        self.history: "collections.deque[tuple[str, float, bool]]" = \
            collections.deque(maxlen=history_maxlen)

    def record(self, source: str, duration: float) -> bool:
        """Returns True if this step was a straggler."""
        is_straggler = (self.ewma is not None
                        and duration > self.threshold * self.ewma)
        if is_straggler:
            # saturating count, LRU-bounded source set
            count = self.flagged.get(source, 0)
            self.flagged[source] = min(count + 1, self.history_maxlen)
            self.flagged.move_to_end(source)
            while len(self.flagged) > self.history_maxlen:
                self.flagged.popitem(last=False)
        # EWMA excludes outliers so one straggler doesn't poison the mean
        if not is_straggler:
            self.ewma = (duration if self.ewma is None
                         else (1 - self.alpha) * self.ewma + self.alpha * duration)
        self.history.append((source, duration, is_straggler))
        return is_straggler

    def persistent_stragglers(self, min_count: int = 3) -> list[str]:
        return [s for s, n in self.flagged.items() if n >= min_count]


class StepWatchdog:
    def __init__(self, engine: ProgressEngine, limit: float = 300.0,
                 on_hang: Callable[[], None] = None, clock=time.monotonic,
                 epoch=None):
        self.limit = limit
        self.on_hang = on_hang or (lambda: None)
        self.clock = clock
        self.epoch = epoch
        self._armed_at: float | None = None
        self.fired = 0
        # strict: firing the watchdog (on_hang raising) must abort the
        # run loudly, not be isolated into a silent unregister + hang
        self._sub = engine.register_subsystem(
            "watchdog", self._poll, cheap=True, priority=3, strict=True)

    def arm(self) -> None:
        self._armed_at = self.clock()

    def disarm(self) -> None:
        self._armed_at = None

    def _poll(self) -> bool:
        if self._armed_at is not None and \
                self.clock() - self._armed_at > self.limit:
            # disarm BEFORE the callbacks: firing is one-shot per arm —
            # a poll sweep racing the handler must not refire, and the
            # handler itself may progress the engine (more sweeps)
            self._armed_at = None
            self.fired += 1
            if self.epoch is not None:
                # a hung step means the in-flight collective is presumed
                # dead: same membership, but every in-flight start fails
                # retryably so the step can be restarted on fresh plans
                self.epoch.invalidate(
                    survivors=self.epoch.n_devices,
                    reason=f"step watchdog fired after {self.limit}s")
            self.on_hang()
            return True
        return False


def monitor_mesh(engine: ProgressEngine, mesh, axis: str = "data", *,
                 timeout: float, epoch=None, on_failure=None,
                 clock=time.monotonic) -> HeartbeatMonitor:
    """A :class:`HeartbeatMonitor` shaped to a (possibly 2-D) mesh.

    One peer per rank of ``axis``; ``devices_per_peer`` is the product
    of the *other* mesh dims, so losing one data rank on a
    (data=2, model=2) mesh invalidates the epoch with the surviving
    *device* count (what ``elastic.plan_mesh`` consumes), not the
    surviving peer count.  This is the heartbeat wiring the FSDP
    trainer uses: its persistent reduce-scatter/all-gather handles
    registered under the same ``epoch`` fail exactly once on
    invalidation and rebuild on the survivors' mesh."""
    shape = dict(mesh.shape)
    n = shape.get(axis, 1)
    per = 1
    for name, size in shape.items():
        if name != axis:
            per *= size
    return HeartbeatMonitor(engine, [f"{axis}{i}" for i in range(n)],
                            timeout=timeout, on_failure=on_failure,
                            clock=clock, epoch=epoch,
                            devices_per_peer=per)
