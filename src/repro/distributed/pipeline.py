"""GPipe-style pipeline parallelism as an explicit ppermute schedule.

The assignment's multi-pod mesh gives a natural PP mapping: stages on
the `pod` axis (cross-pod DCI links carry only the [mb, S, D] activation
handoff once per microbatch-tick, instead of gradient traffic every
step).  The schedule is the paper's pattern once more: a static state
machine of point-to-point transfers expressed in the dataflow.

Semantics: ``num_stages`` devices along ``axis`` each own a contiguous
block of layers (stacked params sharded on dim 0).  Microbatches enter
stage 0 one tick apart; activations hop stage→stage via ppermute; after
``M + S - 1`` ticks all M microbatches exited stage S-1 (the classic
GPipe bubble of (S-1)/(M+S-1)).  Forward AND backward differentiate
through the tick scan, so gradient pipelining falls out of JAX AD.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat


def gpipe(stage_fn: Callable, mesh, axis: str, num_stages: int,
          params_spec=P(0), x_spec=P()):
    """Build a pipelined apply: (stage_params_stacked, x_microbatches) -> y.

    * ``stage_fn(stage_params, x) -> y``: one stage's computation
      (same shape in/out — the residual-stream case).
    * ``stage_params_stacked``: pytree with leading dim ``num_stages``
      (sharded over ``axis``).
    * ``x_microbatches``: [M, mb, ...] (replicated over ``axis``).

    Returns y_microbatches: [M, mb, ...].
    """
    S = num_stages

    def pipelined(stage_params, xs):
        M = xs.shape[0]
        ticks = M + S - 1

        def body(my_params, xs):
            # inside shard_map: my_params has leading dim 1 (this stage's
            # slice); xs is the full [M, mb, ...] (replicated)
            mine = jax.tree.map(lambda a: a[0], my_params)
            sid = jax.lax.axis_index(axis)
            perm = [(i, (i + 1) % S) for i in range(S)]
            mb_shape = xs.shape[1:]
            # pcast: carries become device-varying inside the tick scan
            carry_in = compat.pcast(jnp.zeros(mb_shape, xs.dtype),
                                    (axis,), to="varying")
            out = compat.pcast(jnp.zeros_like(xs), (axis,), to="varying")

            def tick(state, t):
                carry_in, out = state
                # stage 0 injects microbatch t (if valid); others consume
                inject = jnp.where(t < M, t, 0)
                x0 = xs[inject]
                x_in = jnp.where(sid == 0, x0, carry_in)
                y = stage_fn(mine, x_in)
                # last stage owns microbatch (t - (S-1)) at this tick
                mb_idx = t - (S - 1)
                valid = jnp.logical_and(sid == S - 1, mb_idx >= 0)
                oh = (jax.nn.one_hot(jnp.where(mb_idx >= 0, mb_idx, 0), M,
                                     dtype=y.dtype)
                      * valid.astype(y.dtype))
                out = out + oh.reshape((M,) + (1,) * y.ndim) * y[None]
                carry_next = jax.lax.ppermute(y, axis, perm)
                return (carry_next, out), None

            (carry_in, out), _ = jax.lax.scan(
                tick, (carry_in, out), jnp.arange(ticks))
            # only stage S-1 holds real outputs; psum broadcasts them
            # (every other stage contributes zeros)
            return jax.lax.psum(out, axis)

        return compat.shard_map(
            body, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(axis), stage_params), P()),
            out_specs=P())(stage_params, xs)

    return pipelined


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    return (num_stages - 1) / (num_microbatches + num_stages - 1)
