"""Pipeline parallelism on the progress engine.

Two implementations of the same semantics:

* :func:`gpipe` — the original reference path: a monolithic GPipe
  ``lax.scan`` whose static one-hot/ppermute state machine runs entirely
  inside one XLA program.  Forward AND backward differentiate through
  the tick scan, so gradient pipelining falls out of JAX AD.  The
  runtime cannot see (or overlap) any of it.
* :class:`PipelineSchedule` — 1F1B rebuilt as a **continuation DAG on
  the progress engine** (the paper's §4.6 task-based-runtime
  integration): each stage owns a stream adopted by a
  ``ProgressExecutor``, every (stage, microbatch) forward/backward cell
  is a DAG node gated by ``when_all`` on exactly its inputs — a forward
  cell on (recv_activation, params_ready), a backward cell on
  (recv_grad, stashed_activation) — and micro-batch activation handoffs
  are **persistent user-space nonblocking p2p** (``repro.collectives.
  p2p`` channels: fixed-shape every tick, the ideal ``*_init``+``Start``
  case).  Warmup/steady/cooldown phases are not special-cased anywhere:
  they fall out of the dependency structure.

Semantics (both paths): ``num_stages`` devices along ``axis`` each own
a contiguous block of layers (stacked params sharded on dim 0);
microbatches enter stage 0 one tick apart; activations hop
stage→stage; after the pipeline drains, all M microbatches have exited
stage S-1.  Both schedules burn the same warmup bubble of
(S-1)/(M+S-1) ticks — 1F1B's win is memory: at most min(S, M)
activation stashes live per stage instead of GPipe's M.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.collectives import schedules as S_mod
from repro.core import (INLINE, ContinuationQueue, ProgressEngine, Request,
                        global_engine, jax_future)


def gpipe(stage_fn: Callable, mesh, axis: str, num_stages: int,
          params_spec=P(0), x_spec=P()):
    """Build a pipelined apply: (stage_params_stacked, x_microbatches) -> y.

    * ``stage_fn(stage_params, x) -> y``: one stage's computation
      (same shape in/out — the residual-stream case).
    * ``stage_params_stacked``: pytree with leading dim ``num_stages``
      (sharded over ``axis``).
    * ``x_microbatches``: [M, mb, ...] (replicated over ``axis``).

    Returns y_microbatches: [M, mb, ...].
    """
    S = num_stages

    def pipelined(stage_params, xs):
        M = xs.shape[0]
        ticks = M + S - 1

        def body(my_params, xs):
            # inside shard_map: my_params has leading dim 1 (this stage's
            # slice); xs is the full [M, mb, ...] (replicated)
            mine = jax.tree.map(lambda a: a[0], my_params)
            sid = jax.lax.axis_index(axis)
            perm = S_mod.ring_perm(S)
            mb_shape = xs.shape[1:]
            # pcast: carries become device-varying inside the tick scan
            carry_in = compat.pcast(jnp.zeros(mb_shape, xs.dtype),
                                    (axis,), to="varying")
            out = compat.pcast(jnp.zeros_like(xs), (axis,), to="varying")

            def tick(state, t):
                carry_in, out = state
                # stage 0 injects microbatch t (if valid); others consume
                inject = jnp.where(t < M, t, 0)
                x0 = xs[inject]
                x_in = jnp.where(sid == 0, x0, carry_in)
                y = stage_fn(mine, x_in)
                # last stage owns microbatch (t - (S-1)) at this tick
                mb_idx = t - (S - 1)
                valid = jnp.logical_and(sid == S - 1, mb_idx >= 0)
                oh = (jax.nn.one_hot(jnp.where(mb_idx >= 0, mb_idx, 0), M,
                                     dtype=y.dtype)
                      * valid.astype(y.dtype))
                out = out + oh.reshape((M,) + (1,) * y.ndim) * y[None]
                carry_next = jax.lax.ppermute(y, axis, perm)
                return (carry_next, out), None

            (carry_in, out), _ = jax.lax.scan(
                tick, (carry_in, out), jnp.arange(ticks))
            # only stage S-1 holds real outputs; psum broadcasts them
            # (every other stage contributes zeros)
            return jax.lax.psum(out, axis)

        return compat.shard_map(
            body, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(axis), stage_params), P()),
            out_specs=P())(stage_params, xs)

    return pipelined


SCHEDULES = ("gpipe", "1f1b")


def bubble_fraction(num_stages: int, num_microbatches: int,
                    schedule: str = "gpipe") -> float:
    """Fraction of pipeline ticks burned in the warmup/cooldown bubble.

    GPipe and 1F1B share the same bubble — (S-1)/(M+S-1) — because both
    must fill S-1 ticks before the last stage has work and drain S-1
    after stage 0 runs dry.  1F1B's advantage is peak activation
    memory, not bubble time (see
    :func:`peak_activation_microbatches`)."""
    if schedule not in SCHEDULES:
        raise ValueError(f"schedule must be one of {SCHEDULES}, "
                         f"got {schedule!r}")
    return (num_stages - 1) / (num_microbatches + num_stages - 1)


def peak_activation_microbatches(num_stages: int, num_microbatches: int,
                                 schedule: str = "gpipe") -> int:
    """Peak in-flight activation stashes on the deepest stage (stage 0).

    GPipe runs all M forwards before any backward, so every microbatch's
    activations are live at once; 1F1B starts draining after S forwards,
    capping the stash depth at min(S, M)."""
    if schedule not in SCHEDULES:
        raise ValueError(f"schedule must be one of {SCHEDULES}, "
                         f"got {schedule!r}")
    if schedule == "gpipe":
        return num_microbatches
    return min(num_stages, num_microbatches)


# ---------------------------------------------------------------------------
# The 1F1B grid: a static dependency simulation, cached per (S, M)
# ---------------------------------------------------------------------------

class _Op:
    __slots__ = ("stage", "kind", "mb", "tick", "src_hop")

    def __init__(self, stage, kind, mb, tick, src_hop):
        self.stage = stage
        self.kind = kind          # "F" | "B"
        self.mb = mb
        self.tick = tick
        self.src_hop = src_hop    # ("f"|"b", tick) of the hop feeding it


class _Grid:
    __slots__ = ("S", "M", "forward_only", "ops", "ticks",
                 "hop_edges", "hop_order", "peak_stash")

    def __init__(self, S, M, forward_only, ops, ticks, hop_edges,
                 hop_order, peak_stash):
        self.S = S
        self.M = M
        self.forward_only = forward_only
        self.ops = ops                  # list[_Op] in fire order
        self.ticks = ticks              # number of ticks
        self.hop_edges = hop_edges      # ("f"|"b", tick) -> [(src_stage, mb)]
        self.hop_order = hop_order      # "f"|"b" -> [ticks with a hop]
        self.peak_stash = peak_stash


_grid_cache: dict = {}


def _stage_order(S: int, M: int, s: int, forward_only: bool):
    """Stage s's 1F1B op order: min(M, S-s) warmup forwards, steady
    B/F alternation, cooldown backwards."""
    w = min(M, S - s)
    order = [("F", m) for m in range(w)]
    if forward_only:
        return order + [("F", m) for m in range(w, M)]
    for i in range(M - w):
        order.append(("B", i))
        order.append(("F", w + i))
    for m in range(M - w, M):
        order.append(("B", m))
    return order


def _build_grid(S: int, M: int, forward_only: bool = False) -> _Grid:
    """Greedy tick simulation of the per-stage 1F1B orders under hop
    latency (an activation produced at tick t is consumable downstream
    at tick t+1).  The warmup/steady/cooldown phases are emergent."""
    key = (S, M, forward_only)
    grid = _grid_cache.get(key)
    if grid is not None:
        return grid
    orders = [_stage_order(S, M, s, forward_only) for s in range(S)]
    ptr = [0] * S
    f_tick = [[None] * M for _ in range(S)]
    b_tick = [[None] * M for _ in range(S)]
    ops: list[_Op] = []
    hop_edges: dict = {}
    stash_depth = [0] * S
    peak_stash = 0
    t = 0
    while any(ptr[s] < len(orders[s]) for s in range(S)):
        fired = []
        for s in range(S):
            if ptr[s] >= len(orders[s]):
                continue
            kind, m = orders[s][ptr[s]]
            if kind == "F":
                ready = s == 0 or (f_tick[s - 1][m] is not None
                                   and f_tick[s - 1][m] < t)
            elif s == S - 1:
                ready = f_tick[s][m] is not None and f_tick[s][m] < t
            else:
                ready = (b_tick[s + 1][m] is not None
                         and b_tick[s + 1][m] < t
                         and f_tick[s][m] is not None and f_tick[s][m] < t)
            if ready:
                fired.append((s, kind, m))
        if not fired:
            raise AssertionError(
                f"1F1B grid deadlock at tick {t} (S={S}, M={M})")
        for s, kind, m in fired:
            ptr[s] += 1
            if kind == "F":
                f_tick[s][m] = t
                src = ("f", f_tick[s - 1][m]) if s > 0 else None
                if s < S - 1:
                    hop_edges.setdefault(("f", t), []).append((s, m))
                if not forward_only:
                    stash_depth[s] += 1
                    peak_stash = max(peak_stash, stash_depth[s])
            else:
                b_tick[s][m] = t
                src = ("b", b_tick[s + 1][m]) if s < S - 1 else None
                if s > 0:
                    hop_edges.setdefault(("b", t), []).append((s, m))
                stash_depth[s] -= 1
            ops.append(_Op(s, kind, m, t, src))
        t += 1
    hop_order = {
        "f": sorted(tk for d, tk in hop_edges if d == "f"),
        "b": sorted(tk for d, tk in hop_edges if d == "b"),
    }
    grid = _Grid(S, M, forward_only, ops, t, hop_edges, hop_order,
                 peak_stash)
    _grid_cache[key] = grid
    return grid


# ---------------------------------------------------------------------------
# The event-driven schedule
# ---------------------------------------------------------------------------

class _StepRun:
    """All mutable state of one in-flight pipeline step."""

    __slots__ = ("grid", "params_stages", "xs", "targets", "scale",
                 "inbox_f", "inbox_b", "stash", "dp_acc", "losses",
                 "outputs", "staging", "cell_req", "hop_rreq",
                 "params_ready", "done", "_lock", "t0", "cell_spans")

    def __init__(self, grid):
        self.grid = grid
        self.inbox_f: dict = {}
        self.inbox_b: dict = {}
        self.stash: dict = {}
        self.losses: dict = {}
        self.outputs: dict = {}
        self.staging: dict = {}
        self.cell_req: dict = {}
        self.hop_rreq: dict = {}
        # per-stage [(t_issue, t_done), ...] — cells on a stage are
        # serial, so wall - sum(spans) is that stage's true idle time
        self.cell_spans: dict = {}
        self.done = Request(tag="pipeline_step")
        self._lock = threading.Lock()
        self.t0 = time.monotonic()


class PipelineSchedule:
    """1F1B pipeline parallelism as a continuation DAG.

    * ``stage_fn(stage_params, x) -> y`` — one stage's computation
      (same activation shape in/out, the residual-stream case).
    * ``loss_fn(y, target) -> scalar`` — the loss head, applied to the
      last stage's output per microbatch (required for :meth:`step`).
    * ``mesh``/``axis`` — a 1-D mesh whose ``axis`` has ``num_stages``
      devices; stacked params ``[S, ...]`` are sharded over it, and
      each stage's cells dispatch on that stage's own device + stream.

    Execution model: :meth:`istep` builds one DAG per call from the
    cached (S, M) grid.  Every (stage, microbatch) forward/backward
    cell is a ``ContinuationQueue.node`` gated by ``when_all`` on its
    true inputs — the p2p receive carrying its activation (or gradient)
    and the previous cell on its stage stream (serial stage order; for
    backward cells also the forward cell that stashed the activation).
    When the gate fires, a one-shot issue task is enqueued on the
    stage's stream, so the adopting executor worker — not the caller —
    dispatches the jitted per-stage program.  Handoffs ride TWO
    persistent p2p channels (forward ring for activations, reverse ring
    for gradients), one ``start`` per tick with edges stacked, so a
    steady-state step pays split+dispatch per hop and zero compiles.

    The whole step completes through continuations: ``istep`` returns a
    Request, and nothing in the DAG ever polls or blocks — the only
    blocking wait is the caller's (``step`` = ``istep`` + wait), counted
    in ``blocking_waits``."""

    def __init__(self, stage_fn: Callable, mesh, axis: str,
                 num_stages: int, *, loss_fn: Callable | None = None,
                 engine: Optional[ProgressEngine] = None, executor=None,
                 epoch=None, name: str = "pipe"):
        from repro.collectives.p2p import P2P
        self.stage_fn = stage_fn
        self.loss_fn = loss_fn
        self.mesh = mesh
        self.axis = axis
        self.S = num_stages
        if dict(mesh.shape).get(axis) != num_stages:
            raise ValueError(
                f"mesh axis {axis!r} has {dict(mesh.shape).get(axis)} "
                f"device(s), schedule wants {num_stages} stages")
        self.engine = engine if engine is not None else global_engine()
        self.executor = executor
        self.epoch = epoch
        self.name = name
        self.devices = list(mesh.devices.flat)
        mk = executor.stream if executor is not None else self.engine.stream
        self.stage_streams = [mk(f"{name}-stage{s}")
                              for s in range(num_stages)]
        self.dag_stream = mk(f"{name}-dag")
        # DAG gates fire INLINE on whichever thread progresses the dag
        # stream (an executor worker, or the step waiter's sweep)
        self.queue = ContinuationQueue(self.engine, self.dag_stream,
                                       policy=INLINE, name=f"{name}-dag-q")
        self.p2p = P2P(self.engine, executor=executor,
                       name=f"{name}-p2p", epoch=epoch)
        self._chan = {}              # "f"/"b" -> P2PChannel
        self._zeros = None           # per-stage [1, *act] zero shards
        self._act_sig = None
        self._sharding = NamedSharding(mesh, P(axis))
        self.steps = 0
        self.blocking_waits = 0
        # set after each step: {"window_s", "idle_s" (per stage),
        # "bubble"} — measured idle from the cell spans, comparable to
        # bubble_fraction's analytic value
        self.last_step_timing: dict | None = None
        self._build_programs()

    # -- jitted per-stage programs (one wrapper each; jit caches one
    # executable per stage device) ----------------------------------------
    def _build_programs(self):
        stage_fn, loss_fn = self.stage_fn, self.loss_fn

        def fwd(p1, x1):
            p0 = jax.tree.map(lambda a: a[0], p1)
            return stage_fn(p0, x1[0])[None]

        def bwd(p1, x1, dy1, acc):
            p0 = jax.tree.map(lambda a: a[0], p1)
            _, pull = jax.vjp(stage_fn, p0, x1[0])
            dp, dx = pull(dy1[0])
            acc = jax.tree.map(lambda a, d: a + d[None], acc, dp)
            return dx[None], acc

        def last_bwd(p1, x1, t1, scale, acc):
            p0 = jax.tree.map(lambda a: a[0], p1)

            def head(pp, xx):
                return loss_fn(stage_fn(pp, xx), t1[0])

            loss, pull = jax.vjp(head, p0, x1[0])
            dp, dx = pull(scale)
            acc = jax.tree.map(lambda a, d: a + d[None], acc, dp)
            return loss, dx[None], acc

        self._fwd = jax.jit(fwd)
        self._bwd = jax.jit(bwd, donate_argnums=(3,))
        self._last_bwd = jax.jit(last_bwd, donate_argnums=(4,))

    # -- public API --------------------------------------------------------
    def step(self, params, xs, targets, timeout: float = 600.0):
        """Blocking 1F1B train step: returns ``(loss, grads)`` with
        ``loss`` the mean microbatch loss (device scalar) and ``grads``
        the stacked ``[S, ...]`` gradient tree sharded over the stage
        axis — bit-identical to sequential per-stage accumulation."""
        return self._wait(self.istep(params, xs, targets), timeout)

    def istep(self, params, xs, targets) -> Request:
        """Nonblocking step: build the DAG, return its completion
        Request (value ``(loss, grads)``)."""
        if self.loss_fn is None:
            raise ValueError("istep needs loss_fn (construct the "
                             "schedule with one, or use apply)")
        if targets is None:
            raise ValueError("istep needs targets for the loss head")
        return self._launch(params, xs, targets, forward_only=False)

    def apply(self, params, xs, timeout: float = 600.0):
        """Forward-only pipelined apply (gpipe-comparable): returns
        y_microbatches [M, mb, ...] (on the last stage's device)."""
        req = self._launch(params, xs, None, forward_only=True)
        return self._wait(req, timeout)

    def stats(self) -> dict:
        hops = {d: c.starts for d, c in self._chan.items()}
        return {
            "steps": self.steps,
            "blocking_waits": self.blocking_waits,
            "hop_starts": hops,
            "p2p_stream_completions": self.p2p.stream.completions,
            "p2p_issued": self.p2p.issued,
            "p2p_completed": self.p2p.completed,
            "stage_stream_completions": [s.completions
                                         for s in self.stage_streams],
            "dag_executed": self.queue.executed,
        }

    def close(self):
        self.p2p.close()
        self.queue.close()
        if self.executor is not None:
            for s in self.stage_streams + [self.dag_stream]:
                if self.executor.owns(s):
                    self.executor.release(s)

    # -- DAG construction --------------------------------------------------
    def _launch(self, params, xs, targets, *, forward_only: bool) -> Request:
        S, eng = self.S, self.engine
        M = int(xs.shape[0])
        grid = _build_grid(S, M, forward_only)
        run = _StepRun(grid)
        self.steps += 1

        params = jax.device_put(params, self._sharding)
        run.params_stages = [self._stage_view(params, s) for s in range(S)]
        run.xs = jax.device_put(xs, self.devices[0])
        run.targets = None if targets is None else \
            jax.device_put(targets, self.devices[-1])
        run.scale = jax.device_put(jnp.float32(1.0 / M), self.devices[-1])
        run.dp_acc = None if forward_only else [
            jax.tree.map(jnp.zeros_like, run.params_stages[s])
            for s in range(S)]

        act_shape = tuple(xs.shape[1:])
        self._ensure_channels(act_shape, xs.dtype)

        # pre-create every completion request the DAG will gate on
        run.params_ready = [
            jax_future(eng, jax.tree.leaves(run.params_stages[s]),
                       self.stage_streams[s]) for s in range(S)]
        for op in grid.ops:
            run.cell_req[(op.stage, op.kind, op.mb)] = Request(
                tag=f"{op.kind}{op.stage}.{op.mb}")
        for d in ("f", "b"):
            for t in grid.hop_order[d]:
                run.hop_rreq[(d, t)] = Request(tag=f"hop{d}@{t}")

        # wire the cells
        prev_on_stage: list = [None] * S
        for op in grid.ops:
            creq = run.cell_req[(op.stage, op.kind, op.mb)]
            deps = [run.params_ready[op.stage]]
            if prev_on_stage[op.stage] is not None:
                deps.append(prev_on_stage[op.stage])
            if op.src_hop is not None:
                deps.append(run.hop_rreq[op.src_hop])
            if op.kind == "B":
                # the stashed activation: the forward cell of (s, m)
                deps.append(run.cell_req[(op.stage, "F", op.mb)])
            node = self.queue.node(
                (lambda *_vals, op=op, creq=creq:
                 self._enqueue_cell(run, op, creq)), deps)
            self.queue.attach(
                node, lambda _rq: None,
                on_error=lambda rq: self._fail(run, rq.exception))
            prev_on_stage[op.stage] = creq

        # wire the hops: one persistent start per (direction, tick),
        # chained per direction (one outstanding start per channel)
        for d in ("f", "b"):
            prev = None
            for t in grid.hop_order[d]:
                rreq = run.hop_rreq[(d, t)]
                edges = grid.hop_edges[(d, t)]
                deps = [run.cell_req[(s, "F" if d == "f" else "B", m)]
                        for s, m in edges]
                if prev is not None:
                    deps.append(prev)
                node = self.queue.node(
                    (lambda *_vals, d=d, t=t, edges=edges, rreq=rreq:
                     self._start_hop(run, d, t, edges, rreq)), deps)
                self.queue.attach(
                    node, lambda _rq: None,
                    on_error=lambda rq: self._fail(run, rq.exception))
                prev = rreq

        # the step gate: every cell retired -> finalize
        gate = self.queue.when_all(list(run.cell_req.values()))
        self.queue.attach(
            gate, lambda _rq: self._finalize(run),
            on_error=lambda rq: self._fail(run, rq.exception))
        return run.done

    # -- node bodies -------------------------------------------------------
    def _enqueue_cell(self, run: _StepRun, op: _Op, creq: Request) -> None:
        """Gate fired: enqueue the one-shot issue task on the stage's
        stream; the adopting worker dispatches the jitted program."""
        if run.done.is_complete:
            return

        def issue(_thing):
            if run.done.is_complete:
                return "done"
            t_issue = time.monotonic()
            try:
                out = self._dispatch(run, op)
            except BaseException as exc:  # noqa: BLE001
                self._fail(run, exc)
                return "done"
            fut = jax_future(self.engine, out,
                             self.stage_streams[op.stage])

            def _done(_rq):
                run.cell_spans.setdefault(op.stage, []).append(
                    (t_issue, time.monotonic()))
                creq.complete(None)

            self.queue.attach(
                fut, _done,
                on_error=lambda rq: self._fail(
                    run, rq.exception or RuntimeError("cell failed")))
            return "done"

        self.engine.async_start(issue, None, self.stage_streams[op.stage])

    def _dispatch(self, run: _StepRun, op: _Op):
        """Run one cell's jitted program on its stage device (async
        dispatch — the returned arrays gate the cell's completion)."""
        s, m = op.stage, op.mb
        p = run.params_stages[s]
        if op.kind == "F":
            x1 = run.inbox_f.pop((s, m)) if s > 0 else run.xs[m:m + 1]
            if not run.grid.forward_only:
                run.stash[(s, m)] = x1
            y1 = self._fwd(p, x1)
            if s < self.S - 1:
                run.staging[("f", op.tick, s)] = y1
            elif run.grid.forward_only:
                run.outputs[m] = y1
            return y1
        if s == self.S - 1:
            x1 = run.stash.pop((s, m))
            t1 = run.targets[m:m + 1]
            loss, dx1, run.dp_acc[s] = self._last_bwd(
                p, x1, t1, run.scale, run.dp_acc[s])
            run.losses[m] = loss
            if s > 0:
                run.staging[("b", op.tick, s)] = dx1
            return (loss, dx1)
        x1 = run.stash.pop((s, m))
        dy1 = run.inbox_b.pop((s, m))
        dx1, run.dp_acc[s] = self._bwd(p, x1, dy1, run.dp_acc[s])
        if s > 0:
            run.staging[("b", op.tick, s)] = dx1
        return dx1

    def _start_hop(self, run: _StepRun, d: str, t: int, edges,
                   rreq: Request) -> None:
        """All of tick t's producing cells retired: stack their slices
        (zeros elsewhere) and start the persistent channel."""
        if run.done.is_complete:
            return
        S = self.S
        shards = list(self._zeros)
        for s, _m in edges:
            shards[s] = run.staging.pop((d, t, s))
        payload = jax.make_array_from_single_device_arrays(
            (S,) + self._act_sig[0], self._sharding, shards)
        chan = self._chan[d]
        try:
            chan.send.start(payload)
            inner = chan.recv.start()
        except BaseException as exc:  # noqa: BLE001
            self._fail(run, exc)
            return

        def deliver(rq):
            value = rq.value()
            recv_shards = self._by_stage(value)
            for s, m in edges:
                if d == "f":
                    run.inbox_f[(s + 1, m)] = recv_shards[s + 1]
                else:
                    run.inbox_b[(s - 1, m)] = recv_shards[s - 1]
            rreq.complete(None)

        self.queue.attach(
            inner, deliver,
            on_error=lambda rq: self._fail(
                run, rq.exception or RuntimeError("p2p hop failed")))

    def _finalize(self, run: _StepRun) -> None:
        if run.done.is_complete:
            return
        try:
            if run.grid.forward_only:
                ys = jnp.concatenate([run.outputs[m]
                                      for m in range(run.grid.M)])
                result = ys
            else:
                loss = run.losses[0]
                for m in range(1, run.grid.M):
                    loss = loss + run.losses[m]
                loss = loss * run.scale
                grads = self._stack_grads(run.dp_acc)
                result = (loss, grads)
        except BaseException as exc:  # noqa: BLE001
            self._fail(run, exc)
            return
        self.last_step_timing = self._timing(run)
        with run._lock:
            if not run.done.is_complete:
                run.done.complete(result)

    def _timing(self, run: _StepRun) -> dict | None:
        """Measured bubble: per-stage idle inside the step window.  Per
        stage, busy = sum of its (serial) cell spans; idle = window -
        busy; the mean idle fraction across stages is directly
        comparable to :func:`bubble_fraction`'s analytic value."""
        spans = run.cell_spans
        if len(spans) != self.S or not all(spans.values()):
            return None
        t_lo = min(t0 for ss in spans.values() for t0, _ in ss)
        t_hi = max(t1 for ss in spans.values() for _, t1 in ss)
        window = max(t_hi - t_lo, 1e-9)
        idle = [window - sum(t1 - t0 for t0, t1 in spans[s])
                for s in range(self.S)]
        return {"window_s": window, "idle_s": idle,
                "bubble": sum(idle) / (window * self.S),
                "cells": [len(spans[s]) for s in range(self.S)],
                "grid_ticks": run.grid.ticks}

    # -- helpers -----------------------------------------------------------
    def _fail(self, run: _StepRun, exc: BaseException | None) -> None:
        exc = exc or RuntimeError("pipeline step failed")
        with run._lock:
            if run.done.is_complete:
                return
            run.done.fail(exc)
        # release every still-pending gate so sibling branches retire
        # instead of hanging (their nodes observe done and no-op)
        for req in list(run.cell_req.values()) + list(run.hop_rreq.values()):
            if not req.is_complete:
                try:
                    req.fail(exc)
                except BaseException:  # noqa: BLE001
                    pass

    def _stage_view(self, params, s: int):
        return jax.tree.map(lambda l: self._by_stage(l)[s], params)

    @staticmethod
    def _by_stage(arr):
        shards = sorted(arr.addressable_shards,
                        key=lambda sh: sh.index[0].start or 0)
        return [sh.data for sh in shards]

    def _ensure_channels(self, act_shape, dtype) -> None:
        sig = (tuple(act_shape), jnp.dtype(dtype))
        if self._act_sig == sig:
            return
        if self._act_sig is not None:
            for c in self._chan.values():
                c.close()
            self._chan = {}
        self._act_sig = sig
        self._zeros = [
            jax.device_put(jnp.zeros((1,) + sig[0], sig[1]),
                           self.devices[s]) for s in range(self.S)]
        if self.S > 1:
            like = jax.ShapeDtypeStruct((self.S,) + sig[0], sig[1])
            self._chan = {
                "f": self.p2p.channel_init(like, self.mesh, self.axis,
                                           tag=f"{self.name}-act"),
                "b": self.p2p.channel_init(like, self.mesh, self.axis,
                                           tag=f"{self.name}-grad",
                                           reverse=True),
            }

    def _stack_grads(self, dp_acc):
        S = self.S
        leaves0, treedef = jax.tree.flatten(dp_acc[0])
        per_stage = [jax.tree.leaves(dp_acc[s]) for s in range(S)]
        out = []
        for i, l0 in enumerate(leaves0):
            shape = (S,) + tuple(l0.shape[1:])
            out.append(jax.make_array_from_single_device_arrays(
                shape, self._sharding, [per_stage[s][i] for s in range(S)]))
        return jax.tree.unflatten(treedef, out)

    def _wait(self, req: Request, timeout: float):
        """The only blocking wait in the lifecycle: drive progress (or
        yield to the executor) until the step's DAG completes."""
        self.blocking_waits += 1
        ex = self.executor if self.executor is not None \
            else self.engine.executor
        owned = ex is not None and ex.running and ex.owns(self.dag_stream)
        t0 = time.monotonic()
        while not req.is_complete:
            if owned:
                time.sleep(20e-6)
            else:
                made = self.engine.progress_all()
                if not made:
                    time.sleep(5e-6)
            if timeout is not None and not req.is_complete \
                    and time.monotonic() - t0 > timeout:
                raise TimeoutError(
                    f"pipeline step timed out after {timeout}s "
                    f"({self.stats()})")
        return req.value()

    def __repr__(self):
        return (f"PipelineSchedule(S={self.S}, axis={self.axis!r}, "
                f"steps={self.steps})")
