"""Flash-decode Pallas kernel: one query token vs. a long KV cache.

Decode attention is HBM-bandwidth-bound (the cache read dominates); the
kernel streams the cache through VMEM in blocks, maintaining the online
max/denominator in scratch.  Grid: (batch, kv_head, cache_blocks) with
the cache-block axis innermost/sequential.  All query heads of one KV
head (the GQA group) are processed together — q block [G, hd] hits the
MXU as a tall-skinny GEMM against [block_k, hd].

Per-sequence valid lengths mask the tail block (continuous batching
serves sequences of different lengths from one padded cache).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fd_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, block_k: int):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    k_start = ki * block_k

    @pl.when(k_start < length)
    def _run():
        q = q_ref[0, 0, :, :].astype(jnp.float32) * scale    # [G, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # [bk, hd]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [G,bk]
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = (acc_scr[...] * corr
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _fini():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_decode(q, k_cache, v_cache, lengths, *, block_k: int = 512,
                 interpret: bool = False):
    """q: [B,H,hd]; caches: [B,S,KVH,hd]; lengths: [B] -> [B,H,hd]."""
    B, H, hd = q.shape
    _, S, KVH, _ = k_cache.shape
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    block_k = min(block_k, S)
    assert S % block_k == 0, (S, block_k)
    grid = (B, KVH, S // block_k)
    qg = q.reshape(B, KVH, G, hd)

    kernel = functools.partial(_fd_kernel, scale=scale, block_k=block_k)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM if not interpret else None,
                         block_shape=(1,),
                         index_map=lambda b, h, ki: (b,)),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, ki: (b, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, qg, k_cache, v_cache)
    return out.reshape(B, H, hd)
