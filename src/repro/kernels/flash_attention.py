"""Flash-attention forward Pallas kernel (TPU target).

TPU adaptation of the CUDA flash algorithm (DESIGN.md §6): the SRAM
tiling becomes explicit VMEM BlockSpecs; the MXU wants the [block_q,
head_dim] × [head_dim, block_k] GEMM shapes aligned to 128; the running
max/denominator live in VMEM scratch across the kv-block grid dimension
(sequential innermost grid axis on TPU), replacing the CUDA thread-block
reduction.

Grid: (batch, heads, q_blocks, kv_blocks) — kv innermost/sequential.
GQA is handled in the q-head → kv-head index map (no KV repeat in HBM).
Causality is exploited by masking; fully-masked kv blocks are skipped
via ``pl.when`` (the 2× causal FLOP saving).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, block_q: int, block_k: int, causal: bool,
               sq: int, sk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal alignment: q position i is absolute position i + (sk - sq),
    # i.e. the last query attends to the full kv (prefill-with-history)
    q_abs0 = qi * block_q + (sk - sq)
    k_start = ki * block_k

    # skip kv blocks entirely above the diagonal
    should_run = True
    if causal:
        should_run = k_start <= q_abs0 + block_q - 1

    @pl.when(should_run)
    def _run():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale   # [bq, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # [bk, hd]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [bq,bk]
        if causal:
            qpos = q_abs0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = (acc_scr[...] * corr
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _fini():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: [B,Sq,H,hd]; k,v: [B,Sk,KVH,hd] -> [B,Sq,H,hd]."""
    B, Sq, H, hd = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, block_q, Sk, block_k)
    grid = (B, H, Sq // block_q, Sk // block_k)

    kernel = functools.partial(
        _fa_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, sq=Sq, sk=Sk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, qi, ki: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, hd), q.dtype),
        scratch_shapes=[
            # m, l: [block_q, 1]; acc: [block_q, hd] — all VMEM-resident
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
