"""Jit'd public wrappers for the Pallas kernels.

Each op dispatches on ``impl``:

* ``"pallas"``    — the TPU kernel (use ``interpret=True`` on CPU).
* ``"xla"``       — the pure-jnp reference (also the backward path:
  forward runs the kernel, backward rematerializes through the
  reference formulation via ``jax.custom_vjp``).

On this CPU container the kernels are validated with ``interpret=True``;
on a real TPU the same entry points run compiled Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _fa_kernel
from repro.kernels.decode_attention import flash_decode as _fd_kernel
from repro.kernels.rmsnorm import rmsnorm_bwd as _rms_bwd_kernel
from repro.kernels.rmsnorm import rmsnorm_fwd as _rms_fwd_kernel
from repro.kernels.ssd_scan import ssd_chunk as _ssd_kernel


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


# ---------------------------------------------------------------------------
# flash attention (fwd kernel; bwd via reference remat)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, causal: bool = True, interpret: bool | None = None):
    itp = (not _on_tpu()) if interpret is None else interpret
    return _fa_kernel(q, k, v, causal=causal, interpret=itp)


def _fa_fwd(q, k, v, causal, interpret):
    return flash_attention(q, k, v, causal, interpret), (q, k, v)


def _fa_bwd(causal, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.flash_attention_ref(
        q_, k_, v_, causal=causal), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ---------------------------------------------------------------------------
# flash decode (inference only — no vjp needed)
# ---------------------------------------------------------------------------

def flash_decode(q, k_cache, v_cache, lengths, interpret: bool | None = None):
    itp = (not _on_tpu()) if interpret is None else interpret
    return _fd_kernel(q, k_cache, v_cache, lengths, interpret=itp)


# ---------------------------------------------------------------------------
# fused rmsnorm (fwd + bwd kernels)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def rmsnorm(x, scale, eps: float = 1e-6, interpret: bool | None = None):
    itp = (not _on_tpu()) if interpret is None else interpret
    shape = x.shape
    y = _rms_fwd_kernel(x.reshape(-1, shape[-1]), scale, eps, interpret=itp)
    return y.reshape(shape)


def _rms_fwd(x, scale, eps, interpret):
    return rmsnorm(x, scale, eps, interpret), (x, scale)


def _rms_bwd(eps, interpret, res, g):
    x, scale = res
    itp = (not _on_tpu()) if interpret is None else interpret
    shape = x.shape
    dx, ds = _rms_bwd_kernel(x.reshape(-1, shape[-1]), scale,
                             g.reshape(-1, shape[-1]), eps, interpret=itp)
    return dx.reshape(shape), jnp.sum(ds, axis=0).astype(scale.dtype)


rmsnorm.defvjp(_rms_fwd, _rms_bwd)


# ---------------------------------------------------------------------------
# SSD intra-chunk (fwd kernel; bwd via reference remat)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def ssd_chunk(x, b, c, dt, a_log, interpret: bool | None = None):
    itp = (not _on_tpu()) if interpret is None else interpret
    return _ssd_kernel(x, b, c, dt, a_log, interpret=itp)


def _ssd_fwd(x, b, c, dt, a_log, interpret):
    return ssd_chunk(x, b, c, dt, a_log, interpret), (x, b, c, dt, a_log)


def _ssd_bwd(interpret, res, gs):
    x, b, c, dt, a_log = res
    _, vjp = jax.vjp(ref.ssd_chunk_ref, x, b, c, dt, a_log)
    return vjp(gs)


ssd_chunk.defvjp(_ssd_fwd, _ssd_bwd)
