"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: [B,Sq,H,hd]; k,v: [B,Sk,KVH,hd] -> [B,Sq,H,hd] (f32 math)."""
    B, Sq, H, hd = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    kr = jnp.repeat(k, G, axis=2)
    vr = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                   kr.astype(jnp.float32))
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
    return o.astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q: [B,H,hd]; caches: [B,S,KVH,hd]; lengths: [B] -> [B,H,hd]."""
    B, H, hd = q.shape
    _, S, KVH, _ = k_cache.shape
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    kr = jnp.repeat(k_cache, G, axis=2)
    vr = jnp.repeat(v_cache, G, axis=2)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32) * scale,
                   kr.astype(jnp.float32))
    valid = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhk,bkhd->bhd", p, vr.astype(jnp.float32))
    return o.astype(q.dtype)


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def ssd_chunk_ref(x, b, c, dt, a_log):
    """One-chunk SSD oracle (intra-chunk + emitted chunk state).

    x: [B,Q,nh,hp]; b,c: [B,Q,ds]; dt: [B,Q,nh] (post-softplus);
    a_log: [nh].  Returns (y_intra [B,Q,nh,hp], state [B,nh,hp,ds],
    decay_total [B,nh]).
    """
    B, Q, nh, hp = x.shape
    ds = b.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))
    dA = dt.astype(jnp.float32) * a                     # [B,Q,nh]
    cum = jnp.cumsum(dA, axis=1)
    seg = cum[:, :, None, :] - cum[:, None, :, :]       # [B,Q,Q,nh]
    tri = jnp.tril(jnp.ones((Q, Q), jnp.float32))
    Lmat = jnp.exp(jnp.clip(seg, -60.0, 0.0)) * tri[None, :, :, None]
    cb = jnp.einsum("bis,bjs->bij", c.astype(jnp.float32), b.astype(jnp.float32))
    w = cb[..., None] * Lmat
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
    y = jnp.einsum("bijh,bjhp->bihp", w, xdt)
    decay_out = jnp.exp(jnp.clip(cum[:, -1:, :] - cum, -60.0, 0.0))
    state = jnp.einsum("bjhp,bjh,bjs->bhps", xdt, decay_out, b.astype(jnp.float32))
    decay_total = jnp.exp(jnp.clip(cum[:, -1, :], -60.0, 0.0))
    return y.astype(x.dtype), state, decay_total
