"""Fused RMSNorm Pallas kernel (fwd + bwd) — the classic bandwidth win:
unfused, the norm reads x three times (mean-square, normalize, scale);
fused it reads once, computes in VMEM, writes once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_fwd_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    o_ref[...] = (x * inv * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _rms_bwd_kernel(x_ref, s_ref, g_ref, dx_ref, ds_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    xhat = x * inv
    ds_ref[0, :] = jnp.sum(g * xhat, axis=0).astype(ds_ref.dtype)
    gs = g * s
    # d/dx of xhat·s: inv·(gs − xhat·mean(gs⊙xhat))
    dx = inv * (gs - xhat * jnp.mean(gs * xhat, axis=-1, keepdims=True))
    dx_ref[...] = dx.astype(dx_ref.dtype)


def rmsnorm_fwd(x, scale, eps: float = 1e-6, *, block_rows: int = 256,
                interpret: bool = False):
    """x: [N, D]; scale: [D]."""
    N, D = x.shape
    block_rows = min(block_rows, N)
    assert N % block_rows == 0
    return pl.pallas_call(
        functools.partial(_rms_fwd_kernel, eps=eps),
        grid=(N // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, D), x.dtype),
        interpret=interpret,
    )(x, scale)


def rmsnorm_bwd(x, scale, g, eps: float = 1e-6, *, block_rows: int = 256,
                interpret: bool = False):
    """Returns (dx [N,D], dscale_partials [n_blocks, D])."""
    N, D = x.shape
    block_rows = min(block_rows, N)
    assert N % block_rows == 0
    nb = N // block_rows
    dx, ds = pl.pallas_call(
        functools.partial(_rms_bwd_kernel, eps=eps),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, D), x.dtype),
            jax.ShapeDtypeStruct((nb, D), jnp.float32),
        ],
        interpret=interpret,
    )(x, scale, g)
    return dx, ds
