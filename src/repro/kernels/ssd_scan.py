"""Mamba2 SSD intra-chunk Pallas kernel (TPU target).

The SSD "dual form" makes the intra-chunk computation a pair of GEMMs
plus a masked decay product — ideal MXU work.  This kernel computes, per
(batch, chunk, head-block):

    y_intra = ((C·Bᵀ) ⊙ L) · (dt⊙x)      (quadratic-within-chunk term)
    state   = (decay_out ⊙ dt⊙x)ᵀ · B     (chunk's emitted state)

The inter-chunk recurrence (linear scan over chunks) stays outside in
jnp — it is O(S/Q) sequential steps on [nh, hp, ds] tensors and fuses
fine in XLA; the quadratic work is what needs VMEM tiling.

Grid: (B, n_chunks, head_blocks); one chunk's [Q, ·] tensors are VMEM
blocks (Q = 128–256 aligns the GEMMs to the MXU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, alog_ref, y_ref, st_ref, dec_ref,
                *, block_h: int, q: int):
    # blocks: x [1,Q,bh,hp]; b/c [1,Q,ds]; dt [1,Q,bh]; alog [bh]
    x = x_ref[0].astype(jnp.float32)          # [Q, bh, hp]
    bm = b_ref[0].astype(jnp.float32)         # [Q, ds]
    cm = c_ref[0].astype(jnp.float32)         # [Q, ds]
    dt = dt_ref[0].astype(jnp.float32)        # [Q, bh]
    a = -jnp.exp(alog_ref[...].astype(jnp.float32))   # [bh]

    dA = dt * a[None, :]                      # [Q, bh]
    cum = jnp.cumsum(dA, axis=0)              # [Q, bh]
    seg = cum[:, None, :] - cum[None, :, :]   # [Q, Q, bh]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tri = (iota_i >= iota_j).astype(jnp.float32)
    Lmat = jnp.exp(jnp.clip(seg, -60.0, 0.0)) * tri[:, :, None]

    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    w = cb[:, :, None] * Lmat                 # [Q, Q, bh]
    xdt = x * dt[:, :, None]                  # [Q, bh, hp]

    # y[i,h,p] = sum_j w[i,j,h] xdt[j,h,p] — batched over h via dot_general
    y = jax.lax.dot_general(
        w.transpose(2, 0, 1), xdt.transpose(1, 0, 2),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)   # [bh, Q, hp]
    y_ref[0] = y.transpose(1, 0, 2).astype(y_ref.dtype)

    decay_out = jnp.exp(jnp.clip(cum[-1:, :] - cum, -60.0, 0.0))  # [Q, bh]
    xd = xdt * decay_out[:, :, None]          # [Q, bh, hp]
    st = jax.lax.dot_general(
        xd.transpose(1, 2, 0), bm,
        (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # [bh, hp, ds]
    st_ref[0] = st
    dec_ref[0] = jnp.exp(jnp.clip(cum[-1, :], -60.0, 0.0))


def ssd_chunk(x, b, c, dt, a_log, *, block_h: int = 8, interpret: bool = False):
    """Intra-chunk SSD for stacked chunks.

    x: [B,Q,nh,hp]; b,c: [B,Q,ds]; dt: [B,Q,nh]; a_log: [nh].
    Returns (y_intra [B,Q,nh,hp], states [B,nh,hp,ds], decay_total [B,nh]).
    """
    B, Q, nh, hp = x.shape
    ds = b.shape[-1]
    block_h = min(block_h, nh)
    assert nh % block_h == 0
    grid = (B, nh // block_h)

    kernel = functools.partial(_ssd_kernel, block_h=block_h, q=Q)
    y, st, dec = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, block_h, hp), lambda bi, hi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, Q, ds), lambda bi, hi: (bi, 0, 0)),
            pl.BlockSpec((1, Q, ds), lambda bi, hi: (bi, 0, 0)),
            pl.BlockSpec((1, Q, block_h), lambda bi, hi: (bi, 0, hi)),
            pl.BlockSpec((block_h,), lambda bi, hi: (hi,)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, block_h, hp), lambda bi, hi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, block_h, hp, ds), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, block_h), lambda bi, hi: (bi, hi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Q, nh, hp), x.dtype),
            jax.ShapeDtypeStruct((B, nh, hp, ds), jnp.float32),
            jax.ShapeDtypeStruct((B, nh), jnp.float32),
        ],
        interpret=interpret,
    )(x, b, c, dt, a_log)
    return y, st, dec
