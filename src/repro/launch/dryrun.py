import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.
#
# The container has ONE real CPU device; the production meshes need 512
# placeholder devices, so the XLA_FLAGS override above runs before ANY
# other import (jax locks the device count on first init).
# ruff: noqa: E402
import argparse
import json
import time
import traceback

import jax

from repro.analysis import roofline as roofline_mod
from repro.configs import get_config, list_configs
from repro.configs.shapes import SHAPES, get_shape, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell
from repro.models import registry


def _parse_value(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    if v in ("true", "True"):
        return True
    if v in ("false", "False"):
        return False
    return v


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save_hlo_dir: str | None = None,
             cfg_overrides: dict | None = None,
             cell_kwargs: dict | None = None) -> dict:
    cfg = get_config(arch, **(cfg_overrides or {}))
    shape = get_shape(shape_name)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    chips = mesh.devices.size
    t0 = time.time()
    cell = build_cell(cfg, shape, mesh, **(cell_kwargs or {}))
    lowered = cell.lower()
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    if save_hlo_dir:
        os.makedirs(save_hlo_dir, exist_ok=True)
        with open(os.path.join(save_hlo_dir,
                               f"{arch}_{shape_name}_{mesh_name}.hlo"), "w") as f:
            f.write(text)

    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf = registry.model_flops(
        cfg, tokens, training=(shape.kind == "train"),
        seq_len=shape.seq_len if shape.kind != "decode" else 0,
        decode_cache_len=shape.seq_len if shape.kind == "decode" else 0)
    bytes_in_use = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                    + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    wb = 1.0 if (cell_kwargs or {}).get("int8_weights") else 2.0
    rl = roofline_mod.from_compiled(
        text, arch=arch, shape=shape_name, mesh_name=mesh_name, chips=chips,
        model_flops=mf, bytes_in_use=bytes_in_use,
        cfg=cfg, shape_spec=shape, mesh_shape=dict(mesh.shape),
        weight_bytes=wb)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "bytes_in_use_per_device": bytes_in_use,
        },
        "xla_cost_analysis_flops_while_once": ca.get("flops"),
        "roofline": rl.to_dict(),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--save-hlo", default=None, help="dir to dump compiled HLO text")
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells already recorded in --out")
    ap.add_argument("--set", action="append", default=[], metavar="K=V",
                    help="ModelConfig override, e.g. remat_policy=dots")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--cast-bf16", action="store_true",
                    help="cast params to bf16 before use (halves FSDP gathers)")
    ap.add_argument("--decode-ws", action="store_true",
                    help="weight-stationary decode sharding")
    ap.add_argument("--int8-weights", action="store_true",
                    help="serve with per-channel int8 weights (halves the "
                         "per-token HBM weight stream)")
    ap.add_argument("--tag", default="", help="annotation stored in records")
    ap.add_argument("--rules", default="",
                    help="rule overrides 'act_seq=model;mlp=;heads=' "
                         "(axes +-separated, empty = replicate)")
    args = ap.parse_args()

    cfg_overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        cfg_overrides[k] = _parse_value(v)
    rules_overrides = {}
    for item in filter(None, args.rules.split(";")):
        k, v = item.split("=", 1)
        if v:
            rules_overrides[k] = (tuple(v.split("+")), ())
        else:
            rules_overrides[k] = ((),)
    cell_kwargs = dict(microbatches=args.microbatches,
                       cast_params_bf16=args.cast_bf16,
                       decode_weight_stationary=args.decode_ws,
                       int8_weights=args.int8_weights,
                       rules_overrides=rules_overrides)

    archs = list_configs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    done = set()
    if args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    n_ok = n_fail = n_skip = 0
    with open(args.out, "a") as out:
        for arch in archs:
            for shape_name in shapes:
                for multi_pod in meshes:
                    mesh_name = "2x16x16" if multi_pod else "16x16"
                    if (arch, shape_name, mesh_name) in done:
                        continue
                    tag = f"{arch} × {shape_name} × {mesh_name}"
                    try:
                        rec = run_cell(arch, shape_name, multi_pod,
                                       args.save_hlo, cfg_overrides, cell_kwargs)
                    except Exception as e:  # noqa: BLE001
                        rec = {"arch": arch, "shape": shape_name,
                               "mesh": mesh_name, "status": "error",
                               "error": f"{type(e).__name__}: {e}",
                               "trace": traceback.format_exc()[-2000:]}
                    if args.tag:
                        rec["tag"] = args.tag
                    if cfg_overrides or args.microbatches > 1 or args.cast_bf16 \
                            or args.decode_ws:
                        rec["variant"] = {"overrides": cfg_overrides,
                                          **cell_kwargs}
                    out.write(json.dumps(rec) + "\n")
                    out.flush()
                    if rec["status"] == "ok":
                        n_ok += 1
                        r = rec["roofline"]
                        print(f"[OK]   {tag}: compile={rec['compile_s']}s "
                              f"dominant={r['dominant']} "
                              f"frac={r['roofline_fraction']:.3f} "
                              f"mem/dev={rec['memory']['bytes_in_use_per_device']/1e9:.2f}GB",
                              flush=True)
                    elif rec["status"] == "skipped":
                        n_skip += 1
                        print(f"[SKIP] {tag}: {rec['reason']}", flush=True)
                    else:
                        n_fail += 1
                        print(f"[FAIL] {tag}: {rec['error']}", flush=True)
    print(f"done: {n_ok} ok, {n_fail} failed, {n_skip} skipped", flush=True)
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
