"""Production mesh construction.

Target: TPU v5e pods of 16×16 = 256 chips; the multi-pod configuration is
2 pods = 512 chips with a leading "pod" axis.  Defined as functions so
importing this module never touches JAX device state.
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """Arbitrary mesh (tests / elastic re-meshing)."""
    return compat.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many (host) devices exist — smoke tests."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return make_mesh((data, model), ("data", "model"))


# Hardware constants for the roofline (TPU v5e).
TPU_V5E = {
    "name": "tpu-v5e",
    "peak_flops_bf16": 197e12,      # per chip
    "hbm_bytes_per_s": 819e9,       # per chip
    "ici_bytes_per_s_per_link": 50e9,
    "ici_links_per_chip": 4,        # 2D torus on v5e
    "hbm_bytes": 16e9,
}
