"""Production serving launcher: continuous batching on the progress engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --scale tiny --requests 8 --slots 4

Model-axis-sharded decode (vocab-parallel unembed) with the per-step
logits all-gather either in-program (native) or as persistent user-space
collectives on the serve-collective stream:

    PYTHONPATH=src python -m repro.launch.serve --devices 2 \
        --model-shards 2 --collective-backend user

Continuous batching on a paged KV cache (length-bucketed admission,
chunked prefill interleaved with decode, preemption under block
pressure) is the only cache layout — the fixed-slot path is retired:

    PYTHONPATH=src python -m repro.launch.serve \
        --slots 12 --kv-block-size 16 --kv-blocks 65 --requests 64
"""
import argparse
import os
import sys

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--scale", default="tiny", choices=["tiny", "small", "full"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--cache-mode", default="paged",
                    choices=["slots", "paged"],
                    help="KV cache layout; 'paged' (the only mode) is a "
                         "paged block pool with continuous batching "
                         "(backlog admission, chunked prefill, preemption)."
                         "  'slots' is retired and errors with a migration "
                         "hint.")
    ap.add_argument("--kv-block-size", type=int, default=16,
                    help="positions per KV block (paged mode)")
    ap.add_argument("--kv-blocks", type=int, default=0,
                    help="total pool blocks incl. the reserved scratch "
                         "block (0 = slots*ceil(max_seq/block)+1, i.e. "
                         "the fixed-slot capacity)")
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="fused prefill calls interleaved per admission "
                         "round before decode resumes (paged mode)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU rehearsal)")
    ap.add_argument("--model-shards", type=int, default=0,
                    help="shard decode over a 'model' mesh axis of this "
                         "size (0 = unsharded)")
    ap.add_argument("--collective-backend", default="native",
                    choices=["native", "user"],   # -> one CollectiveSpec
                    help="per-step logits all-gather: native in-program "
                         "lax.all_gather, or persistent user-space "
                         "allgather on the serve-collective stream")
    ap.add_argument("--collective-chunks", type=int, default=1,
                    help="chunk pipelining factor for the user backend")
    ap.add_argument("--collective-round-batch", type=int, default=0,
                    help="rounds fused per dispatch in the user backend "
                         "(0 = auto from payload size)")
    ap.add_argument("--progress-workers", type=int, default=0,
                    help="N background progress threads (0 = caller-driven)")
    ap.add_argument("--continuation-policy", default="deferred",
                    choices=["inline", "deferred"],
                    help="completion callbacks run inline on the progress "
                         "thread, or deferred to a bounded owner drain")
    ap.add_argument("--continuation-max-drain", type=int, default=64,
                    help="max continuations executed per drain (deferred "
                         "policy backpressure bound)")
    ap.add_argument("--heartbeat-timeout", type=float, default=0.0,
                    help="enable a HeartbeatMonitor subsystem with this "
                         "peer timeout in seconds (0 = off); a dead peer "
                         "invalidates the membership epoch and the server "
                         "drains, remeshes and re-admits")
    ap.add_argument("--watchdog-limit", type=float, default=0.0,
                    help="enable a StepWatchdog subsystem with this "
                         "wall-clock step limit in seconds (0 = off)")
    ap.add_argument("--chaos-kill", type=int, default=0,
                    help="simulate the death of N devices after half the "
                         "requests finish (invalidates the membership "
                         "epoch) and report the recovery")
    ap.add_argument("--stats", action="store_true",
                    help="print progress statistics after serving")
    args = ap.parse_args()

    if args.cache_mode == "slots":
        raise SystemExit(
            "--cache-mode slots was retired: the paged pool serves the "
            "same bytes at block granularity.  Drop the flag, or mimic "
            "fixed lanes with --kv-block-size B --kv-blocks "
            "(slots*max_seq//B + 1).")

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax

    from repro.configs import get_config
    from repro.core import ProgressEngine, ProgressExecutor
    from repro.core import stats as stats_mod
    from repro.models import registry
    from repro.collectives.nonblocking import CollectiveSpec
    from repro.serve.engine import GenRequest, ServeEngine
    from examples.train_lm import SCALES

    spec = CollectiveSpec(backend=args.collective_backend,
                          chunks=args.collective_chunks,
                          round_batch=args.collective_round_batch or None)

    cfg = get_config(args.arch)
    overrides = dict(SCALES[args.scale])
    if overrides:
        if cfg.moe:
            overrides["moe"] = cfg.moe.__class__(
                num_experts=4, top_k=2, expert_d_ff=overrides["d_ff"] // 2,
                group_size=64)
        if cfg.ssm:
            overrides["ssm"] = cfg.ssm.__class__(d_state=16, expand=2,
                                                 head_dim=16, chunk_size=16)
        if cfg.shared_attn_every:
            overrides.update(num_layers=5, shared_attn_every=2,
                             shared_attn_lora_rank=8)
        if cfg.is_encoder_decoder:
            overrides.update(num_encoder_layers=2, encoder_frames=16,
                             max_position_embeddings=256)
        cfg = cfg.with_overrides(**overrides)

    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    eng = ProgressEngine()
    executor = None
    if args.progress_workers > 0:
        executor = ProgressExecutor(
            eng, args.progress_workers,
            continuation_max_drain=args.continuation_max_drain)
    mesh = None
    if args.model_shards > 0:
        from repro.launch.mesh import make_mesh
        if args.model_shards > len(jax.devices()):
            raise SystemExit(f"--model-shards {args.model_shards} > "
                             f"{len(jax.devices())} devices (use --devices)")
        mesh = make_mesh((args.model_shards,), ("model",))
    elif args.collective_backend == "user":
        raise SystemExit("--collective-backend user requires --model-shards "
                         ">= 1 (the user backend is the sharded decode's "
                         "logits all-gather)")
    # fault tolerance: one membership epoch shared by the monitors and
    # the serve engine's persistent collectives — a dead peer or a hung
    # step fails in-flight starts retryably, and the engine drains,
    # remeshes onto the survivors, and re-admits from the backlog
    epoch = None
    heartbeat = None
    if args.heartbeat_timeout > 0 or args.watchdog_limit > 0 \
            or args.chaos_kill > 0:
        from repro.collectives.nonblocking import MembershipEpoch
        from repro.distributed.fault_tolerance import (HeartbeatMonitor,
                                                       StepWatchdog)
        epoch = MembershipEpoch()
        if args.heartbeat_timeout > 0:
            heartbeat = HeartbeatMonitor(
                eng, [f"rank{i}" for i in range(len(jax.devices()))],
                timeout=args.heartbeat_timeout, epoch=epoch)
        if args.watchdog_limit > 0:
            StepWatchdog(eng, limit=args.watchdog_limit, epoch=epoch)
    srv = ServeEngine(cfg, params, eng, batch_slots=args.slots,
                      max_seq=args.max_seq, executor=executor,
                      continuation_policy=args.continuation_policy,
                      continuation_max_drain=args.continuation_max_drain,
                      mesh=mesh, collective_spec=spec,
                      kv_block_size=args.kv_block_size,
                      kv_blocks=args.kv_blocks or None,
                      prefill_chunk=args.prefill_chunk,
                      epoch=epoch)
    if executor is not None:
        executor.start()
    rng = np.random.RandomState(1)
    reqs = []

    def make_request(i):
        prompt = rng.randint(1, cfg.vocab_size - 1,
                             size=rng.randint(2, 8)).astype(np.int32)
        r = GenRequest(f"req{i}", prompt, max_new_tokens=args.max_new)
        srv.submit(r)
        reqs.append(r)

    if args.chaos_kill > 0:
        import time as _time
        half = max(1, args.requests // 2)
        for i in range(half):
            make_request(i)
        srv.run_until_idle(timeout=600)
        survivors = max(1, len(jax.devices()) - args.chaos_kill)
        t_kill = _time.monotonic()
        epoch.invalidate(survivors=survivors,
                         reason=f"--chaos-kill {args.chaos_kill}")
        for i in range(half, args.requests):
            make_request(i)
        srv.run_until_idle(timeout=600)
        t_rec = (_time.monotonic() - t_kill) * 1e3
        print(f"chaos: killed {args.chaos_kill} device(s) -> {survivors} "
              f"survivors; remeshes={srv.remeshes}, second half served "
              f"in {t_rec:.1f} ms")
    else:
        for i in range(args.requests):
            make_request(i)
        srv.run_until_idle(timeout=600)
    if heartbeat is not None:
        for peer in heartbeat.alive:
            heartbeat.beat(peer)
    snap = stats_mod.collect(eng, executor)   # before close drops the queue
    lat = srv.latency_snapshot()              # before close, too
    sched = srv.scheduler_snapshot()
    srv.close(timeout=60)
    if executor is not None:
        executor.shutdown(drain=True, timeout=60)

    gen = sum(len(r.out_tokens) for r in reqs)
    mode = (f"{args.progress_workers} progress workers"
            if args.progress_workers > 0 else "caller-driven progress")
    shard = (f"model-shards={args.model_shards} "
             f"backend={args.collective_backend}, "
             if args.model_shards > 0 else "")
    print(f"served {len(reqs)} requests, {gen} tokens in {srv.steps} fused "
          f"decode steps (batching factor {gen / max(srv.steps, 1):.2f}x) "
          f"[{shard}{mode}]")
    # null-safe latency report: requests that failed before their first
    # token are counted, not subtracted from everyone else's TTFT
    print(lat.format())
    if sched is not None:
        print(sched.format())
    if args.stats:
        print(stats_mod.format_stats(snap))
    return 0


if __name__ == "__main__":
    sys.exit(main())
