"""Production serving launcher: continuous batching on the progress engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --scale tiny --requests 8 --slots 4
"""
import argparse
import sys

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--scale", default="tiny", choices=["tiny", "small", "full"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--progress-workers", type=int, default=0,
                    help="N background progress threads (0 = caller-driven)")
    ap.add_argument("--continuation-policy", default="deferred",
                    choices=["inline", "deferred"],
                    help="completion callbacks run inline on the progress "
                         "thread, or deferred to a bounded owner drain")
    ap.add_argument("--continuation-max-drain", type=int, default=64,
                    help="max continuations executed per drain (deferred "
                         "policy backpressure bound)")
    ap.add_argument("--stats", action="store_true",
                    help="print progress statistics after serving")
    args = ap.parse_args()

    import jax

    from repro.configs import get_config
    from repro.core import ProgressEngine, ProgressExecutor
    from repro.core import stats as stats_mod
    from repro.models import registry
    from repro.serve.engine import GenRequest, ServeEngine
    from examples.train_lm import SCALES

    cfg = get_config(args.arch)
    overrides = dict(SCALES[args.scale])
    if overrides:
        if cfg.moe:
            overrides["moe"] = cfg.moe.__class__(
                num_experts=4, top_k=2, expert_d_ff=overrides["d_ff"] // 2,
                group_size=64)
        if cfg.ssm:
            overrides["ssm"] = cfg.ssm.__class__(d_state=16, expand=2,
                                                 head_dim=16, chunk_size=16)
        if cfg.shared_attn_every:
            overrides.update(num_layers=5, shared_attn_every=2,
                             shared_attn_lora_rank=8)
        if cfg.is_encoder_decoder:
            overrides.update(num_encoder_layers=2, encoder_frames=16,
                             max_position_embeddings=256)
        cfg = cfg.with_overrides(**overrides)

    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    eng = ProgressEngine()
    executor = None
    if args.progress_workers > 0:
        executor = ProgressExecutor(
            eng, args.progress_workers,
            continuation_max_drain=args.continuation_max_drain)
    srv = ServeEngine(cfg, params, eng, batch_slots=args.slots,
                      max_seq=args.max_seq, executor=executor,
                      continuation_policy=args.continuation_policy,
                      continuation_max_drain=args.continuation_max_drain)
    if executor is not None:
        executor.start()
    rng = np.random.RandomState(1)
    reqs = []
    for i in range(args.requests):
        prompt = rng.randint(1, cfg.vocab_size - 1,
                             size=rng.randint(2, 8)).astype(np.int32)
        r = GenRequest(f"req{i}", prompt, max_new_tokens=args.max_new)
        srv.submit(r)
        reqs.append(r)
    srv.run_until_idle(timeout=600)
    snap = stats_mod.collect(eng, executor)   # before close drops the queue
    srv.close(timeout=60)
    if executor is not None:
        executor.shutdown(drain=True, timeout=60)

    gen = sum(len(r.out_tokens) for r in reqs)
    ttfts = [(r.first_token_at - r.submitted_at) for r in reqs]
    mode = (f"{args.progress_workers} progress workers"
            if args.progress_workers > 0 else "caller-driven progress")
    print(f"served {len(reqs)} requests, {gen} tokens in {srv.steps} fused "
          f"decode steps (batching factor {gen / max(srv.steps, 1):.2f}x); "
          f"mean TTFT {np.mean(ttfts) * 1e3:.0f} ms [{mode}]")
    if args.stats:
        print(stats_mod.format_stats(snap))
    return 0


if __name__ == "__main__":
    sys.exit(main())
