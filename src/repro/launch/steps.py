"""Step builders shared by the dry-run, the trainer, and the server.

``build_cell`` returns, for one (arch × shape × mesh) cell, the jitted
step function plus the abstract inputs and shardings — everything
``.lower()`` needs, with zero device allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec, input_specs
from repro.models import registry
from repro.sharding import merged_rules, axis_rules, resolve_spec, spec_tree
from repro.train import optimizer as opt


BATCH_AXES = {
    "tokens": ("batch", "act_seq"),
    "labels": ("batch", "act_seq"),
    "encoder_embeds": ("batch", "frames", "act_embed"),
    "vision_embeds": ("batch", "frames", "act_embed"),
    "pos": ("batch",),
}


@dataclasses.dataclass
class Cell:
    cfg: ModelConfig
    shape: ShapeSpec
    mesh: jax.sharding.Mesh
    step_fn: Callable
    abstract_args: tuple
    in_shardings: tuple
    out_shardings: Any

    def lower(self):
        jitted = jax.jit(self.step_fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings)
        with compat.set_mesh(self.mesh):
            return jitted.lower(*self.abstract_args)


def _shardings_for(tree_axes, tree_shapes, mesh):
    specs = spec_tree(tree_axes, tree_shapes, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_shardings(batch_specs, mesh):
    out = {}
    for k, v in batch_specs.items():
        out[k] = NamedSharding(mesh, resolve_spec(BATCH_AXES[k], v.shape, mesh))
    return out


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh,
               opt_cfg: opt.AdamWConfig | None = None,
               *, microbatches: int = 1,
               cast_params_bf16: bool = False,
               decode_weight_stationary: bool = False,
               int8_weights: bool = False,
               rules_overrides: dict | None = None) -> Cell:
    """Build one (arch × shape × mesh) cell.

    Optimization knobs (all default OFF — the baseline):
    * microbatches            — gradient accumulation; activation memory
                                and logits buffers shrink ×m.
    * cast_params_bf16        — cast f32 master params to bf16 *before*
                                the model consumes them, so FSDP
                                all-gathers move half the bytes.
    * decode_weight_stationary — serve_step keeps 2D-sharded weights
                                resident and replicates the (tiny) token
                                activations over the data axis instead of
                                gathering weights every step (Pope et al.
                                2D weight-stationary inference layout).
    * int8_weights            — serving only: large weight matrices stored
                                per-channel int8 + f32 scales; the
                                dequantizing convert fuses into consumers,
                                halving the per-token HBM weight stream.
    """
    rules = merged_rules(cfg.sharding_overrides)
    if decode_weight_stationary and shape.kind == "decode":
        rules.update({
            "batch": ((),),          # activations replicated over data
            "act_heads": ((),), "act_kv_heads": ((),),
        })
    if rules_overrides:
        rules.update(rules_overrides)   # explicit --rules wins
    with axis_rules(rules):
        p_shapes = registry.param_shapes(cfg)
        p_axes = registry.param_axes(cfg)
        p_shard = _shardings_for(p_axes, p_shapes, mesh)
        b_specs = input_specs(cfg, shape)
        b_shard = batch_shardings(b_specs, mesh)

        if shape.kind == "train":
            ocfg = opt_cfg or opt.AdamWConfig()
            o_shapes = opt.state_shapes(p_shapes)
            o_shard = opt.AdamWState(
                step=NamedSharding(mesh, P()),
                mu=p_shard, nu=p_shard)

            def train_step(params, opt_state, batch):
                with axis_rules(rules):
                    cparams = params
                    if cast_params_bf16:
                        cdt = jnp.dtype(cfg.dtype)
                        cparams = jax.tree.map(
                            lambda p: p.astype(cdt)
                            if p.dtype == jnp.float32 and p.ndim > 1 else p,
                            params)
                    if microbatches > 1:
                        def split(x):
                            return x.reshape((microbatches,
                                              x.shape[0] // microbatches)
                                             + x.shape[1:])
                        mb = jax.tree.map(split, batch)
                        vg = jax.value_and_grad(registry.loss_fn, has_aux=True)

                        def body(carry, b):
                            acc_l, acc_g = carry
                            (l, _), g = vg(cparams, cfg, b)
                            return (acc_l + l,
                                    jax.tree.map(jnp.add, acc_g, g)), None

                        zg = jax.tree.map(
                            lambda p: jnp.zeros(p.shape, jnp.float32), cparams)
                        (loss, grads), _ = jax.lax.scan(
                            body, (jnp.zeros(()), zg), mb)
                        inv = 1.0 / microbatches
                        loss = loss * inv
                        grads = jax.tree.map(lambda g: g * inv, grads)
                        metrics = {"nll": loss, "aux": jnp.zeros(())}
                    else:
                        (loss, metrics), grads = jax.value_and_grad(
                            registry.loss_fn, has_aux=True)(cparams, cfg, batch)
                    grads = jax.tree.map(lambda g, p: g.astype(jnp.float32),
                                         grads, cparams)
                    params, opt_state, om = opt.apply(ocfg, opt_state, params, grads)
                    metrics = dict(metrics, loss=loss, **om)
                    return params, opt_state, metrics

            return Cell(
                cfg, shape, mesh, train_step,
                (p_shapes, o_shapes, b_specs),
                (p_shard, o_shard, b_shard),
                (p_shard, o_shard, None),
            )

        if shape.kind == "prefill":
            def prefill_step(params, batch):
                with axis_rules(rules):
                    logits, _ = registry.forward(params, cfg, batch)
                    return logits

            return Cell(
                cfg, shape, mesh, prefill_step,
                (p_shapes, b_specs),
                (p_shard, b_shard),
                None,
            )

        if shape.kind == "decode":
            c_shapes = registry.cache_shapes(cfg, shape.global_batch, shape.seq_len)
            c_axes = registry.cache_axes(cfg, shape.global_batch, shape.seq_len)
            c_shard = _shardings_for(c_axes, c_shapes, mesh)

            if int8_weights:
                from repro.serve import quantization as QZ
                qp_shapes = QZ.quantized_shapes(p_shapes)
                qp_axes = QZ.quantized_axes(p_axes, p_shapes)
                qp_shard = _shardings_for(qp_axes, qp_shapes, mesh)

                def serve_step_q(qparams, cache, batch):
                    with axis_rules(rules):
                        params = QZ.dequantize_tree(qparams,
                                                    jnp.dtype(cfg.dtype))
                        logits, cache = registry.decode_step(
                            params, cfg, cache, batch["tokens"], batch["pos"])
                        return logits, cache

                return Cell(
                    cfg, shape, mesh, serve_step_q,
                    (qp_shapes, c_shapes, b_specs),
                    (qp_shard, c_shard, b_shard),
                    (None, c_shard),
                )

            def serve_step(params, cache, batch):
                with axis_rules(rules):
                    logits, cache = registry.decode_step(
                        params, cfg, cache, batch["tokens"], batch["pos"])
                    return logits, cache

            return Cell(
                cfg, shape, mesh, serve_step,
                (p_shapes, c_shapes, b_specs),
                (p_shard, c_shard, b_shard),
                (None, c_shard),
            )

    raise ValueError(shape.kind)
