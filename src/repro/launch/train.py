"""Production training launcher.

On a real TPU fleet every host runs this same script (JAX SPMD runtime);
on this CPU container use --devices to force host devices for a scaled
rehearsal, e.g.:

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --devices 8 --mesh 4x2 --scale tiny --steps 20

All async subsystems (data prefetch, checkpointing, monitors) run on the
one collated progress engine (see DESIGN.md).
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU rehearsal)")
    ap.add_argument("--mesh", default="", help="e.g. 4x2 -> (data=4, model=2)")
    ap.add_argument("--scale", default="tiny", choices=["tiny", "small", "full"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--cast-bf16", action="store_true")
    ap.add_argument("--collective-backend", default="native",
                    choices=["native", "user"],
                    help="native: gradient reduction inside the jitted "
                         "step (GSPMD); user: nonblocking user-space "
                         "collectives on the progress engine")
    ap.add_argument("--collective-chunks", type=int, default=4,
                    help="chunk pipelining factor for --collective-backend "
                         "user")
    ap.add_argument("--collective-algorithm", default="ring",
                    help="user-backend allreduce schedule "
                         "(ring/bidir/recursive_doubling/halving_doubling)")
    ap.add_argument("--collective-round-batch", type=int, default=0,
                    help="rounds fused per jitted dispatch in the user "
                         "backend (0 = auto from bucket size)")
    ap.add_argument("--pipeline", default="none",
                    choices=["none", "gpipe", "1f1b"],
                    help="pipeline-parallel backend: gpipe = the "
                         "monolithic lax.scan reference; 1f1b = the "
                         "event-driven continuation-DAG schedule on the "
                         "progress engine (per-stage streams, persistent "
                         "user-space p2p handoffs), composed with the "
                         "engine grad reducer over the data axis")
    ap.add_argument("--pipeline-stages", type=int, default=0,
                    help="pipeline stages (0 = the mesh's second dim); "
                         "with --pipeline the mesh is (data x stage) and "
                         "--microbatches sets M per step")
    ap.add_argument("--elastic", action="store_true",
                    help="membership-aware fault tolerance (user backend "
                         "only): a shared MembershipEpoch ties the "
                         "watchdog/heartbeat to the reducer's persistent "
                         "collectives; on invalidation the trainer "
                         "remeshes onto the survivors and retries the "
                         "step's batch")
    ap.add_argument("--heartbeat-timeout", type=float, default=0.0,
                    help="enable a HeartbeatMonitor with this peer "
                         "timeout in seconds (0 = off; implies --elastic "
                         "epoch wiring)")
    ap.add_argument("--chaos-kill", type=int, default=0,
                    help="simulate the death of N devices at the first "
                         "logged step >= --chaos-kill-step (requires "
                         "--elastic)")
    ap.add_argument("--chaos-kill-step", type=int, default=10)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    if args.pipeline != "none":
        return _run_pipeline(args)

    import jax
    import jax.numpy as jnp

    from repro import compat
    from repro.configs import get_config
    from repro.configs.shapes import ShapeSpec
    from repro.core import ProgressEngine
    from repro.data.pipeline import PrefetchPipeline, SyntheticLM
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import build_cell
    from repro.models import registry
    from repro.train import optimizer as opt_mod
    from repro.train.train_loop import Trainer, TrainLoopConfig
    from examples.train_lm import SCALES  # reuse the reduction table

    n_dev = len(jax.devices())
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
    else:
        shape = (n_dev, 1)
    mesh = make_mesh(shape, ("data", "model"))
    print(f"devices={n_dev} mesh={dict(mesh.shape)}")

    cfg = get_config(args.arch)
    overrides = dict(SCALES[args.scale])
    if overrides:
        if cfg.moe:
            overrides["moe"] = cfg.moe.__class__(
                num_experts=4, top_k=2, expert_d_ff=overrides["d_ff"] // 2,
                group_size=64)
        if cfg.ssm:
            overrides["ssm"] = cfg.ssm.__class__(d_state=16, expand=2,
                                                 head_dim=16, chunk_size=16)
        if cfg.shared_attn_every:
            overrides.update(num_layers=5, shared_attn_every=2,
                             shared_attn_lora_rank=8)
        if cfg.is_encoder_decoder:
            overrides.update(num_encoder_layers=2, encoder_frames=16,
                             max_position_embeddings=256)
        cfg = cfg.with_overrides(**overrides)

    shape_spec = ShapeSpec("train", seq_len=args.seq,
                           global_batch=args.global_batch, kind="train")
    ocfg = opt_mod.AdamWConfig(lr=3e-3, warmup_steps=5,
                               total_steps=max(args.steps, 10))
    cell = build_cell(cfg, shape_spec, mesh, opt_cfg=ocfg,
                      microbatches=args.microbatches,
                      cast_params_bf16=args.cast_bf16)
    jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings)

    user_backend = args.collective_backend == "user"
    if user_backend:
        if dict(mesh.shape).get("model", 1) != 1:
            raise SystemExit("--collective-backend user needs a pure "
                             "data-parallel mesh (model dim 1)")
        if args.microbatches > 1:
            raise SystemExit("--collective-backend user does not compose "
                             "with --microbatches yet")

    with compat.set_mesh(mesh):
        params = registry.init_params(cfg, jax.random.PRNGKey(0))
        opt_state = opt_mod.init(params)
        # place onto the cell's shardings (FSDP/TP distribution)
        params = jax.device_put(params, cell.in_shardings[0])
        opt_state = jax.device_put(opt_state, cell.in_shardings[1])
        b_shardings = cell.in_shardings[2]

    eng = ProgressEngine()
    src = SyntheticLM(cfg.vocab_size, args.seq, args.global_batch, seed=5)

    def to_batch(b):
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.is_encoder_decoder:
            batch["encoder_embeds"] = jnp.ones(
                (args.global_batch, cfg.encoder_frames, cfg.d_model),
                jnp.bfloat16)
        return batch

    pipe = PrefetchPipeline(map(to_batch, iter(src)), eng, depth=3)

    def step_fn(params, opt_state, batch):
        batch = {k: jax.device_put(v, b_shardings[k]) for k, v in batch.items()}
        return jitted(params, opt_state, batch)

    elastic_on = args.elastic or args.heartbeat_timeout > 0 \
        or args.chaos_kill > 0
    if elastic_on and not user_backend:
        raise SystemExit("--elastic/--chaos-kill/--heartbeat-timeout "
                         "require --collective-backend user (the epoch "
                         "invalidates user-space persistent collectives)")

    split, reducer, epoch, remesh_fn = None, None, None, None
    if user_backend:
        # Split step: shard_map-local grads (stacked per device) + an
        # engine-driven bucketed allreduce + a jitted apply.  Traced
        # OUTSIDE the mesh context so in-model shard hints no-op inside
        # the manual shard_map region.
        from jax.sharding import PartitionSpec as P
        from repro.collectives.overlap import EngineGradReducer
        from repro.train.train_loop import UserCollectiveStep

        def local_grad(params, batch):
            cparams = params
            if args.cast_bf16:
                # mirror build_cell's cast_params_bf16: bf16 forward,
                # f32 master params and gradients
                cdt = jnp.dtype(cfg.dtype)
                cparams = jax.tree.map(
                    lambda p: p.astype(cdt)
                    if p.dtype == jnp.float32 and p.ndim > 1 else p, params)
            (loss, mets), g = jax.value_and_grad(
                registry.loss_fn, has_aux=True)(cparams, cfg, batch)
            stacked = jax.tree.map(
                lambda v: v[None].astype(jnp.float32), g)
            mets = dict(mets, loss=loss)
            return jax.tree.map(lambda v: v[None], mets), stacked

        def make_grad_fn(mesh_):
            return jax.jit(compat.shard_map(
                local_grad, mesh=mesh_, in_specs=(P(), P("data")),
                out_specs=P("data")))

        @jax.jit
        def apply_fn(params, opt_state, grads, stacked_mets):
            params, opt_state, om = opt_mod.apply(ocfg, opt_state,
                                                  params, grads)
            mets = {k: jnp.mean(v) for k, v in stacked_mets.items()}
            return params, opt_state, dict(mets, **om)

        if elastic_on:
            from repro.collectives.nonblocking import MembershipEpoch
            epoch = MembershipEpoch()

        reducer = EngineGradReducer(
            mesh, "data", engine=eng,
            algorithm=args.collective_algorithm,
            chunks=args.collective_chunks, mean=True,
            round_batch=args.collective_round_batch or None,
            epoch=epoch)
        split = UserCollectiveStep(make_grad_fn(mesh), apply_fn, reducer)

        if elastic_on:
            from jax.sharding import NamedSharding

            from repro.distributed import elastic

            def remesh_fn(exc, params, opt_state):
                # survivors' mesh: pure data-parallel (model dim stays 1)
                survivors = getattr(exc, "survivors", None) \
                    or len(jax.devices())
                new_mesh = elastic.remesh(survivors, prefer_model=1)
                print(f"remesh: {getattr(exc, 'survivors', '?')} "
                      f"survivor(s) -> mesh {dict(new_mesh.shape)}")
                reducer.remesh(new_mesh, "data")
                params = jax.device_put(
                    params, NamedSharding(new_mesh, P()))
                opt_state = jax.device_put(
                    opt_state, NamedSharding(new_mesh, P()))
                return (UserCollectiveStep(make_grad_fn(new_mesh),
                                           apply_fn, reducer),
                        params, opt_state)

        print(f"collective backend: user "
              f"({reducer.algorithm}, chunks={args.collective_chunks}, "
              f"round_batch={args.collective_round_batch or 'auto'}, "
              f"persistent schedules per bucket)")

    loop_cfg = TrainLoopConfig(
        total_steps=args.steps, checkpoint_every=10,
        checkpoint_dir=os.path.join(args.ckpt_dir, args.arch),
        log_every=5, collective_backend=args.collective_backend,
        collective_algorithm=args.collective_algorithm,
        collective_chunks=args.collective_chunks,
        collective_round_batch=args.collective_round_batch)
    hooks = [lambda s, m: print(
        f"step {s:4d} loss={m['loss']:.4f} "
        f"{m['step_time_s'] * 1e3:.0f}ms", flush=True)]
    if args.heartbeat_timeout > 0:
        from repro.distributed.fault_tolerance import HeartbeatMonitor
        hb = HeartbeatMonitor(
            eng, [f"rank{i}" for i in range(len(jax.devices()))],
            timeout=args.heartbeat_timeout, epoch=epoch)
        hooks.append(lambda s, m: [hb.beat(p) for p in hb.alive])
    if args.chaos_kill > 0:
        killed = []

        def chaos_hook(s, m):
            if s >= args.chaos_kill_step and not killed:
                killed.append(s)
                survivors = max(1, len(jax.devices()) - args.chaos_kill)
                print(f"chaos: killing {args.chaos_kill} device(s) at "
                      f"step {s} -> {survivors} survivors")
                epoch.invalidate(survivors=survivors,
                                 reason=f"--chaos-kill {args.chaos_kill}")
        hooks.append(chaos_hook)
    trainer = Trainer(
        step_fn, params, opt_state, pipe, loop_cfg,
        engine=eng, split_step=split, epoch=epoch, remesh_fn=remesh_fn,
        hooks=hooks)
    if user_backend:
        log = trainer.run()
    else:
        with compat.set_mesh(mesh):
            log = trainer.run()
    pipe.close()
    if reducer is not None:
        reducer.close()
    if log:
        print(f"final loss {log[-1]['loss']:.4f}")
    else:
        # resume found a checkpoint at/past --steps: nothing left to run
        print(f"nothing to do: resumed past step {args.steps - 1} "
              f"(rm -r {loop_cfg.checkpoint_dir} to restart)")
    return 0


def _run_pipeline(args):
    """Pipeline-parallel rehearsal: a residual-MLP stage stack trained
    against a fixed linear teacher, on a (data x stage) mesh.

    * ``--pipeline gpipe``: the monolithic ``lax.scan`` reference —
      forward AND backward differentiate through the tick scan inside
      one jitted step (data dim must be 1).
    * ``--pipeline 1f1b``: one event-driven :class:`PipelineSchedule`
      per data row (per-stage executor-owned streams, persistent p2p
      handoffs), composed with the existing ``EngineGradReducer`` over
      the data axis of the 2-D mesh — the split-step
      ``UserCollectiveStep`` path, exactly as for plain data-parallel.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.collectives.overlap import EngineGradReducer
    from repro.core import ProgressEngine, ProgressExecutor
    from repro.data.pipeline import PrefetchPipeline
    from repro.distributed import pipeline as pl
    from repro.train import optimizer as opt_mod
    from repro.train.train_loop import (Trainer, TrainLoopConfig,
                                        UserCollectiveStep)

    n_dev = len(jax.devices())
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
    else:
        S0 = args.pipeline_stages or n_dev
        shape = (max(n_dev // S0, 1), S0)
    D, S = shape
    if args.pipeline_stages and args.pipeline_stages != S:
        raise SystemExit(f"--pipeline-stages {args.pipeline_stages} "
                         f"contradicts --mesh {args.mesh} (stage dim {S})")
    if D * S > n_dev:
        raise SystemExit(f"mesh {D}x{S} needs {D * S} devices, have {n_dev}")
    if args.pipeline == "gpipe" and D != 1:
        raise SystemExit("--pipeline gpipe differentiates through one "
                         "scan; use a 1xS mesh (data dim 1)")
    mesh = Mesh(np.array(jax.devices()[:D * S]).reshape(D, S),
                ("data", "stage"))
    M = max(args.microbatches, 1)
    d_model, d_hidden, mb = 16, 32, max(args.global_batch, 1)
    print(f"pipeline={args.pipeline} mesh={dict(mesh.shape)} "
          f"microbatches={M} "
          f"bubble={pl.bubble_fraction(S, M, args.pipeline):.3f} "
          f"peak_act={pl.peak_activation_microbatches(S, M, args.pipeline)}")

    def stage_fn(p, x):
        return x + jnp.tanh(x @ p["w1"]) @ p["w2"]

    def loss_fn(y, t):
        return jnp.mean((y - t) ** 2)

    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    params = {
        "w1": jax.random.normal(k1, (S, d_model, d_hidden)) * 0.1,
        "w2": jax.random.normal(k2, (S, d_hidden, d_model)) * 0.1,
    }
    ocfg = opt_mod.AdamWConfig(lr=3e-3, warmup_steps=5,
                               total_steps=max(args.steps, 10))
    opt_state = opt_mod.init(params)

    eng = ProgressEngine()
    ex = ProgressExecutor(eng, num_workers=2).start()
    eng.attach_executor(ex)

    rng = np.random.default_rng(7)
    teacher = (rng.standard_normal((d_model, d_model))
               .astype(np.float32) * 0.3)

    def gen():
        while True:
            xs = rng.standard_normal((D, M, mb, d_model)).astype(np.float32)
            yield {"xs": jnp.asarray(xs), "ts": jnp.asarray(xs @ teacher)}

    pipe = PrefetchPipeline(gen(), eng, depth=3)

    @jax.jit
    def apply_fn(params, opt_state, grads, stacked_mets):
        params, opt_state, om = opt_mod.apply(ocfg, opt_state,
                                              params, grads)
        mets = {k: jnp.mean(v) for k, v in stacked_mets.items()}
        return params, opt_state, dict(mets, **om)

    loop_cfg = TrainLoopConfig(
        total_steps=args.steps, checkpoint_every=10,
        checkpoint_dir=os.path.join(args.ckpt_dir,
                                    f"pipeline-{args.pipeline}"),
        log_every=5,
        collective_backend="user" if args.pipeline == "1f1b" else "native",
        collective_algorithm=args.collective_algorithm,
        collective_chunks=args.collective_chunks,
        pipeline=args.pipeline)
    hooks = [lambda s, m: print(
        f"step {s:4d} loss={m['loss']:.4f} "
        f"{m['step_time_s'] * 1e3:.0f}ms", flush=True)]

    rows, reducer = [], None
    if args.pipeline == "gpipe":
        gmesh = Mesh(mesh.devices[0], ("stage",))
        params = jax.device_put(params, NamedSharding(gmesh, P("stage")))
        gp = pl.gpipe(stage_fn, gmesh, "stage", S)

        def gp_loss(p, xs, ts):
            ys = gp(p, xs)
            per = jnp.stack([loss_fn(ys[m], ts[m]) for m in range(M)])
            return jnp.mean(per)

        @jax.jit
        def step_fn(p, o, batch):
            loss, g = jax.value_and_grad(gp_loss)(
                p, batch["xs"][0], batch["ts"][0])
            p, o, om = opt_mod.apply(ocfg, o, p, g)
            return p, o, dict(loss=loss, **om)

        trainer = Trainer(step_fn, params, opt_state, pipe, loop_cfg,
                          engine=eng, hooks=hooks)
    else:
        params = jax.device_put(params, NamedSharding(mesh, P("stage")))
        for r in range(D):
            rmesh = Mesh(mesh.devices[r], ("stage",))
            rows.append(pl.PipelineSchedule(
                stage_fn, rmesh, "stage", S, loss_fn=loss_fn,
                engine=eng, executor=ex, name=f"pipe{r}"))
        sharding2d = NamedSharding(mesh, P("data", "stage"))

        def stack_rows(*row_leaves):
            # row r's [S, w...] leaf is one single-device [1, w...] shard
            # per stage — reassemble all D*S of them into one global
            # [D, S, w...] array for the data-axis reduction (zero-copy)
            shards = [sh[None]
                      for leaf in row_leaves
                      for sh in pl.PipelineSchedule._by_stage(leaf)]
            shape = (D, S) + tuple(row_leaves[0].shape[1:])
            return jax.make_array_from_single_device_arrays(
                shape, sharding2d, shards)

        def grad_fn(params, batch):
            # launch every row's DAG before waiting on any: the rows'
            # stage streams progress concurrently under the executor
            reqs = [rows[r].istep(params, batch["xs"][r], batch["ts"][r])
                    for r in range(D)]
            outs = [rows[r]._wait(reqs[r], timeout=600) for r in range(D)]
            # each row's loss scalar lives on that row's last-stage
            # device; hop through host for the [D] metrics stack
            losses = jnp.asarray(np.stack(
                [np.asarray(o[0]) for o in outs]))
            grads = jax.tree.map(stack_rows, *[o[1] for o in outs])
            return {"loss": losses}, grads

        reducer = EngineGradReducer(
            mesh, "data", engine=eng,
            algorithm=args.collective_algorithm,
            chunks=args.collective_chunks, mean=True,
            round_batch=args.collective_round_batch or None)
        split = UserCollectiveStep(grad_fn, apply_fn, reducer)
        trainer = Trainer(None, params, opt_state, pipe, loop_cfg,
                          engine=eng, split_step=split, hooks=hooks)

    log = trainer.run()
    pipe.close()
    for r in rows:
        r.close()
    if reducer is not None:
        reducer.close()
    ex.shutdown(drain=True, timeout=600)
    if log:
        print(f"final loss {log[-1]['loss']:.4f}")
        if rows:
            st = rows[0].stats()
            print(f"pipe0 stats: hops={st['hop_starts']} "
                  f"p2p_completions={st['p2p_stream_completions']} "
                  f"blocking_waits={st['blocking_waits']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
