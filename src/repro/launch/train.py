"""Production training launcher.

On a real TPU fleet every host runs this same script (JAX SPMD runtime);
on this CPU container use --devices to force host devices for a scaled
rehearsal, e.g.:

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --devices 8 --mesh 4x2 --scale tiny --steps 20

All async subsystems (data prefetch, checkpointing, monitors) run on the
one collated progress engine (see DESIGN.md).
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU rehearsal)")
    ap.add_argument("--mesh", default="", help="e.g. 4x2 -> (data=4, model=2)")
    ap.add_argument("--scale", default="tiny", choices=["tiny", "small", "full"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--cast-bf16", action="store_true")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp

    from repro import compat
    from repro.configs import get_config
    from repro.configs.shapes import ShapeSpec
    from repro.core import ProgressEngine
    from repro.data.pipeline import PrefetchPipeline, SyntheticLM
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import build_cell
    from repro.models import registry
    from repro.train import optimizer as opt_mod
    from repro.train.train_loop import Trainer, TrainLoopConfig
    from examples.train_lm import SCALES  # reuse the reduction table

    n_dev = len(jax.devices())
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
    else:
        shape = (n_dev, 1)
    mesh = make_mesh(shape, ("data", "model"))
    print(f"devices={n_dev} mesh={dict(mesh.shape)}")

    cfg = get_config(args.arch)
    overrides = dict(SCALES[args.scale])
    if overrides:
        if cfg.moe:
            overrides["moe"] = cfg.moe.__class__(
                num_experts=4, top_k=2, expert_d_ff=overrides["d_ff"] // 2,
                group_size=64)
        if cfg.ssm:
            overrides["ssm"] = cfg.ssm.__class__(d_state=16, expand=2,
                                                 head_dim=16, chunk_size=16)
        if cfg.shared_attn_every:
            overrides.update(num_layers=5, shared_attn_every=2,
                             shared_attn_lora_rank=8)
        if cfg.is_encoder_decoder:
            overrides.update(num_encoder_layers=2, encoder_frames=16,
                             max_position_embeddings=256)
        cfg = cfg.with_overrides(**overrides)

    shape_spec = ShapeSpec("train", seq_len=args.seq,
                           global_batch=args.global_batch, kind="train")
    cell = build_cell(cfg, shape_spec, mesh,
                      opt_cfg=opt_mod.AdamWConfig(
                          lr=3e-3, warmup_steps=5,
                          total_steps=max(args.steps, 10)),
                      microbatches=args.microbatches,
                      cast_params_bf16=args.cast_bf16)
    jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings)

    with compat.set_mesh(mesh):
        params = registry.init_params(cfg, jax.random.PRNGKey(0))
        opt_state = opt_mod.init(params)
        # place onto the cell's shardings (FSDP/TP distribution)
        params = jax.device_put(params, cell.in_shardings[0])
        opt_state = jax.device_put(opt_state, cell.in_shardings[1])
        b_shardings = cell.in_shardings[2]
        eng = ProgressEngine()
        src = SyntheticLM(cfg.vocab_size, args.seq, args.global_batch, seed=5)

        def to_batch(b):
            batch = {k: jnp.asarray(v) for k, v in b.items()}
            if cfg.is_encoder_decoder:
                batch["encoder_embeds"] = jnp.ones(
                    (args.global_batch, cfg.encoder_frames, cfg.d_model),
                    jnp.bfloat16)
            return batch

        pipe = PrefetchPipeline(map(to_batch, iter(src)), eng, depth=3)

        def step_fn(params, opt_state, batch):
            batch = {k: jax.device_put(v, b_shardings[k]) for k, v in batch.items()}
            return jitted(params, opt_state, batch)

        trainer = Trainer(
            step_fn, params, opt_state, pipe,
            TrainLoopConfig(total_steps=args.steps, checkpoint_every=10,
                            checkpoint_dir=os.path.join(args.ckpt_dir, args.arch),
                            log_every=5),
            engine=eng,
            hooks=[lambda s, m: print(
                f"step {s:4d} loss={m['loss']:.4f} "
                f"{m['step_time_s'] * 1e3:.0f}ms", flush=True)])
        log = trainer.run()
        pipe.close()
    print(f"final loss {log[-1]['loss']:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
