"""Production training launcher.

On a real TPU fleet every host runs this same script (JAX SPMD runtime);
on this CPU container use --devices to force host devices for a scaled
rehearsal, e.g.:

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --devices 8 --mesh 4x2 --scale tiny --steps 20

All async subsystems (data prefetch, checkpointing, monitors) run on the
one collated progress engine (see DESIGN.md).
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU rehearsal)")
    ap.add_argument("--mesh", default="", help="e.g. 4x2 -> (data=4, model=2)")
    ap.add_argument("--scale", default="tiny", choices=["tiny", "small", "full"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--cast-bf16", action="store_true")
    ap.add_argument("--collective-backend", default="native",
                    choices=["native", "user"],
                    help="native: gradient reduction inside the jitted "
                         "step (GSPMD); user: nonblocking user-space "
                         "collectives on the progress engine")
    ap.add_argument("--collective-chunks", type=int, default=4,
                    help="chunk pipelining factor for --collective-backend "
                         "user")
    ap.add_argument("--collective-algorithm", default="ring",
                    help="user-backend allreduce schedule "
                         "(ring/bidir/recursive_doubling/halving_doubling)")
    ap.add_argument("--collective-round-batch", type=int, default=0,
                    help="rounds fused per jitted dispatch in the user "
                         "backend (0 = auto from bucket size)")
    ap.add_argument("--fsdp", action="store_true",
                    help="ZeRO-style FSDP over the mesh's data axis: "
                         "params + optimizer state sharded into flat "
                         "per-dtype buckets, grads reduce-scattered "
                         "(half the wire bytes of allreduce), full "
                         "params prefetched per step via persistent "
                         "all-gathers chained off compute futures; "
                         "works with both collective backends (native "
                         "uses in-program all_gather/psum_scatter) and "
                         "lifts the user backend's model-dim-1 limit")
    ap.add_argument("--fsdp-bucket-bytes", type=int, default=1 << 22,
                    help="flat-bucket size for --fsdp (smaller = more "
                         "buckets = more prefetch-chain links)")
    ap.add_argument("--pipeline", default="none",
                    choices=["none", "gpipe", "1f1b"],
                    help="pipeline-parallel backend: gpipe = the "
                         "monolithic lax.scan reference; 1f1b = the "
                         "event-driven continuation-DAG schedule on the "
                         "progress engine (per-stage streams, persistent "
                         "user-space p2p handoffs), composed with the "
                         "engine grad reducer over the data axis")
    ap.add_argument("--pipeline-stages", type=int, default=0,
                    help="pipeline stages (0 = the mesh's second dim); "
                         "with --pipeline the mesh is (data x stage) and "
                         "--microbatches sets M per step")
    ap.add_argument("--elastic", action="store_true",
                    help="membership-aware fault tolerance (user backend "
                         "only): a shared MembershipEpoch ties the "
                         "watchdog/heartbeat to the reducer's persistent "
                         "collectives; on invalidation the trainer "
                         "remeshes onto the survivors and retries the "
                         "step's batch")
    ap.add_argument("--heartbeat-timeout", type=float, default=0.0,
                    help="enable a HeartbeatMonitor with this peer "
                         "timeout in seconds (0 = off; implies --elastic "
                         "epoch wiring)")
    ap.add_argument("--chaos-kill", type=int, default=0,
                    help="simulate the death of N devices at the first "
                         "logged step >= --chaos-kill-step (requires "
                         "--elastic)")
    ap.add_argument("--chaos-kill-step", type=int, default=10)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    if args.pipeline != "none":
        return _run_pipeline(args)

    import jax
    import jax.numpy as jnp

    from repro import compat
    from repro.configs import get_config
    from repro.configs.shapes import ShapeSpec
    from repro.core import ProgressEngine
    from repro.data.pipeline import PrefetchPipeline, SyntheticLM
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import build_cell
    from repro.models import registry
    from repro.train import optimizer as opt_mod
    from repro.train.train_loop import Trainer, TrainLoopConfig
    from examples.train_lm import SCALES  # reuse the reduction table

    n_dev = len(jax.devices())
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
    else:
        shape = (n_dev, 1)
    mesh = make_mesh(shape, ("data", "model"))
    print(f"devices={n_dev} mesh={dict(mesh.shape)}")

    cfg = get_config(args.arch)
    overrides = dict(SCALES[args.scale])
    if overrides:
        if cfg.moe:
            overrides["moe"] = cfg.moe.__class__(
                num_experts=4, top_k=2, expert_d_ff=overrides["d_ff"] // 2,
                group_size=64)
        if cfg.ssm:
            overrides["ssm"] = cfg.ssm.__class__(d_state=16, expand=2,
                                                 head_dim=16, chunk_size=16)
        if cfg.shared_attn_every:
            overrides.update(num_layers=5, shared_attn_every=2,
                             shared_attn_lora_rank=8)
        if cfg.is_encoder_decoder:
            overrides.update(num_encoder_layers=2, encoder_frames=16,
                             max_position_embeddings=256)
        cfg = cfg.with_overrides(**overrides)

    shape_spec = ShapeSpec("train", seq_len=args.seq,
                           global_batch=args.global_batch, kind="train")
    ocfg = opt_mod.AdamWConfig(lr=3e-3, warmup_steps=5,
                               total_steps=max(args.steps, 10))

    user_backend = args.collective_backend == "user"
    if args.fsdp:
        if args.microbatches > 1 or args.cast_bf16:
            raise SystemExit("--fsdp does not compose with "
                             "--microbatches/--cast-bf16 yet")
        return _run_fsdp(args, cfg, ocfg, mesh)
    if user_backend:
        if dict(mesh.shape).get("model", 1) != 1:
            raise SystemExit("--collective-backend user on a 2-D mesh "
                             "requires --fsdp (ZeRO sharding over the "
                             "data axis); without it use model dim 1")
        if args.microbatches > 1:
            raise SystemExit("--collective-backend user does not compose "
                             "with --microbatches yet")

    cell = build_cell(cfg, shape_spec, mesh, opt_cfg=ocfg,
                      microbatches=args.microbatches,
                      cast_params_bf16=args.cast_bf16)
    jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings)

    with compat.set_mesh(mesh):
        params = registry.init_params(cfg, jax.random.PRNGKey(0))
        opt_state = opt_mod.init(params)
        # place onto the cell's shardings (FSDP/TP distribution)
        params = jax.device_put(params, cell.in_shardings[0])
        opt_state = jax.device_put(opt_state, cell.in_shardings[1])
        b_shardings = cell.in_shardings[2]

    eng = ProgressEngine()
    src = SyntheticLM(cfg.vocab_size, args.seq, args.global_batch, seed=5)

    def to_batch(b):
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.is_encoder_decoder:
            batch["encoder_embeds"] = jnp.ones(
                (args.global_batch, cfg.encoder_frames, cfg.d_model),
                jnp.bfloat16)
        return batch

    pipe = PrefetchPipeline(map(to_batch, iter(src)), eng, depth=3)

    def step_fn(params, opt_state, batch):
        batch = {k: jax.device_put(v, b_shardings[k]) for k, v in batch.items()}
        return jitted(params, opt_state, batch)

    elastic_on = args.elastic or args.heartbeat_timeout > 0 \
        or args.chaos_kill > 0
    if elastic_on and not user_backend:
        raise SystemExit("--elastic/--chaos-kill/--heartbeat-timeout "
                         "require --collective-backend user (the epoch "
                         "invalidates user-space persistent collectives)")

    from repro.collectives.nonblocking import CollectiveSpec
    spec = CollectiveSpec(backend=args.collective_backend,
                          algorithm=args.collective_algorithm,
                          chunks=args.collective_chunks,
                          round_batch=args.collective_round_batch or None)

    split, reducer, epoch, remesh_fn = None, None, None, None
    if user_backend:
        # Split step: shard_map-local grads (stacked per device) + an
        # engine-driven bucketed allreduce + a jitted apply.  Traced
        # OUTSIDE the mesh context so in-model shard hints no-op inside
        # the manual shard_map region.
        from jax.sharding import PartitionSpec as P
        from repro.collectives.overlap import EngineGradReducer
        from repro.train.train_loop import UserCollectiveStep

        def local_grad(params, batch):
            cparams = params
            if args.cast_bf16:
                # mirror build_cell's cast_params_bf16: bf16 forward,
                # f32 master params and gradients
                cdt = jnp.dtype(cfg.dtype)
                cparams = jax.tree.map(
                    lambda p: p.astype(cdt)
                    if p.dtype == jnp.float32 and p.ndim > 1 else p, params)
            (loss, mets), g = jax.value_and_grad(
                registry.loss_fn, has_aux=True)(cparams, cfg, batch)
            stacked = jax.tree.map(
                lambda v: v[None].astype(jnp.float32), g)
            mets = dict(mets, loss=loss)
            return jax.tree.map(lambda v: v[None], mets), stacked

        def make_grad_fn(mesh_):
            return jax.jit(compat.shard_map(
                local_grad, mesh=mesh_, in_specs=(P(), P("data")),
                out_specs=P("data")))

        @jax.jit
        def apply_fn(params, opt_state, grads, stacked_mets):
            params, opt_state, om = opt_mod.apply(ocfg, opt_state,
                                                  params, grads)
            mets = {k: jnp.mean(v) for k, v in stacked_mets.items()}
            return params, opt_state, dict(mets, **om)

        if elastic_on:
            from repro.collectives.nonblocking import MembershipEpoch
            epoch = MembershipEpoch()

        reducer = EngineGradReducer(mesh, "data", engine=eng, spec=spec,
                                    mean=True, epoch=epoch)
        split = UserCollectiveStep(make_grad_fn(mesh), apply_fn, reducer,
                                   spec=spec)

        if elastic_on:
            from jax.sharding import NamedSharding

            from repro.distributed import elastic

            def remesh_fn(exc, params, opt_state):
                # survivors' mesh: pure data-parallel (model dim stays 1)
                survivors = getattr(exc, "survivors", None) \
                    or len(jax.devices())
                new_mesh = elastic.remesh(survivors, prefer_model=1)
                print(f"remesh: {getattr(exc, 'survivors', '?')} "
                      f"survivor(s) -> mesh {dict(new_mesh.shape)}")
                reducer.remesh(new_mesh, "data")
                params = jax.device_put(
                    params, NamedSharding(new_mesh, P()))
                opt_state = jax.device_put(
                    opt_state, NamedSharding(new_mesh, P()))
                return (UserCollectiveStep(make_grad_fn(new_mesh),
                                           apply_fn, reducer, spec=spec),
                        params, opt_state)

        print(f"collective backend: user "
              f"({reducer.algorithm}, chunks={args.collective_chunks}, "
              f"round_batch={args.collective_round_batch or 'auto'}, "
              f"persistent schedules per bucket)")

    loop_cfg = TrainLoopConfig(
        total_steps=args.steps, checkpoint_every=10,
        checkpoint_dir=os.path.join(args.ckpt_dir, args.arch),
        log_every=5, collective_spec=spec)
    hooks = [lambda s, m: print(
        f"step {s:4d} loss={m['loss']:.4f} "
        f"{m['step_time_s'] * 1e3:.0f}ms", flush=True)]
    if args.heartbeat_timeout > 0:
        from repro.distributed.fault_tolerance import HeartbeatMonitor
        hb = HeartbeatMonitor(
            eng, [f"rank{i}" for i in range(len(jax.devices()))],
            timeout=args.heartbeat_timeout, epoch=epoch)
        hooks.append(lambda s, m: [hb.beat(p) for p in hb.alive])
    if args.chaos_kill > 0:
        killed = []

        def chaos_hook(s, m):
            if s >= args.chaos_kill_step and not killed:
                killed.append(s)
                survivors = max(1, len(jax.devices()) - args.chaos_kill)
                print(f"chaos: killing {args.chaos_kill} device(s) at "
                      f"step {s} -> {survivors} survivors")
                epoch.invalidate(survivors=survivors,
                                 reason=f"--chaos-kill {args.chaos_kill}")
        hooks.append(chaos_hook)
    trainer = Trainer(
        step_fn, params, opt_state, pipe, loop_cfg,
        engine=eng, split_step=split, epoch=epoch, remesh_fn=remesh_fn,
        hooks=hooks)
    if user_backend:
        log = trainer.run()
    else:
        with compat.set_mesh(mesh):
            log = trainer.run()
    pipe.close()
    if reducer is not None:
        reducer.close()
    if log:
        print(f"final loss {log[-1]['loss']:.4f}")
    else:
        # resume found a checkpoint at/past --steps: nothing left to run
        print(f"nothing to do: resumed past step {args.steps - 1} "
              f"(rm -r {loop_cfg.checkpoint_dir} to restart)")
    return 0


def build_fsdp_programs(cfg, ocfg, mesh, layout, *, axis="data"):
    """The three jitted FSDP step programs over ``mesh``'s data axis.

    Shared verbatim by the user and native backends — the *only*
    difference between the two paths is who moves the bytes (persistent
    engine handles vs the in-program ``all_gather``/``psum_scatter``
    pair in ``ag_fn``/``rs_fn``), so a loss-trajectory comparison
    measures exactly the collectives.

    * ``grad_fn(gathered_flats, batch)`` — unflattens the full flat
      buckets ``[n, W]`` in-program, runs loss+grad, reflattens to
      stacked grad buckets ``[n, W]``;
    * ``apply_fn(shards, opt_state, grad_shards, stacked_mets)`` — the
      sharded AdamW step (each rank updates only its block; grad norm
      via cross-data psum of shard sum-of-squares);
    * ``ag_fn(shards)`` / ``rs_fn(flat_grads)`` — the native
      collectives, as standalone programs mirroring the engine handles.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.models import registry
    from repro.train import optimizer as opt_mod

    n = layout.n
    B = layout.num_buckets

    def local_grad(flats, batch):
        params = layout.unflatten([f[0] for f in flats])
        (loss, mets), g = jax.value_and_grad(
            registry.loss_fn, has_aux=True)(params, cfg, batch)
        gleaves = [l.astype(jnp.float32) for l in jax.tree.leaves(g)]
        flat_g = [layout.flatten_bucket(gleaves, b)[None] for b in range(B)]
        mets = dict(mets, loss=loss)
        return jax.tree.map(lambda v: v[None], mets), flat_g

    grad_fn = jax.jit(compat.shard_map(
        local_grad, mesh=mesh, in_specs=(P(axis), P(axis)),
        out_specs=P(axis)))

    state_spec = opt_mod.AdamWState(step=P(), mu=P(axis), nu=P(axis))

    def local_apply(shards, opt_state, gshards, smets):
        state = opt_mod.AdamWState(opt_state.step,
                                   [m[0] for m in opt_state.mu],
                                   [v[0] for v in opt_state.nu])
        new_sh, new_state, om = opt_mod.apply_shards(
            ocfg, state, [s[0] for s in shards], [g[0] for g in gshards],
            axis=axis, grad_scale=1.0 / n)
        mets = {k: jax.lax.pmean(v[0], axis) for k, v in smets.items()}
        return ([s[None] for s in new_sh],
                opt_mod.AdamWState(new_state.step,
                                   [m[None] for m in new_state.mu],
                                   [v[None] for v in new_state.nu]),
                dict(mets, **om))

    apply_fn = jax.jit(compat.shard_map(
        local_apply, mesh=mesh,
        in_specs=(P(axis), state_spec, P(axis), P(axis)),
        out_specs=(P(axis), state_spec, P())))

    def local_ag(shards):
        return [jax.lax.all_gather(s[0], axis, tiled=True)[None]
                for s in shards]

    ag_fn = jax.jit(compat.shard_map(
        local_ag, mesh=mesh, in_specs=(P(axis),), out_specs=P(axis)))

    def local_rs(flat_grads):
        return [jax.lax.psum_scatter(g[0], axis, scatter_dimension=0,
                                     tiled=True)[None]
                for g in flat_grads]

    rs_fn = jax.jit(compat.shard_map(
        local_rs, mesh=mesh, in_specs=(P(axis),), out_specs=P(axis)))

    return grad_fn, apply_fn, ag_fn, rs_fn


def _run_fsdp(args, cfg, ocfg, mesh):
    """ZeRO-style FSDP rehearsal over the mesh's data axis.

    Params and AdamW moments live as flat per-dtype bucket shards
    ``[n, W/n]`` (rank ``r`` owns row ``r``); every step all-gathers the
    full flat buckets for the forward/backward and reduce-scatters the
    grad buckets so each rank receives only the block it will apply —
    half the wire bytes of the allreduce path.  ``--collective-backend
    user`` moves both through persistent engine handles, with the next
    step's gathers chained as continuations off the optimizer's compute
    futures; ``native`` runs the same step programs with in-program
    ``all_gather``/``psum_scatter``.  Other mesh axes (``model``)
    replicate, so the same step runs unchanged on (4,1) and (2,2).
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.collectives.nonblocking import CollectiveSpec, MembershipEpoch
    from repro.collectives.overlap import FsdpLayout, FsdpReducer
    from repro.core import ProgressEngine
    from repro.data.pipeline import PrefetchPipeline, SyntheticLM
    from repro.models import registry
    from repro.train import optimizer as opt_mod
    from repro.train.train_loop import FsdpStep, Trainer, TrainLoopConfig

    axis = "data"
    user_backend = args.collective_backend == "user"
    spec = CollectiveSpec(backend=args.collective_backend,
                          algorithm=args.collective_algorithm,
                          chunks=args.collective_chunks,
                          round_batch=args.collective_round_batch or None)
    eng = ProgressEngine()

    elastic_on = args.elastic or args.heartbeat_timeout > 0 \
        or args.chaos_kill > 0
    if elastic_on and not user_backend:
        raise SystemExit("--elastic/--chaos-kill/--heartbeat-timeout "
                         "require --collective-backend user")
    epoch = MembershipEpoch() if elastic_on else None

    with compat.set_mesh(mesh):
        params = registry.init_params(cfg, jax.random.PRNGKey(0))

    def shard_state(mesh_, params_tree, mu_tree=None, nu_tree=None,
                    step=None):
        n = dict(mesh_.shape)[axis]
        layout = FsdpLayout(params_tree, n, args.fsdp_bucket_bytes)
        sharding = NamedSharding(mesh_, P(axis))
        shards = layout.shard_params(params_tree, mesh_, axis)
        if mu_tree is None:
            mu = [jax.device_put(jnp.zeros_like(s), sharding)
                  for s in shards]
            nu = [jax.device_put(jnp.zeros_like(s), sharding)
                  for s in shards]
            step = jnp.zeros((), jnp.int32)
        else:
            mu = layout.shard_params(mu_tree, mesh_, axis)
            nu = layout.shard_params(nu_tree, mesh_, axis)
        return layout, shards, opt_mod.AdamWState(step, mu, nu)

    layout, shards, opt_state = shard_state(mesh, params)
    print(f"fsdp: {layout.num_buckets} bucket(s), shard widths "
          f"{[w // layout.n for w in layout.widths]} over {axis}="
          f"{layout.n} ({args.collective_backend} backend)")
    grad_fn, apply_fn, ag_fn, rs_fn = build_fsdp_programs(
        cfg, ocfg, mesh, layout, axis=axis)

    reducer, split, step_fn, remesh_fn = None, None, None, None
    if user_backend:
        reducer = FsdpReducer(mesh, axis, engine=eng, spec=spec,
                              bucket_bytes=args.fsdp_bucket_bytes,
                              epoch=epoch)
        split = FsdpStep(grad_fn, apply_fn, reducer, spec=spec)
    else:
        def step_fn(shards, opt_state, batch):
            flats = ag_fn(shards)
            smets, flat_grads = grad_fn(flats, batch)
            gshards = rs_fn(flat_grads)
            return apply_fn(shards, opt_state, gshards, smets)

    if user_backend and elastic_on:
        from repro.distributed import elastic

        model_dim = dict(mesh.shape).get("model", 1)

        def remesh_fn(exc, shards_, opt_state_):
            nonlocal layout
            survivors = getattr(exc, "survivors", None) \
                or len(jax.devices())
            new_mesh = elastic.remesh(survivors, prefer_model=model_dim)
            print(f"remesh: {getattr(exc, 'survivors', '?')} survivor(s) "
                  f"-> mesh {dict(new_mesh.shape)}")
            # shard widths depend on the data-axis size: gather the old
            # shards on host, rebuild the layout + programs for the new
            # mesh, re-shard params AND moments (step counter carries)
            params_tree = layout.unshard_params(shards_)
            mu_tree = layout.unshard_params(opt_state_.mu)
            nu_tree = layout.unshard_params(opt_state_.nu)
            reducer.remesh(new_mesh, axis)
            layout, new_shards, new_state = shard_state(
                new_mesh, params_tree, mu_tree, nu_tree, opt_state_.step)
            g2, a2, _, _ = build_fsdp_programs(cfg, ocfg, new_mesh,
                                               layout, axis=axis)
            return (FsdpStep(g2, a2, reducer, spec=spec),
                    new_shards, new_state)

    src = SyntheticLM(cfg.vocab_size, args.seq, args.global_batch, seed=5)

    def to_batch(b):
        return {k: jnp.asarray(v) for k, v in b.items()}

    pipe = PrefetchPipeline(map(to_batch, iter(src)), eng, depth=3)

    loop_cfg = TrainLoopConfig(
        total_steps=args.steps, checkpoint_every=max(args.steps, 10),
        checkpoint_dir=os.path.join(args.ckpt_dir, args.arch + "-fsdp"),
        log_every=5, collective_spec=spec)
    hooks = [lambda s, m: print(
        f"step {s:4d} loss={m['loss']:.6f} "
        f"{m['step_time_s'] * 1e3:.0f}ms", flush=True)]
    if args.heartbeat_timeout > 0:
        from repro.distributed.fault_tolerance import monitor_mesh
        hb = monitor_mesh(eng, mesh, axis, timeout=args.heartbeat_timeout,
                          epoch=epoch)
        hooks.append(lambda s, m: [hb.beat(p) for p in hb.alive])
    if args.chaos_kill > 0:
        killed = []

        def chaos_hook(s, m):
            if s >= args.chaos_kill_step and not killed:
                killed.append(s)
                survivors = max(1, len(jax.devices()) - args.chaos_kill)
                print(f"chaos: killing {args.chaos_kill} device(s) at "
                      f"step {s} -> {survivors} survivors")
                epoch.invalidate(survivors=survivors,
                                 reason=f"--chaos-kill {args.chaos_kill}")
        hooks.append(chaos_hook)

    trainer = Trainer(step_fn, shards, opt_state, pipe, loop_cfg,
                      engine=eng, split_step=split, epoch=epoch,
                      remesh_fn=remesh_fn, hooks=hooks)
    log = trainer.run()
    pipe.close()
    if reducer is not None:
        print(f"prefetch overlap: {reducer.prefetch_overlap:.3f} "
              f"({reducer.gathers} chained gathers)")
        reducer.close()
    if log:
        print(f"final loss {log[-1]['loss']:.6f}")
    else:
        print(f"nothing to do: resumed past step {args.steps - 1} "
              f"(rm -r {loop_cfg.checkpoint_dir} to restart)")
    return 0


def _run_pipeline(args):
    """Pipeline-parallel rehearsal: a residual-MLP stage stack trained
    against a fixed linear teacher, on a (data x stage) mesh.

    * ``--pipeline gpipe``: the monolithic ``lax.scan`` reference —
      forward AND backward differentiate through the tick scan inside
      one jitted step (data dim must be 1).
    * ``--pipeline 1f1b``: one event-driven :class:`PipelineSchedule`
      per data row (per-stage executor-owned streams, persistent p2p
      handoffs), composed with the existing ``EngineGradReducer`` over
      the data axis of the 2-D mesh — the split-step
      ``UserCollectiveStep`` path, exactly as for plain data-parallel.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.collectives.overlap import EngineGradReducer
    from repro.core import ProgressEngine, ProgressExecutor
    from repro.data.pipeline import PrefetchPipeline
    from repro.distributed import pipeline as pl
    from repro.train import optimizer as opt_mod
    from repro.train.train_loop import (Trainer, TrainLoopConfig,
                                        UserCollectiveStep)

    n_dev = len(jax.devices())
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
    else:
        S0 = args.pipeline_stages or n_dev
        shape = (max(n_dev // S0, 1), S0)
    D, S = shape
    if args.pipeline_stages and args.pipeline_stages != S:
        raise SystemExit(f"--pipeline-stages {args.pipeline_stages} "
                         f"contradicts --mesh {args.mesh} (stage dim {S})")
    if D * S > n_dev:
        raise SystemExit(f"mesh {D}x{S} needs {D * S} devices, have {n_dev}")
    if args.pipeline == "gpipe" and D != 1:
        raise SystemExit("--pipeline gpipe differentiates through one "
                         "scan; use a 1xS mesh (data dim 1)")
    mesh = Mesh(np.array(jax.devices()[:D * S]).reshape(D, S),
                ("data", "stage"))
    M = max(args.microbatches, 1)
    d_model, d_hidden, mb = 16, 32, max(args.global_batch, 1)
    print(f"pipeline={args.pipeline} mesh={dict(mesh.shape)} "
          f"microbatches={M} "
          f"bubble={pl.bubble_fraction(S, M, args.pipeline):.3f} "
          f"peak_act={pl.peak_activation_microbatches(S, M, args.pipeline)}")

    def stage_fn(p, x):
        return x + jnp.tanh(x @ p["w1"]) @ p["w2"]

    def loss_fn(y, t):
        return jnp.mean((y - t) ** 2)

    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    params = {
        "w1": jax.random.normal(k1, (S, d_model, d_hidden)) * 0.1,
        "w2": jax.random.normal(k2, (S, d_hidden, d_model)) * 0.1,
    }
    ocfg = opt_mod.AdamWConfig(lr=3e-3, warmup_steps=5,
                               total_steps=max(args.steps, 10))
    opt_state = opt_mod.init(params)

    eng = ProgressEngine()
    ex = ProgressExecutor(eng, num_workers=2).start()
    eng.attach_executor(ex)

    rng = np.random.default_rng(7)
    teacher = (rng.standard_normal((d_model, d_model))
               .astype(np.float32) * 0.3)

    def gen():
        while True:
            xs = rng.standard_normal((D, M, mb, d_model)).astype(np.float32)
            yield {"xs": jnp.asarray(xs), "ts": jnp.asarray(xs @ teacher)}

    pipe = PrefetchPipeline(gen(), eng, depth=3)

    @jax.jit
    def apply_fn(params, opt_state, grads, stacked_mets):
        params, opt_state, om = opt_mod.apply(ocfg, opt_state,
                                              params, grads)
        mets = {k: jnp.mean(v) for k, v in stacked_mets.items()}
        return params, opt_state, dict(mets, **om)

    from repro.collectives.nonblocking import CollectiveSpec
    pspec = CollectiveSpec(
        backend="user" if args.pipeline == "1f1b" else "native",
        algorithm=args.collective_algorithm,
        chunks=args.collective_chunks,
        round_batch=args.collective_round_batch or None)
    loop_cfg = TrainLoopConfig(
        total_steps=args.steps, checkpoint_every=10,
        checkpoint_dir=os.path.join(args.ckpt_dir,
                                    f"pipeline-{args.pipeline}"),
        log_every=5, collective_spec=pspec, pipeline=args.pipeline)
    hooks = [lambda s, m: print(
        f"step {s:4d} loss={m['loss']:.4f} "
        f"{m['step_time_s'] * 1e3:.0f}ms", flush=True)]

    rows, reducer = [], None
    if args.pipeline == "gpipe":
        gmesh = Mesh(mesh.devices[0], ("stage",))
        params = jax.device_put(params, NamedSharding(gmesh, P("stage")))
        gp = pl.gpipe(stage_fn, gmesh, "stage", S)

        def gp_loss(p, xs, ts):
            ys = gp(p, xs)
            per = jnp.stack([loss_fn(ys[m], ts[m]) for m in range(M)])
            return jnp.mean(per)

        @jax.jit
        def step_fn(p, o, batch):
            loss, g = jax.value_and_grad(gp_loss)(
                p, batch["xs"][0], batch["ts"][0])
            p, o, om = opt_mod.apply(ocfg, o, p, g)
            return p, o, dict(loss=loss, **om)

        trainer = Trainer(step_fn, params, opt_state, pipe, loop_cfg,
                          engine=eng, hooks=hooks)
    else:
        params = jax.device_put(params, NamedSharding(mesh, P("stage")))
        for r in range(D):
            rmesh = Mesh(mesh.devices[r], ("stage",))
            rows.append(pl.PipelineSchedule(
                stage_fn, rmesh, "stage", S, loss_fn=loss_fn,
                engine=eng, executor=ex, name=f"pipe{r}"))
        sharding2d = NamedSharding(mesh, P("data", "stage"))

        def stack_rows(*row_leaves):
            # row r's [S, w...] leaf is one single-device [1, w...] shard
            # per stage — reassemble all D*S of them into one global
            # [D, S, w...] array for the data-axis reduction (zero-copy)
            shards = [sh[None]
                      for leaf in row_leaves
                      for sh in pl.PipelineSchedule._by_stage(leaf)]
            shape = (D, S) + tuple(row_leaves[0].shape[1:])
            return jax.make_array_from_single_device_arrays(
                shape, sharding2d, shards)

        def grad_fn(params, batch):
            # launch every row's DAG before waiting on any: the rows'
            # stage streams progress concurrently under the executor
            reqs = [rows[r].istep(params, batch["xs"][r], batch["ts"][r])
                    for r in range(D)]
            outs = [rows[r]._wait(reqs[r], timeout=600) for r in range(D)]
            # each row's loss scalar lives on that row's last-stage
            # device; hop through host for the [D] metrics stack
            losses = jnp.asarray(np.stack(
                [np.asarray(o[0]) for o in outs]))
            grads = jax.tree.map(stack_rows, *[o[1] for o in outs])
            return {"loss": losses}, grads

        reducer = EngineGradReducer(mesh, "data", engine=eng, spec=pspec,
                                    mean=True)
        split = UserCollectiveStep(grad_fn, apply_fn, reducer, spec=pspec)
        trainer = Trainer(None, params, opt_state, pipe, loop_cfg,
                          engine=eng, split_step=split, hooks=hooks)

    log = trainer.run()
    pipe.close()
    for r in rows:
        r.close()
    if reducer is not None:
        reducer.close()
    ex.shutdown(drain=True, timeout=600)
    if log:
        print(f"final loss {log[-1]['loss']:.4f}")
        if rows:
            st = rows[0].stats()
            print(f"pipe0 stats: hops={st['hop_starts']} "
                  f"p2p_completions={st['p2p_stream_completions']} "
                  f"blocking_waits={st['blocking_waits']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
