"""Whisper-style encoder-decoder backbone. [arXiv:2212.04356]

The conv audio frontend is a STUB per the assignment: ``input_specs()``
feeds precomputed frame embeddings ``[B, frames, d_model]`` directly to
the encoder.  LayerNorm (with bias) + GELU MLPs + learned decoder
positions + sinusoidal encoder positions, biased q/v projections —
matching the whisper architecture rather than the llama conventions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding import shard_hint


def _ln_spec(n, NL=None):
    if NL is None:
        return {"scale": L.PSpec((n,), ("embed_nofsdp",), init="ones"),
                "bias": L.PSpec((n,), ("embed_nofsdp",), init="zeros")}
    return {"scale": L.PSpec((NL, n), ("layers", "embed_nofsdp"), init="ones"),
            "bias": L.PSpec((NL, n), ("layers", "embed_nofsdp"), init="zeros")}


def _ln(x, p, eps):
    return L.layernorm(x, p["scale"], p["bias"], eps)


def param_spec(cfg: ModelConfig):
    D, V = cfg.d_model, cfg.vocab_size
    NE, ND = cfg.num_encoder_layers, cfg.num_layers
    spec = {
        "embed": L.PSpec((V, D), ("vocab", "embed"), init="embed"),
        "pos_embed": L.PSpec((min(cfg.max_position_embeddings, 1 << 16), D),
                             (None, "embed"), init="embed"),
        "encoder": {
            "attn": L.attn_spec(cfg, layers=NE),
            "mlp": L.mlp_spec(cfg, layers=NE),
            "ln1": _ln_spec(D, NE),
            "ln2": _ln_spec(D, NE),
        },
        "enc_final_ln": _ln_spec(D),
        "decoder": {
            "attn": L.attn_spec(cfg, layers=ND),
            "xattn": L.attn_spec(cfg, layers=ND),
            "mlp": L.mlp_spec(cfg, layers=ND),
            "ln1": _ln_spec(D, ND),
            "lnx": _ln_spec(D, ND),
            "ln2": _ln_spec(D, ND),
        },
        "dec_final_ln": _ln_spec(D),
    }
    return spec


def init_params(cfg, rng):
    return L.init_tree(param_spec(cfg), rng, jnp.dtype(cfg.param_dtype))


def param_axes(cfg):
    return L.axes_tree(param_spec(cfg))


def param_shapes(cfg):
    return L.shapes_tree(param_spec(cfg), jnp.dtype(cfg.param_dtype))


def _remat(fn, cfg):
    if cfg.remat_policy == "none":
        return fn
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, encoder_embeds):
    """encoder_embeds: [B, F, D] (stub conv frontend output)."""
    x = encoder_embeds.astype(jnp.dtype(cfg.dtype))
    F = x.shape[1]
    sin = jnp.asarray(L.sinusoidal_positions(F, cfg.d_model), x.dtype)
    x = x + sin[None]
    x = shard_hint(x, "batch", "frames", "act_embed")
    positions = jnp.arange(F)[None, :]

    def body(x, lp):
        h = _ln(x, lp["ln1"], cfg.rms_norm_eps)
        q, k, v = L.attn_qkv(lp["attn"], h, positions, cfg, use_rope=False)
        o = L.attention(q, k, v, causal=False, chunk=cfg.attention_chunk)
        x = x + L.attn_out(lp["attn"], o)
        h = _ln(x, lp["ln2"], cfg.rms_norm_eps)
        x = x + L.mlp_apply(lp["mlp"], h, act=jax.nn.gelu)
        return x, None

    x, _ = jax.lax.scan(_remat(body, cfg), x, params["encoder"])
    return _ln(x, params["enc_final_ln"], cfg.rms_norm_eps)


# ---------------------------------------------------------------------------
# Decoder (train/prefill: full sequence; decode: single token + caches)
# ---------------------------------------------------------------------------

def _xattn(cfg, lp, x, enc_kv):
    """Cross-attention; enc K/V precomputed per layer: [B,F,KVH,hd]."""
    ek, ev = enc_kv
    h = _ln(x, lp["lnx"], cfg.rms_norm_eps)
    dt = h.dtype
    q = jnp.einsum("bsd,dhk->bshk", h, lp["xattn"]["wq"].astype(dt))
    if "bq" in lp["xattn"]:
        q = q + lp["xattn"]["bq"].astype(dt)
    o = L.attention(q, ek, ev, causal=False, chunk=cfg.attention_chunk)
    return x + L.attn_out(lp["xattn"], o)


def _enc_kv(cfg, lp, enc_out):
    dt = enc_out.dtype
    k = jnp.einsum("bfd,dhk->bfhk", enc_out, lp["xattn"]["wk"].astype(dt))
    v = jnp.einsum("bfd,dhk->bfhk", enc_out, lp["xattn"]["wv"].astype(dt))
    if "bv" in lp["xattn"]:
        k = k + lp["xattn"]["bk"].astype(dt)
        v = v + lp["xattn"]["bv"].astype(dt)
    return k, v


def decode_train(params, cfg: ModelConfig, tokens, enc_out):
    """tokens: [B,S]; enc_out: [B,F,D] -> logits [B,S,V]."""
    dt = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    S = x.shape[1]
    pos_table = params["pos_embed"]
    x = x + pos_table[:S][None].astype(dt)
    x = shard_hint(x, "batch", "act_seq", "act_embed")
    positions = jnp.arange(S)[None, :]

    def body(x, lp):
        h = _ln(x, lp["ln1"], cfg.rms_norm_eps)
        q, k, v = L.attn_qkv(lp["attn"], h, positions, cfg, use_rope=False)
        o = L.attention(q, k, v, causal=True, chunk=cfg.attention_chunk)
        x = x + L.attn_out(lp["attn"], o)
        x = _xattn(cfg, lp, x, _enc_kv(cfg, lp, enc_out))
        h = _ln(x, lp["ln2"], cfg.rms_norm_eps)
        x = x + L.mlp_apply(lp["mlp"], h, act=jax.nn.gelu)
        return x, None

    x, _ = jax.lax.scan(_remat(body, cfg), x, params["decoder"])
    x = _ln(x, params["dec_final_ln"], cfg.rms_norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    return shard_hint(logits.astype(jnp.float32), "batch", "act_seq", "act_vocab")


def forward(params, cfg: ModelConfig, tokens, encoder_embeds):
    enc_out = encode(params, cfg, encoder_embeds)
    return decode_train(params, cfg, tokens, enc_out), jnp.zeros((), jnp.float32)


def cache_spec(cfg: ModelConfig, batch: int, max_seq: int):
    ND, KVH, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim()
    F = cfg.encoder_frames
    cdt = jnp.dtype(cfg.dtype)
    kv_axes = ("layers", "cache_batch", "cache_seq", "act_kv_heads", "head_dim")
    x_axes = ("layers", "cache_batch", "frames", "act_kv_heads", "head_dim")
    return {
        "k": L.PSpec((ND, batch, max_seq, KVH, hd), kv_axes, init="zeros", dtype=cdt),
        "v": L.PSpec((ND, batch, max_seq, KVH, hd), kv_axes, init="zeros", dtype=cdt),
        # cross-attention K/V precomputed from the encoder output at prefill
        "xk": L.PSpec((ND, batch, F, KVH, hd), x_axes, init="zeros", dtype=cdt),
        "xv": L.PSpec((ND, batch, F, KVH, hd), x_axes, init="zeros", dtype=cdt),
    }


def cache_shapes(cfg, batch, max_seq):
    return L.shapes_tree(cache_spec(cfg, batch, max_seq))


def cache_axes(cfg, batch, max_seq):
    return L.axes_tree(cache_spec(cfg, batch, max_seq))


def init_cache(cfg, batch, max_seq):
    return L.init_tree(cache_spec(cfg, batch, max_seq), jax.random.PRNGKey(0))


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, fed=None):
    """One decoder token; cross-attn K/V come from the cache.  ``fed``
    is accepted for API uniformity and ignored (attention-only: KV
    writes are position-indexed and overwritten before exposure)."""
    del fed
    dt = jnp.dtype(cfg.dtype)
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = x + jnp.take(params["pos_embed"], pos, axis=0)[:, None].astype(dt)

    def body(x, scanned):
        lp, kc, vc, xk, xv = scanned
        h = _ln(x, lp["ln1"], cfg.rms_norm_eps)
        q, k_new, v_new = L.attn_qkv(lp["attn"], h, pos[:, None], cfg, use_rope=False)
        kc = kc.at[jnp.arange(B), pos].set(k_new[:, 0])
        vc = vc.at[jnp.arange(B), pos].set(v_new[:, 0])
        o = L.decode_attention(q, kc, vc, pos)
        x = x + L.attn_out(lp["attn"], o)
        # cross attention (all F frames valid)
        h = _ln(x, lp["lnx"], cfg.rms_norm_eps)
        qx = jnp.einsum("bsd,dhk->bshk", h, lp["xattn"]["wq"].astype(h.dtype))
        if "bq" in lp["xattn"]:
            qx = qx + lp["xattn"]["bq"].astype(h.dtype)
        F = xk.shape[1]
        ox = L.decode_attention(qx, xk, xv, jnp.full((B,), F - 1, jnp.int32))
        x = x + L.attn_out(lp["xattn"], ox)
        h = _ln(x, lp["ln2"], cfg.rms_norm_eps)
        x = x + L.mlp_apply(lp["mlp"], h, act=jax.nn.gelu)
        return x, (kc, vc)

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["decoder"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = _ln(x, params["dec_final_ln"], cfg.rms_norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = new_k, new_v
    return logits.astype(jnp.float32), new_cache


def loss_fn(params, cfg: ModelConfig, batch):
    logits, aux = forward(params, cfg, batch["tokens"], batch["encoder_embeds"])
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(lse - gold)
    return nll + aux, {"nll": nll, "aux": aux}
