"""zamba2-style hybrid: Mamba2 backbone + ONE shared full-attention block
applied every ``shared_attn_every`` SSM layers, with per-site LoRA deltas
on its projections. [arXiv:2411.15242]

Scan layout: the 38 SSM layers are grouped as ``n_groups`` groups of
``shared_attn_every`` layers (remainder layers form a tail group without
an attention site), and the scan runs over groups.  Shared-attention
parameters are *broadcast* into the scan (same weights every site); only
the LoRA a/b factors are stacked per site — exactly zamba2's weight
sharing, and it keeps compile time depth-independent.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba as M
from repro.sharding import shard_hint


def group_layout(cfg: ModelConfig) -> tuple[int, int, int]:
    """(n_groups, group_size, tail) for the scanned grouping."""
    k = cfg.shared_attn_every
    n_groups = cfg.num_layers // k
    tail = cfg.num_layers - n_groups * k
    return n_groups, k, tail


def param_spec(cfg: ModelConfig):
    D, V = cfg.d_model, cfg.vocab_size
    n_groups, k, tail = group_layout(cfg)
    n_sites = n_groups
    spec = {
        "embed": L.PSpec((V, D), ("vocab", "embed"), init="embed"),
        # grouped SSM blocks: [n_groups, k, ...] — scan over groups, inner
        # python loop over k (k is small and static)
        "blocks": M.block_spec(cfg, cfg.num_layers - tail),
        "block_norms": L.PSpec((cfg.num_layers - tail, D),
                               ("layers", "embed_nofsdp"), init="ones"),
        # the single shared attention+MLP block (no leading layer axis)
        "shared": {
            "attn": L.attn_spec(cfg),
            "mlp": L.mlp_spec(cfg),
            "ln1": L.PSpec((D,), ("embed_nofsdp",), init="ones"),
            "ln2": L.PSpec((D,), ("embed_nofsdp",), init="ones"),
        },
        # per-site LoRA on shared attn q/k/v (stacked on sites)
        "site_lora": _lora_spec(cfg, n_sites),
        "final_norm": L.PSpec((D,), ("embed_nofsdp",), init="ones"),
    }
    if tail:
        spec["tail_blocks"] = M.block_spec(cfg, tail)
        spec["tail_norms"] = L.PSpec((tail, D), ("layers", "embed_nofsdp"), init="ones")
    if not cfg.tie_embeddings:
        spec["lm_head"] = L.PSpec((D, V), ("embed", "vocab"), fan_in=D)
    return spec


def _lora_spec(cfg: ModelConfig, n_sites: int):
    D, H, KVH = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim()
    r = cfg.shared_attn_lora_rank
    spec = {}
    for nm, outd, outax in (("q", (H, hd), ("heads", "head_dim")),
                            ("k", (KVH, hd), ("kv_heads", "head_dim")),
                            ("v", (KVH, hd), ("kv_heads", "head_dim"))):
        spec[f"lora_{nm}_a"] = L.PSpec((n_sites, D, r), ("layers", "embed", None), fan_in=D)
        spec[f"lora_{nm}_b"] = L.PSpec((n_sites, r) + outd, ("layers", None) + outax, init="zeros")
    return spec


def init_params(cfg, rng):
    return L.init_tree(param_spec(cfg), rng, jnp.dtype(cfg.param_dtype))


def param_axes(cfg):
    return L.axes_tree(param_spec(cfg))


def param_shapes(cfg):
    return L.shapes_tree(param_spec(cfg), jnp.dtype(cfg.param_dtype))


def _remat(fn, cfg):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def _shared_attn_fwd(cfg, sp, lora, x, positions, cache=None, pos=None):
    """Shared attention + MLP block with per-site LoRA merged in."""
    ap = dict(sp["attn"])
    ap.update(lora)
    h = L.rmsnorm(x, sp["ln1"], cfg.rms_norm_eps)
    q, k, v = L.attn_qkv(ap, h, positions, cfg)
    if cache is None:
        o = L.attention_dispatch(cfg, q, k, v, causal=True)
        new_cache = None
    else:
        kc, vc = cache
        B = x.shape[0]
        kc = kc.at[jnp.arange(B), pos].set(k[:, 0])
        vc = vc.at[jnp.arange(B), pos].set(v[:, 0])
        o = L.decode_attention(q, kc, vc, pos)
        new_cache = (kc, vc)
    x = x + L.attn_out(ap, o)
    h = L.rmsnorm(x, sp["ln2"], cfg.rms_norm_eps)
    x = x + L.mlp_apply(sp["mlp"], h)
    return shard_hint(x, "batch", "act_seq", "act_embed"), new_cache


def _stack_index(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def forward(params, cfg: ModelConfig, tokens):
    from repro.models.transformer import embed_tokens, unembed
    x = embed_tokens(params, cfg, tokens)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    n_groups, k, tail = group_layout(cfg)

    # reshape stacked blocks [n_groups*k, ...] -> [n_groups, k, ...]
    grouped = jax.tree.map(lambda a: a.reshape((n_groups, k) + a.shape[1:]),
                           params["blocks"])
    gnorms = params["block_norms"].reshape(n_groups, k, -1)

    def group_body(x, scanned):
        gblocks, gn, lora = scanned
        for i in range(k):
            bp = _stack_index(gblocks, i)
            h = L.rmsnorm(x, gn[i], cfg.rms_norm_eps)
            x = x + M.block_forward(bp, cfg, h)
        x, _ = _shared_attn_fwd(cfg, params["shared"], lora, x, positions)
        return x, None

    x, _ = jax.lax.scan(_remat(group_body, cfg), x,
                        (grouped, gnorms, params["site_lora"]))
    for i in range(tail):
        bp = _stack_index(params["tail_blocks"], i)
        h = L.rmsnorm(x, params["tail_norms"][i], cfg.rms_norm_eps)
        x = x + M.block_forward(bp, cfg, h)
    x = L.rmsnorm(x, params["final_norm"], cfg.rms_norm_eps)
    return unembed(params, cfg, x), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Cache + decode: SSM states for every mamba layer + KV cache per attn site
# ---------------------------------------------------------------------------

def cache_spec(cfg: ModelConfig, batch: int, max_seq: int):
    n_groups, k, tail = group_layout(cfg)
    KVH, hd = cfg.num_kv_heads, cfg.resolved_head_dim()
    kv_shape = (n_groups, batch, max_seq, KVH, hd)
    kv_axes = ("layers", "cache_batch", "cache_seq", "act_kv_heads", "head_dim")
    spec = {
        "ssm": M.state_spec(cfg, cfg.num_layers - tail, batch),
        "attn_k": L.PSpec(kv_shape, kv_axes, init="zeros", dtype=jnp.dtype(cfg.dtype)),
        "attn_v": L.PSpec(kv_shape, kv_axes, init="zeros", dtype=jnp.dtype(cfg.dtype)),
    }
    if tail:
        spec["tail_ssm"] = M.state_spec(cfg, tail, batch)
    return spec


def cache_shapes(cfg, batch, max_seq):
    return L.shapes_tree(cache_spec(cfg, batch, max_seq))


def cache_axes(cfg, batch, max_seq):
    return L.axes_tree(cache_spec(cfg, batch, max_seq))


def init_cache(cfg, batch, max_seq):
    return L.init_tree(cache_spec(cfg, batch, max_seq), jax.random.PRNGKey(0))


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, fed=None):
    from repro.models.transformer import unembed
    x, new_cache = decode_hidden(params, cfg, cache, tokens, pos, fed)
    return unembed(params, cfg, x), new_cache


def decode_hidden(params, cfg: ModelConfig, cache, tokens, pos, fed=None):
    """``fed`` [B] bool freezes non-fed lanes' SSM state (see mamba.py)
    — the attention KV rows need no mask: a non-fed lane's write at its
    own ``pos`` is overwritten before the causal mask exposes it."""
    from repro.models.transformer import embed_tokens
    x = embed_tokens(params, cfg, tokens)
    n_groups, k, tail = group_layout(cfg)

    grouped = jax.tree.map(lambda a: a.reshape((n_groups, k) + a.shape[1:]),
                           params["blocks"])
    gnorms = params["block_norms"].reshape(n_groups, k, -1)
    gssm = jax.tree.map(lambda a: a.reshape((n_groups, k) + a.shape[1:]),
                        cache["ssm"])

    def group_body(x, scanned):
        gblocks, gn, lora, sts, kc, vc = scanned
        new_sts = []
        for i in range(k):
            bp = _stack_index(gblocks, i)
            st = _stack_index(sts, i)
            h = L.rmsnorm(x, gn[i], cfg.rms_norm_eps)
            y, new_st = M.block_decode(bp, cfg, st, h)
            if fed is not None:
                new_st = M.masked_state(fed, new_st, st)
            x = x + y
            new_sts.append(new_st)
        sts = jax.tree.map(lambda *a: jnp.stack(a), *new_sts)
        x, (kc, vc) = _shared_attn_fwd(cfg, params["shared"], lora, x,
                                       pos[:, None], cache=(kc, vc), pos=pos)
        return x, (sts, kc, vc)

    x, (new_ssm, new_k, new_v) = jax.lax.scan(
        group_body, x,
        (grouped, gnorms, params["site_lora"], gssm,
         cache["attn_k"], cache["attn_v"]))
    new_cache = {
        "ssm": jax.tree.map(lambda a: a.reshape((n_groups * k,) + a.shape[2:]), new_ssm),
        "attn_k": new_k, "attn_v": new_v,
    }
    if tail:
        tail_sts = []
        for i in range(tail):
            bp = _stack_index(params["tail_blocks"], i)
            st = _stack_index(cache["tail_ssm"], i)
            h = L.rmsnorm(x, params["tail_norms"][i], cfg.rms_norm_eps)
            y, new_st = M.block_decode(bp, cfg, st, h)
            if fed is not None:
                new_st = M.masked_state(fed, new_st, st)
            x = x + y
            tail_sts.append(new_st)
        new_cache["tail_ssm"] = jax.tree.map(lambda *a: jnp.stack(a), *tail_sts)
    x = L.rmsnorm(x, params["final_norm"], cfg.rms_norm_eps)
    return x, new_cache


def loss_fn(params, cfg: ModelConfig, batch):
    logits, aux = forward(params, cfg, batch["tokens"])
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(lse - gold)
    return nll + aux, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# Paged decode: block-table-indexed KV at each shared-attention site,
# fed-masked recurrent state for the SSM layers (see mamba.py notes)
# ---------------------------------------------------------------------------

PAGED_HAS_BLOCKS = True     # the attention sites cache KV per position


def paged_cache_spec(cfg: ModelConfig, lanes: int, num_blocks: int,
                     block_size: int):
    n_groups, k, tail = group_layout(cfg)
    KVH, hd = cfg.num_kv_heads, cfg.resolved_head_dim()
    kv_shape = (n_groups, num_blocks, block_size, KVH, hd)
    kv_axes = ("layers", None, "cache_seq", "act_kv_heads", "head_dim")
    spec = {
        "ssm": M.state_spec(cfg, cfg.num_layers - tail, lanes),
        "attn_k": L.PSpec(kv_shape, kv_axes, init="zeros",
                          dtype=jnp.dtype(cfg.dtype)),
        "attn_v": L.PSpec(kv_shape, kv_axes, init="zeros",
                          dtype=jnp.dtype(cfg.dtype)),
    }
    if tail:
        spec["tail_ssm"] = M.state_spec(cfg, tail, lanes)
    return spec


def init_paged_cache(cfg: ModelConfig, lanes: int, num_blocks: int,
                     block_size: int):
    return L.init_tree(paged_cache_spec(cfg, lanes, num_blocks, block_size),
                       jax.random.PRNGKey(0))


def reset_cache_lane(cfg: ModelConfig, cache, lane_index: int):
    """Slot-cache lane reset: zero the lane's SSM state (the ``ssm`` /
    ``tail_ssm`` subtrees are lane-indexed in both cache layouts, so the
    paged reset applies verbatim); attention rows are position-indexed
    and need no reset."""
    return reset_paged_lane(cfg, cache, lane_index)


def reset_paged_lane(cfg: ModelConfig, cache, lane_index: int):
    """Zero one lane's SSM state; the attention block pools need no
    reset (block discipline: stale bytes are never gathered unmasked)."""
    new = dict(cache)
    new["ssm"] = jax.tree.map(lambda a: a.at[:, lane_index].set(0),
                              cache["ssm"])
    if "tail_ssm" in cache:
        new["tail_ssm"] = jax.tree.map(lambda a: a.at[:, lane_index].set(0),
                                       cache["tail_ssm"])
    return new


def _shared_attn_paged(cfg, sp, lora, x, kc, vc, pos, tables):
    """Shared attention + MLP block against the paged KV pool of one
    site.  kc/vc: [num_blocks, bs, KVH, hd]; tables: [B, max_blocks]."""
    from repro.models.transformer import _paged_view, paged_scatter
    ap = dict(sp["attn"])
    ap.update(lora)
    h = L.rmsnorm(x, sp["ln1"], cfg.rms_norm_eps)
    q, k, v = L.attn_qkv(ap, h, pos[:, None], cfg)
    kc, vc = paged_scatter(kc, vc, k[:, 0], v[:, 0], tables, pos)
    o = L.decode_attention(q, _paged_view(kc, tables),
                           _paged_view(vc, tables), pos)
    x = x + L.attn_out(ap, o)
    h = L.rmsnorm(x, sp["ln2"], cfg.rms_norm_eps)
    x = x + L.mlp_apply(sp["mlp"], h)
    return shard_hint(x, "batch", "act_seq", "act_embed"), kc, vc


def decode_step_paged(params, cfg: ModelConfig, cache, tokens, pos, tables,
                      fed=None):
    from repro.models.transformer import unembed
    x, new_cache = decode_hidden_paged(params, cfg, cache, tokens, pos,
                                       tables, fed)
    return unembed(params, cfg, x), new_cache


def decode_hidden_paged(params, cfg: ModelConfig, cache, tokens, pos, tables,
                        fed=None):
    from repro.models.transformer import embed_tokens
    x = embed_tokens(params, cfg, tokens)
    n_groups, k, tail = group_layout(cfg)

    grouped = jax.tree.map(lambda a: a.reshape((n_groups, k) + a.shape[1:]),
                           params["blocks"])
    gnorms = params["block_norms"].reshape(n_groups, k, -1)
    gssm = jax.tree.map(lambda a: a.reshape((n_groups, k) + a.shape[1:]),
                        cache["ssm"])

    def group_body(x, scanned):
        gblocks, gn, lora, sts, kc, vc = scanned
        new_sts = []
        for i in range(k):
            bp = _stack_index(gblocks, i)
            st = _stack_index(sts, i)
            h = L.rmsnorm(x, gn[i], cfg.rms_norm_eps)
            y, new_st = M.block_decode(bp, cfg, st, h)
            if fed is not None:
                new_st = M.masked_state(fed, new_st, st)
            x = x + y
            new_sts.append(new_st)
        sts = jax.tree.map(lambda *a: jnp.stack(a), *new_sts)
        x, kc, vc = _shared_attn_paged(cfg, params["shared"], lora, x,
                                       kc, vc, pos, tables)
        return x, (sts, kc, vc)

    x, (new_ssm, new_k, new_v) = jax.lax.scan(
        group_body, x,
        (grouped, gnorms, params["site_lora"], gssm,
         cache["attn_k"], cache["attn_v"]))
    new_cache = {
        "ssm": jax.tree.map(lambda a: a.reshape((n_groups * k,) + a.shape[2:]), new_ssm),
        "attn_k": new_k, "attn_v": new_v,
    }
    if tail:
        tail_sts = []
        for i in range(tail):
            bp = _stack_index(params["tail_blocks"], i)
            st = _stack_index(cache["tail_ssm"], i)
            h = L.rmsnorm(x, params["tail_norms"][i], cfg.rms_norm_eps)
            y, new_st = M.block_decode(bp, cfg, st, h)
            if fed is not None:
                new_st = M.masked_state(fed, new_st, st)
            x = x + y
            tail_sts.append(new_st)
        new_cache["tail_ssm"] = jax.tree.map(lambda *a: jnp.stack(a), *tail_sts)
    x = L.rmsnorm(x, params["final_norm"], cfg.rms_norm_eps)
    return x, new_cache
