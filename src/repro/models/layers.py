"""Shared model building blocks.

Conventions:

* Parameters live in nested dicts of ``jnp`` arrays.  The *structure* is
  declared once as a tree of :class:`PSpec` (shape + logical axes + init);
  ``init_tree`` / ``axes_tree`` / ``shapes_tree`` derive everything else,
  so the dry-run never has to materialize parameters.
* Layers that are scanned over carry a leading ``"layers"`` axis.
* Compute dtype is ``cfg.dtype`` (bf16 by default); softmax, norms and
  accumulations are f32.
* Attention here is the **XLA path**: a chunked online-softmax scan whose
  memory profile matches the Pallas flash kernel (``repro.kernels``) — the
  dry-run/roofline therefore reflects flash-attention-like HLO bytes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.sharding import shard_hint

# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"        # normal | zeros | ones | embed | ssm_a | ssm_dt
    fan_in: int | None = None   # overrides fan-in for "normal"
    dtype: Any = None           # overrides param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def init_tree(spec_tree, rng: jax.Array, param_dtype=jnp.float32):
    """Materialize a parameter tree from a PSpec tree."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=_is_pspec)
    rngs = jax.random.split(rng, len(leaves))
    out = []
    for spec, key in zip(leaves, rngs):
        dtype = spec.dtype or param_dtype
        if spec.init == "zeros":
            v = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            v = jnp.ones(spec.shape, dtype)
        elif spec.init == "embed":
            v = (jax.random.normal(key, spec.shape, dtype) * 0.02).astype(dtype)
        elif spec.init == "ssm_a":
            # A_log init: log(uniform in [1, 16))
            lo, hi = 1.0, 16.0
            u = jax.random.uniform(key, spec.shape, jnp.float32, lo, hi)
            v = jnp.log(u).astype(dtype)
        elif spec.init == "ssm_dt":
            # dt_bias init: inverse softplus of uniform log-spaced dt
            lo, hi = 1e-3, 1e-1
            u = jax.random.uniform(key, spec.shape, jnp.float32)
            dt = jnp.exp(u * (math.log(hi) - math.log(lo)) + math.log(lo))
            v = (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
        elif spec.init == "normal":
            fan_in = spec.fan_in
            if fan_in is None:
                fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            std = 1.0 / math.sqrt(max(fan_in, 1))
            v = (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)
        else:
            raise ValueError(spec.init)
        out.append(v)
    return jax.tree.unflatten(treedef, out)


def axes_tree(spec_tree):
    return jax.tree.map(lambda s: s.axes, spec_tree, is_leaf=_is_pspec)


def shapes_tree(spec_tree, param_dtype=jnp.float32):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or param_dtype),
        spec_tree, is_leaf=_is_pspec)


# ---------------------------------------------------------------------------
# Norms / positional embeddings / activations
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, scale, bias, eps: float):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def rope(x, positions, theta: float):
    """Rotary embeddings. x: [..., S, H, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs[None, :]  # [..., S, half]
    cos = jnp.cos(angles)[..., :, None, :]   # [..., S, 1, half]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int) -> np.ndarray:
    pos = np.arange(length)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / dim)
    return np.concatenate([np.sin(angle), np.cos(angle)], axis=-1).astype(np.float32)


def softcap(x, cap: float):
    if cap and cap > 0.0:
        return (jnp.tanh(x / cap) * cap).astype(x.dtype)
    return x


# ---------------------------------------------------------------------------
# Attention — chunked online-softmax (flash-equivalent XLA formulation)
# ---------------------------------------------------------------------------

NEG_INF = -1e30

# Training-mode context: some collective placements (the custom MoE block)
# only pay off when a backward pass follows.  registry.loss_fn sets this.
import contextlib as _contextlib
import threading as _threading

_mode = _threading.local()


@_contextlib.contextmanager
def training_mode():
    prev = getattr(_mode, "training", False)
    _mode.training = True
    try:
        yield
    finally:
        _mode.training = prev


def in_training() -> bool:
    return getattr(_mode, "training", False)


def attention(q, k, v, *, causal: bool, chunk: int = 1024, q_offset=0,
              logit_cap: float = 0.0, bias_mode: str | None = None):
    """Multi-head attention with GQA, scanned over KV chunks.

    q: [B, Sq, H, hd]; k, v: [B, Sk, KVH, hd].  Returns [B, Sq, H, hd].

    The KV sequence is processed in chunks with a running (max, denom,
    accumulator) — the same dataflow as the Pallas flash kernel, so the
    compiled HLO never materializes the [Sq, Sk] score matrix.
    """
    B, Sq, H, hd = q.shape
    _, Sk, KVH, _ = k.shape
    assert H % KVH == 0
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)

    chunk = min(chunk, Sk)
    if Sk % chunk != 0:
        chunk = Sk  # small/odd cases: single chunk
    n_chunks = Sk // chunk

    q = (q.astype(jnp.float32) * scale).astype(q.dtype)
    q_pos = q_offset + jnp.arange(Sq)

    # [n, B, chunk, KVH, hd]
    ks = k.reshape(B, n_chunks, chunk, KVH, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, n_chunks, chunk, KVH, hd).transpose(1, 0, 2, 3, 4)

    def step(carry, inputs):
        m, l, acc = carry
        idx, k_c, v_c = inputs
        # repeat KV group-wise to full heads; shardable over "act_heads"
        k_r = jnp.repeat(k_c, G, axis=2)   # [B, chunk, H, hd]
        v_r = jnp.repeat(v_c, G, axis=2)
        s = jnp.einsum("bqhd,bchd->bhqc", q, k_r,
                       preferred_element_type=jnp.float32)
        s = softcap(s, logit_cap) if logit_cap else s
        if causal:
            k_pos = idx * chunk + jnp.arange(chunk)
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask[None, None], s, NEG_INF)
        m_c = jnp.max(s, axis=-1)                       # [B,H,Sq]
        m_new = jnp.maximum(m, m_c)
        # guard fully-masked rows
        m_safe = jnp.maximum(m_new, NEG_INF / 2)
        p = jnp.exp(s - m_safe[..., None])              # [B,H,Sq,chunk]
        corr = jnp.exp(jnp.minimum(m - m_new, 0.0))
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqc,bchd->bqhd", p.astype(v_r.dtype), v_r,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr.transpose(0, 2, 1)[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    acc0 = jnp.zeros((B, Sq, H, hd), jnp.float32)
    if n_chunks == 1:
        (m, l, acc), _ = step((m0, l0, acc0), (jnp.int32(0), ks[0], vs[0]))
    else:
        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, acc0), (jnp.arange(n_chunks), ks, vs))
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def attention_blockskip(q, k, v, *, chunk: int = 1024, logit_cap: float = 0.0):
    """Causal attention over ONLY the lower-triangular (q-block, kv-block)
    pairs — a static schedule of nc(nc+1)/2 block GEMMs instead of nc²,
    halving attention FLOPs exactly (the flash-kernel block-skip, in XLA).
    """
    B, S, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    chunk = min(chunk, S)
    if S % chunk or S == chunk:
        return attention(q, k, v, causal=True, chunk=chunk, logit_cap=logit_cap)
    nc = S // chunk
    scale = 1.0 / math.sqrt(hd)
    qf = (q.astype(jnp.float32) * scale).astype(q.dtype)

    qr = qf.reshape(B, nc, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    kr = k.reshape(B, nc, chunk, KVH, hd).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(B, nc, chunk, KVH, hd).transpose(1, 0, 2, 3, 4)

    pairs = [(qi, ki) for qi in range(nc) for ki in range(qi + 1)]
    qi_arr = jnp.asarray([p[0] for p in pairs], jnp.int32)
    ki_arr = jnp.asarray([p[1] for p in pairs], jnp.int32)

    pos_in_chunk = jnp.arange(chunk)

    def step(carry, idx):
        m, l, acc = carry
        qi, ki = idx
        qb = jax.lax.dynamic_index_in_dim(qr, qi, 0, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(kr, ki, 0, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vr, ki, 0, keepdims=False)
        k_r = jnp.repeat(kb, G, axis=2)
        v_r = jnp.repeat(vb, G, axis=2)
        s = jnp.einsum("bqhd,bchd->bhqc", qb, k_r,
                       preferred_element_type=jnp.float32)
        s = softcap(s, logit_cap) if logit_cap else s
        qpos = qi * chunk + pos_in_chunk
        kpos = ki * chunk + pos_in_chunk
        mask = qpos[:, None] >= kpos[None, :]
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_q = jax.lax.dynamic_index_in_dim(m, qi, 0, keepdims=False)
        l_q = jax.lax.dynamic_index_in_dim(l, qi, 0, keepdims=False)
        a_q = jax.lax.dynamic_index_in_dim(acc, qi, 0, keepdims=False)
        m_c = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_q, m_c)
        m_safe = jnp.maximum(m_new, NEG_INF / 2)
        p = jnp.exp(s - m_safe[..., None])
        corr = jnp.exp(jnp.minimum(m_q - m_new, 0.0))
        l_new = l_q * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqc,bchd->bqhd", p.astype(v_r.dtype), v_r,
                        preferred_element_type=jnp.float32)
        a_new = a_q * corr.transpose(0, 2, 1)[..., None] + pv
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 0)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, qi, 0)
        return (m, l, acc), None

    m0 = jnp.full((nc, B, H, chunk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nc, B, H, chunk), jnp.float32)
    acc0 = jnp.zeros((nc, B, chunk, H, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (qi_arr, ki_arr))
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 1, 3, 2)[..., None]
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    return out.astype(q.dtype)


def attention_dispatch(cfg, q, k, v, *, causal: bool = True):
    """Select the attention implementation from cfg.attention_impl."""
    if cfg.attention_impl == "ring" and causal:
        from repro.collectives.ring_attention import ring_attention
        return ring_attention(q, k, v, causal=True, logit_cap=cfg.logit_softcap)
    if cfg.attention_impl == "xla_blockskip" and causal:
        return attention_blockskip(q, k, v, chunk=cfg.attention_chunk,
                                   logit_cap=cfg.logit_softcap)
    return attention(q, k, v, causal=causal, chunk=cfg.attention_chunk,
                     logit_cap=cfg.logit_softcap)


def decode_attention(q, k_cache, v_cache, pos, *, logit_cap: float = 0.0):
    """Single-token attention against a (possibly sequence-sharded) cache.

    q: [B, 1, H, hd]; caches: [B, S, KVH, hd]; pos: [B] (#valid entries).
    GQA is computed via head grouping (no KV repeat), so the cache can be
    sharded on S or KVH and SPMD inserts the reduction collectives.
    """
    B, _, H, hd = q.shape
    _, S, KVH, _ = k_cache.shape
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KVH, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32) * scale,
                   k_cache.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    s = softcap(s, logit_cap) if logit_cap else s
    valid = jnp.arange(S)[None, :] < pos[:, None] + 1       # [B,S]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", (p / l).astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (params + apply), GQA + optional bias + RoPE
# ---------------------------------------------------------------------------

def padded_heads(cfg) -> tuple[int, int]:
    """(H, KVH) after optional padding to a multiple of cfg.pad_heads_to.

    CAVEAT: padding both H and KVH changes the GQA q→kv grouping, so this
    is an *architecture variant* for TP experiments, not an equivalence-
    preserving transform (see EXPERIMENTS §Perf notes).  The semantics-
    preserving route to sharded attention for awkward head counts is ring
    attention (``attention_impl="ring"``), which shards the sequence.
    """
    H, KVH = cfg.num_heads, cfg.num_kv_heads
    p = cfg.pad_heads_to
    if not p or not H:
        return H, KVH
    pad = lambda n: ((n + p - 1) // p) * p
    return pad(H), pad(KVH)


def attn_spec(cfg, layers: int | None = None, lora_rank: int = 0):
    D = cfg.d_model
    H, KVH = padded_heads(cfg)
    hd = cfg.resolved_head_dim()
    L = (layers,) if layers is not None else ()
    lax = ("layers",) if layers is not None else ()
    spec = {
        "wq": PSpec(L + (D, H, hd), lax + ("embed", "heads", "head_dim"), fan_in=D),
        "wk": PSpec(L + (D, KVH, hd), lax + ("embed", "kv_heads", "head_dim"), fan_in=D),
        "wv": PSpec(L + (D, KVH, hd), lax + ("embed", "kv_heads", "head_dim"), fan_in=D),
        "wo": PSpec(L + (H, hd, D), lax + ("heads", "head_dim", "embed"), fan_in=H * hd),
    }
    if cfg.qkv_bias:
        spec["bq"] = PSpec(L + (H, hd), lax + ("heads", "head_dim"), init="zeros")
        spec["bk"] = PSpec(L + (KVH, hd), lax + ("kv_heads", "head_dim"), init="zeros")
        spec["bv"] = PSpec(L + (KVH, hd), lax + ("kv_heads", "head_dim"), init="zeros")
    if lora_rank:
        for nm, outd in (("q", (H, hd)), ("k", (KVH, hd)), ("v", (KVH, hd))):
            spec[f"lora_{nm}_a"] = PSpec(L + (D, lora_rank), lax + ("embed", None), fan_in=D)
            spec[f"lora_{nm}_b"] = PSpec(L + (lora_rank,) + outd, lax + (None,) + (("heads", "head_dim") if nm == "q" else ("kv_heads", "head_dim")), init="zeros")
    return spec


def attn_qkv(p, x, positions, cfg, *, use_rope=True):
    """Project to q, k, v (with optional bias/LoRA) and apply RoPE."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if "lora_q_a" in p:
        for nm, t in (("q", q), ("k", k), ("v", v)):
            a, b = p[f"lora_{nm}_a"].astype(dt), p[f"lora_{nm}_b"].astype(dt)
            delta = jnp.einsum("bsr,rhk->bshk", jnp.einsum("bsd,dr->bsr", x, a), b)
            if nm == "q":
                q = q + delta
            elif nm == "k":
                k = k + delta
            else:
                v = v + delta
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = shard_hint(q, "batch", "act_seq", "act_heads", "head_dim")
    k = shard_hint(k, "batch", "act_seq", "act_kv_heads", "head_dim")
    v = shard_hint(v, "batch", "act_seq", "act_kv_heads", "head_dim")
    return q, k, v


def attn_out(p, o):
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
    return shard_hint(y, "batch", "act_seq", "act_embed")


# ---------------------------------------------------------------------------
# MLP (SwiGLU) + MoE
# ---------------------------------------------------------------------------

def mlp_spec(cfg, layers: int | None = None, d_ff: int | None = None):
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    L = (layers,) if layers is not None else ()
    lax = ("layers",) if layers is not None else ()
    return {
        "wi_gate": PSpec(L + (D, F), lax + ("embed", "mlp"), fan_in=D),
        "wi_up": PSpec(L + (D, F), lax + ("embed", "mlp"), fan_in=D),
        "wo": PSpec(L + (F, D), lax + ("mlp", "embed"), fan_in=F),
    }


def mlp_apply(p, x, act=jax.nn.silu):
    dt = x.dtype
    g = jnp.einsum("bsd,df->bsf", x, p["wi_gate"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", x, p["wi_up"].astype(dt))
    h = act(g) * u
    h = shard_hint(h, "batch", "act_seq", "act_mlp")
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dt))


def moe_spec(cfg, layers: int | None = None):
    D, E = cfg.d_model, cfg.moe.num_experts
    F = cfg.moe.expert_d_ff
    L = (layers,) if layers is not None else ()
    lax = ("layers",) if layers is not None else ()
    return {
        "router": PSpec(L + (D, E), lax + ("embed", None), fan_in=D),
        "wi_gate": PSpec(L + (E, D, F), lax + ("experts", "embed", "expert_mlp"), fan_in=D),
        "wi_up": PSpec(L + (E, D, F), lax + ("experts", "embed", "expert_mlp"), fan_in=D),
        "wo": PSpec(L + (E, F, D), lax + ("experts", "expert_mlp", "embed"), fan_in=F),
    }


def _moe_expert_block(xg, dispatch, combine, wi_gate, wi_up, wo):
    """Dispatch → expert FFN → combine, with ALL model-axis collectives
    placed explicitly (paper thesis: user-level collective placement).

    Left to the partitioner, the model-axis partial-sums land on the
    *dispatched* tensors ([g,E,C,d]: top_k×capacity-inflated — measured
    8–12 GB/layer/device f32 on grok-1), and the group dim got gathered
    too.  Because dispatch and combine are linear in the token tensor,
    both reductions commute to TOKEN space: inside shard_map the only
    collectives are one fwd psum of y [g,t,d] and (via AD of the
    replicated inputs) psums of d_xg [g,t,d] + d_combine [g,t,E,C].
    ``dispatch`` must be stop_gradient-ed (it is a mask; its cotangent
    psum would be pure waste).

    Expert weights enter as their (F @ model)-sharded local blocks; the
    d-dim FSDP gather happens once per layer at the shard_map boundary.
    """
    from repro.sharding import _abstract_mesh, resolve_spec
    mesh = _abstract_mesh()
    F = wi_gate.shape[-1]
    tp = 1 if (mesh is None or mesh.empty) else mesh.shape.get("model", 1)
    # The explicit block imposes expert-internal TP (F over `model`).
    # Worth it only for wide experts (grok: F/tp = 2048); for many-tiny-
    # expert MoEs (granite: F/tp = 32) the [g,t,E,C] combine-space psums
    # exceed the savings — measured in EXPERIMENTS §Perf B — so fall back
    # to the capacity-sharded einsum formulation.
    if mesh is None or mesh.empty or tp == 1 or F % tp != 0 \
            or F // tp < 512 or not in_training():
        xe = jnp.einsum("gtec,gtd->gecd", dispatch, xg)
        xe = shard_hint(xe, "moe_groups", "act_experts", "expert_cap", "act_embed")
        h = (jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, wi_gate))
             * jnp.einsum("gecd,edf->gecf", xe, wi_up))
        h = shard_hint(h, "moe_groups", "act_experts", "expert_cap",
                       "act_expert_mlp")
        ye = jnp.einsum("gecf,efd->gecd", h, wo)
        return jnp.einsum("gtec,gecd->gtd", combine, ye)
    from jax.sharding import PartitionSpec as P
    g_spec = resolve_spec(("moe_groups",), (xg.shape[0],), mesh)
    gax = g_spec[0] if len(g_spec) else None

    batch_axes = (gax,) if isinstance(gax, str) else tuple(gax or ())
    blk = _make_moe_blk_vjp(batch_axes)
    return compat.shard_map(
        blk, mesh=mesh,
        in_specs=(P(gax, None, None), P(gax, None, None, None),
                  P(gax, None, None, None),
                  P(None, None, "model"), P(None, None, "model"),
                  P(None, "model", None)),
        out_specs=P(gax, None, None))(xg, dispatch, combine,
                                      wi_gate, wi_up, wo)


def _moe_blk_fwd_inner(xg_l, disp_l, comb_l, wg_l, wu_l, wo_l):
    xe = jnp.einsum("gtec,gtd->gecd", disp_l, xg_l)          # local
    g1 = jnp.einsum("gecd,edf->gecf", xe, wg_l)              # F-local
    u1 = jnp.einsum("gecd,edf->gecf", xe, wu_l)
    h = jax.nn.silu(g1) * u1
    ye_p = jnp.einsum("gecf,efd->gecd", h, wo_l)             # partial over F
    y_p = jnp.einsum("gtec,gecd->gtd", comb_l, ye_p)         # still partial
    return jax.lax.psum(y_p, "model"), (xe, g1, u1, ye_p)


import functools as _functools


@_functools.lru_cache(maxsize=None)
def _make_moe_blk_vjp(batch_axes: tuple):
    """custom_vjp MoE block for fixed batch (data) axes.

    Weight cotangents vary over the batch axes inside shard_map and must
    be psum'd over them explicitly (the FSDP gradient reduction — XLA's
    ReduceScatterCreator turns the AR+slice at the boundary into a
    reduce-scatter)."""

    @jax.custom_vjp
    def blk(xg_l, disp_l, comb_l, wg_l, wu_l, wo_l):
        return _moe_blk_fwd_inner(xg_l, disp_l, comb_l, wg_l, wu_l, wo_l)[0]

    def fwd(xg_l, disp_l, comb_l, wg_l, wu_l, wo_l):
        y = _moe_blk_fwd_inner(xg_l, disp_l, comb_l, wg_l, wu_l, wo_l)[0]
        return y, (xg_l, disp_l, comb_l, wg_l, wu_l, wo_l)

    def bwd(res, dy):
        # Hand-placed backward: the ONLY cross-model collectives are the
        # token-space psums of d_xg and d_comb — XLA's reassociation
        # otherwise moves them onto the capacity-inflated tensors.
        xg_l, disp_l, comb_l, wg_l, wu_l, wo_l = res
        # recompute forward intermediates locally (cheaper than saving)
        xe = jnp.einsum("gtec,gtd->gecd", disp_l, xg_l)
        g1 = jnp.einsum("gecd,edf->gecf", xe, wg_l)
        u1 = jnp.einsum("gecd,edf->gecf", xe, wu_l)
        sg = jax.nn.sigmoid(g1.astype(jnp.float32))
        silu_g = (g1.astype(jnp.float32) * sg).astype(g1.dtype)
        h = silu_g * u1
        ye_p = jnp.einsum("gecf,efd->gecd", h, wo_l)

        dy = dy.astype(xg_l.dtype)
        d_comb = jax.lax.psum(
            jnp.einsum("gtd,gecd->gtec", dy, ye_p), "model")
        d_ye = jnp.einsum("gtec,gtd->gecd", comb_l, dy)      # local
        d_h = jnp.einsum("gecd,efd->gecf", d_ye, wo_l)
        d_wo = jnp.einsum("gecf,gecd->efd", h, d_ye)
        d_silu_g = d_h * u1
        d_u1 = d_h * silu_g
        dsilu = (sg * (1 + g1.astype(jnp.float32) * (1 - sg))).astype(g1.dtype)
        d_g1 = d_silu_g * dsilu
        d_xe = (jnp.einsum("gecf,edf->gecd", d_g1, wg_l)
                + jnp.einsum("gecf,edf->gecd", d_u1, wu_l))  # local partial
        d_wg = jnp.einsum("gecd,gecf->edf", xe, d_g1)
        d_wu = jnp.einsum("gecd,gecf->edf", xe, d_u1)
        d_xg = jax.lax.psum(
            jnp.einsum("gtec,gecd->gtd", disp_l, d_xe), "model")
        if batch_axes:
            d_wg = jax.lax.psum(d_wg, batch_axes)
            d_wu = jax.lax.psum(d_wu, batch_axes)
            d_wo = jax.lax.psum(d_wo, batch_axes)
        return (d_xg, disp_l * 0, d_comb, d_wg, d_wu, d_wo)

    blk.defvjp(fwd, bwd)
    return blk


def _moe_route(p, x, cfg):
    """Router + GShard capacity dispatch, shared by every MoE apply path.

    Returns ``(xg, dispatch, combine, aux)`` — the grouped tokens
    ``[g, t, d]``, the (stop-gradient-ready) dispatch mask and combine
    weights ``[g, t, E, C]``, and the Switch aux loss.
    """
    mc = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K = mc.num_experts, mc.top_k
    Gt = min(mc.group_size, T)
    if T % Gt != 0:
        Gt = T
    Gn = T // Gt
    C = max(1, int(math.ceil(Gt * K * mc.capacity_factor / E)))
    # round capacity to a multiple of 16 for clean "expert_cap" sharding
    C = int(min(Gt, ((C + 15) // 16) * 16))

    xg = x.reshape(Gn, Gt, D)
    xg = shard_hint(xg, "moe_groups", None, "act_embed")
    logits = jnp.einsum("gtd,de->gte", xg, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)          # [g,t,K]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # GShard position-in-expert, one top-k slot at a time (k-major order) so
    # the peak live tensor stays [g,t,E,C] rather than [g,t,K,E,C].
    counts = jnp.zeros((Gn, 1, E), jnp.float32)             # tokens routed so far
    combine = jnp.zeros((Gn, Gt, E, C), x.dtype)
    sel_all = jnp.zeros((Gn, Gt, E), jnp.float32)
    for kk in range(K):
        sel_k = jax.nn.one_hot(gate_idx[:, :, kk], E, dtype=jnp.float32)
        pos_k = counts + jnp.cumsum(sel_k, axis=1) - sel_k  # [g,t,E]
        counts = counts + jnp.sum(sel_k, axis=1, keepdims=True)
        keep_k = (pos_k < C) * sel_k
        # scalar position of each token within its chosen expert
        pos_tok = jnp.sum(pos_k * sel_k, axis=-1)           # [g,t]
        cap_oh = jax.nn.one_hot(pos_tok, C, dtype=x.dtype)  # [g,t,C]
        w_k = (gate_vals[:, :, kk:kk + 1].astype(jnp.float32) * keep_k).astype(x.dtype)
        combine = combine + jnp.einsum("gte,gtc->gtec", w_k, cap_oh)
        sel_all = sel_all + sel_k
    combine = shard_hint(combine, "moe_groups", None, "act_experts", "expert_cap")
    dispatch = (combine > 0).astype(x.dtype)

    # load-balance aux loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))                      # [E]
    fe = jnp.mean(sel_all, axis=(0, 1)) / K                # [E] fraction routed
    aux = E * jnp.sum(me * fe) * mc.aux_loss_weight
    return xg, dispatch, combine, aux


def moe_apply(p, x, cfg):
    """GShard-style grouped capacity dispatch (einsum formulation).

    Token groups are a batch-like dim sharded over (pod, data); the expert
    dim (or, when E is not divisible by the tensor axis, the capacity dim)
    shards over "model".  SPMD inserts the dispatch all-to-alls; for the
    explicitly placed expert-parallel variant (user-space Bruck
    all-to-alls on the progress engine) see
    :func:`moe_apply_expert_parallel`.  Returns (y, aux_loss).
    """
    B, S, D = x.shape
    xg, dispatch, combine, aux = _moe_route(p, x, cfg)

    y = _moe_expert_block(
        xg, jax.lax.stop_gradient(dispatch), combine,
        p["wi_gate"].astype(x.dtype), p["wi_up"].astype(x.dtype),
        p["wo"].astype(x.dtype))
    return y.reshape(B, S, D), aux


def moe_dispatch_alltoall(xe, mesh, axis: str, *, reverse: bool = False,
                          coll=None, spec=None, timeout: float = 120.0):
    """Block-transpose the dispatched tensor between the group-sharded
    and expert-sharded layouts — the MoE all-to-all, placed explicitly.

    ``xe`` is the global ``[G, E, C, d]`` dispatched tensor.  Forward
    (``reverse=False``): groups are sharded over ``axis``; the result is
    the same global array with the EXPERT dim sharded instead (each rank
    ends up holding every group's slice of its own experts).  Reverse
    undoes it (the combine-side all-to-all).  Both dims must divide the
    axis size.

    ``coll=None`` runs a jitted in-program ``lax.all_to_all``;  a
    :class:`~repro.collectives.nonblocking.UserCollectives` context runs
    the engine-driven Bruck ``ialltoall`` instead (paper §4.7).  All-to-
    all is pure data movement, so the two are bit-identical — the MoE
    twin of the fig-14 user-vs-native claim.
    """
    from jax.sharding import PartitionSpec as P
    n = dict(mesh.shape)[axis]
    G, E = xe.shape[0], xe.shape[1]
    if G % n or E % n:
        raise ValueError(
            f"moe_dispatch_alltoall: groups ({G}) and experts ({E}) must "
            f"divide the {axis!r} axis size ({n})")
    if n == 1:
        return xe
    if coll is None:
        if reverse:
            fn = compat.shard_map(
                lambda v: jax.lax.all_to_all(v, axis, 0, 1, tiled=True),
                mesh=mesh, in_specs=P(None, axis), out_specs=P(axis))
        else:
            fn = compat.shard_map(
                lambda v: jax.lax.all_to_all(v, axis, 1, 0, tiled=True),
                mesh=mesh, in_specs=P(axis), out_specs=P(None, axis))
        return jax.jit(fn)(xe)
    # user backend: ialltoall's payload is n*n stacked blocks (rank s's
    # rows are its n destination blocks); build block (s, r) = s's groups
    # x r's experts, transpose, and reassemble.
    Gl, El = G // n, E // n
    rest = xe.shape[2:]
    r_axes = tuple(range(4, 4 + len(rest)))
    if reverse:
        # expert-sharded in: rank i holds (source j, its El experts)
        pay = jnp.transpose(
            xe.reshape(n, Gl, n, El, *rest),
            (2, 0, 1, 3) + r_axes).reshape(n * n, Gl, El, *rest)
    else:
        pay = jnp.transpose(
            xe.reshape(n, Gl, n, El, *rest),
            (0, 2, 1, 3) + r_axes).reshape(n * n, Gl, El, *rest)
    out = coll.ialltoall(pay, mesh, axis, spec=spec).wait(timeout=timeout)
    out = out.reshape(n, n, Gl, El, *rest)
    if reverse:
        # row (j, i) = groups of j x experts of i -> group-major global
        return jnp.transpose(out, (0, 2, 1, 3) + r_axes).reshape(
            G, E, *rest)
    # row (i, j) = groups of j x experts of i -> group-major global
    return jnp.transpose(out, (1, 2, 0, 3) + r_axes).reshape(G, E, *rest)


@_functools.lru_cache(maxsize=None)
def _moe_expert_ffn_sharded(mesh, axis: str):
    """Jitted expert-sharded FFN: every contraction is expert-local, so
    the only collectives in the expert-parallel path are the two
    explicit all-to-alls around it."""
    from jax.sharding import PartitionSpec as P

    def ffn(xed, wg, wu, wo):
        h = (jax.nn.silu(jnp.einsum("gecd,edf->gecf", xed, wg))
             * jnp.einsum("gecd,edf->gecf", xed, wu))
        return jnp.einsum("gecf,efd->gecd", h, wo)

    return jax.jit(compat.shard_map(
        ffn, mesh=mesh,
        in_specs=(P(None, axis), P(axis), P(axis), P(axis)),
        out_specs=P(None, axis)))


def moe_apply_expert_parallel(p, x, cfg, mesh, axis: str = "model", *,
                              coll=None, spec=None, timeout: float = 120.0):
    """Expert-parallel MoE with EXPLICIT all-to-all placement — the
    dispatch path for many-tiny-expert configs (granite-moe-3b-a800m:
    E=40 experts of F=512, where expert-internal TP is a loss).

    Tokens are routed on the group-sharded layout, block-transposed to
    the expert shards (:func:`moe_dispatch_alltoall`), run through the
    expert-local FFN, and transposed back for the combine.  With
    ``coll`` the transposes are engine-driven user-space Bruck
    all-to-alls that overlap with host work; without, in-program native
    ones.  Either way the token math is identical einsums to
    :func:`moe_apply`'s fallback path, so outputs are bit-identical
    across all three paths.  Returns (y, aux_loss).
    """
    B, S, D = x.shape
    xg, dispatch, combine, aux = _moe_route(p, x, cfg)
    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xg)      # [G, E, C, d]
    xed = moe_dispatch_alltoall(xe, mesh, axis, coll=coll, spec=spec,
                                timeout=timeout)
    ye = _moe_expert_ffn_sharded(mesh, axis)(
        xed, p["wi_gate"].astype(x.dtype), p["wi_up"].astype(x.dtype),
        p["wo"].astype(x.dtype))
    ye = moe_dispatch_alltoall(ye, mesh, axis, reverse=True, coll=coll,
                               spec=spec, timeout=timeout)
    y = jnp.einsum("gtec,gecd->gtd", combine, ye)
    return y.reshape(B, S, D), aux
