"""Mamba2 (SSD — state-space duality) blocks and LM. [arXiv:2405.21060]

TPU adaptation notes (see DESIGN.md §6):

* The CUDA reference fuses z/x/B/C/dt into one ``in_proj`` and runs one
  grouped conv over ``[x;B;C]``.  We keep **separate projections and
  convs per component** so that the head dimension (``nh``) shards
  cleanly over the tensor axis — the fused layout would interleave
  differently-sharded components in one matrix.
* The SSD chunked algorithm is expressed as matmuls (MXU-friendly):
  intra-chunk "attention" term + inter-chunk recurrent state carried by
  ``lax.scan``.  ``repro.kernels.ssd_scan`` is the Pallas version of the
  intra-chunk block.
* Decode keeps an O(1) recurrent state ``h [B, nh, hp, ds]`` — this is
  what makes ``long_500k`` run where full attention cannot.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding import shard_hint


def dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nh = d_inner // s.head_dim
    return d_inner, nh, s.head_dim, s.d_state


# ---------------------------------------------------------------------------
# Parameter spec (one stacked block set)
# ---------------------------------------------------------------------------

def block_spec(cfg: ModelConfig, layers: int):
    D = cfg.d_model
    d_inner, nh, hp, ds = dims(cfg)
    W = cfg.ssm.conv_width
    NL = layers
    lax = ("layers",)
    return {
        "z_proj": L.PSpec((NL, D, nh, hp), lax + ("embed", "heads", "head_dim"), fan_in=D),
        "x_proj": L.PSpec((NL, D, nh, hp), lax + ("embed", "heads", "head_dim"), fan_in=D),
        "b_proj": L.PSpec((NL, D, ds), lax + ("embed", "state"), fan_in=D),
        "c_proj": L.PSpec((NL, D, ds), lax + ("embed", "state"), fan_in=D),
        "dt_proj": L.PSpec((NL, D, nh), lax + ("embed", "heads"), fan_in=D),
        "conv_x": L.PSpec((NL, W, nh, hp), lax + ("conv", "heads", "head_dim"), fan_in=W),
        "conv_b": L.PSpec((NL, W, ds), lax + ("conv", "state"), fan_in=W),
        "conv_c": L.PSpec((NL, W, ds), lax + ("conv", "state"), fan_in=W),
        "a_log": L.PSpec((NL, nh), lax + ("heads",), init="ssm_a"),
        "d_skip": L.PSpec((NL, nh), lax + ("heads",), init="ones"),
        "dt_bias": L.PSpec((NL, nh), lax + ("heads",), init="ssm_dt"),
        "norm": L.PSpec((NL, nh, hp), lax + ("heads", "head_dim"), init="ones"),
        "out_proj": L.PSpec((NL, nh, hp, D), lax + ("heads", "head_dim", "embed"), fan_in=d_inner),
    }


def param_spec(cfg: ModelConfig):
    D, V = cfg.d_model, cfg.vocab_size
    spec = {
        "embed": L.PSpec((V, D), ("vocab", "embed"), init="embed"),
        "blocks": block_spec(cfg, cfg.num_layers),
        "block_norms": L.PSpec((cfg.num_layers, D), ("layers", "embed_nofsdp"), init="ones"),
        "final_norm": L.PSpec((D,), ("embed_nofsdp",), init="ones"),
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = L.PSpec((D, V), ("embed", "vocab"), fan_in=D)
    return spec


def init_params(cfg, rng):
    return L.init_tree(param_spec(cfg), rng, jnp.dtype(cfg.param_dtype))


def param_axes(cfg):
    return L.axes_tree(param_spec(cfg))


def param_shapes(cfg):
    return L.shapes_tree(param_spec(cfg), jnp.dtype(cfg.param_dtype))


# ---------------------------------------------------------------------------
# Causal conv1d helpers
# ---------------------------------------------------------------------------

def _causal_conv(u, w):
    """u: [B, S, ...feat], w: [W, ...feat] — depthwise causal conv."""
    W = w.shape[0]
    feat = u.shape[2:]
    pad = jnp.zeros((u.shape[0], W - 1) + feat, u.dtype)
    up = jnp.concatenate([pad, u], axis=1)
    out = jnp.zeros_like(u)
    for i in range(W):
        out = out + up[:, i:i + u.shape[1]] * w[i].astype(u.dtype)
    return out


# ---------------------------------------------------------------------------
# SSD forward (chunked, matmul form)
# ---------------------------------------------------------------------------

def ssd_forward(xh, bm, cm, dt, a_log, *, chunk: int):
    """Chunked SSD. xh: [B,S,nh,hp]; bm/cm: [B,S,ds]; dt: [B,S,nh] (post-
    softplus). Returns y: [B,S,nh,hp].

    Within a chunk the quadratic "attention" form runs on the MXU; across
    chunks a recurrent state h [B,nh,hp,ds] is carried by lax.scan.
    """
    B, S, nh, hp = xh.shape
    ds = bm.shape[-1]
    Q = min(chunk, S)
    if S % Q != 0:
        Q = S
    nc = S // Q

    a = -jnp.exp(a_log.astype(jnp.float32))                 # [nh]
    dA = dt.astype(jnp.float32) * a                         # [B,S,nh]

    xc = xh.reshape(B, nc, Q, nh, hp)
    bc = bm.reshape(B, nc, Q, ds).astype(jnp.float32)
    cc = cm.reshape(B, nc, Q, ds).astype(jnp.float32)
    dtc = dt.reshape(B, nc, Q, nh).astype(jnp.float32)
    dAc = dA.reshape(B, nc, Q, nh)
    cum = jnp.cumsum(dAc, axis=2)                           # [B,nc,Q,nh]

    # intra-chunk: y[i] = sum_{j<=i} C_i.B_j exp(cum_i - cum_j) dt_j x_j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # [B,nc,Q,Q,nh]
    seg = shard_hint(seg, "batch", None, None, None, "act_heads")
    tri = jnp.tril(jnp.ones((Q, Q), jnp.float32))
    Lmat = jnp.exp(jnp.clip(seg, -60.0, 0.0)) * tri[None, None, :, :, None]
    cb = jnp.einsum("bnis,bnjs->bnij", cc, bc,
                    preferred_element_type=jnp.float32)     # [B,nc,Q,Q]
    w = (cb[..., None] * Lmat).astype(xh.dtype)             # [B,nc,Q,Q,nh]
    w = shard_hint(w, "batch", None, None, None, "act_heads")
    xdt = (xc.astype(jnp.float32) * dtc[..., None]).astype(xh.dtype)
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", w, xdt,
                         preferred_element_type=jnp.float32)

    # chunk states: S_n = sum_j exp(cum_end - cum_j) dt_j x_j B_j^T
    decay_out = jnp.exp(jnp.clip(cum[:, :, -1:, :] - cum, -60.0, 0.0))  # [B,nc,Q,nh]
    sts = jnp.einsum("bnjhp,bnjh,bnjs->bnhps", xdt, decay_out, bc,
                     preferred_element_type=jnp.float32)    # [B,nc,nh,hp,ds]
    chunk_decay = jnp.exp(jnp.clip(jnp.sum(dAc, axis=2), -60.0, 0.0))  # [B,nc,nh]

    def scan_fn(h, inp):
        st, dec = inp                                       # [B,nh,hp,ds], [B,nh]
        h_prev = h                                          # state *entering* chunk
        h = h * dec[..., None, None] + st
        return h, h_prev

    h0 = jnp.zeros((B, nh, hp, ds), jnp.float32)
    sts_t = sts.transpose(1, 0, 2, 3, 4)                    # [nc,B,nh,hp,ds]
    dec_t = chunk_decay.transpose(1, 0, 2)                  # [nc,B,nh]
    _, h_prevs = jax.lax.scan(scan_fn, h0, (sts_t, dec_t))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)              # [B,nc,nh,hp,ds]

    # inter-chunk: y_inter[i] = exp(cum_i) * C_i . h_prev
    decay_in = jnp.exp(jnp.clip(cum, -60.0, 0.0))           # [B,nc,Q,nh]
    y_inter = jnp.einsum("bnis,bnhps,bnih->bnihp", cc, h_prevs, decay_in,
                         preferred_element_type=jnp.float32)
    y = (y_intra + y_inter).reshape(B, S, nh, hp)
    return y


def ssd_decode_step(h, x1, b1, c1, dt1, a_log):
    """One recurrent step. h: [B,nh,hp,ds]; x1: [B,nh,hp]; b1/c1: [B,ds];
    dt1: [B,nh] (post-softplus). Returns (y [B,nh,hp], h)."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    dA = jnp.exp(dt1.astype(jnp.float32) * a)               # [B,nh]
    dBx = jnp.einsum("bhp,bs,bh->bhps", x1.astype(jnp.float32),
                     b1.astype(jnp.float32), dt1.astype(jnp.float32))
    h = h * dA[..., None, None] + dBx
    y = jnp.einsum("bhps,bs->bhp", h, c1.astype(jnp.float32))
    return y, h


# ---------------------------------------------------------------------------
# Full block (proj + conv + SSD + gate + out)
# ---------------------------------------------------------------------------

def block_forward(bp, cfg: ModelConfig, x):
    """x: [B,S,D] -> [B,S,D]."""
    dt_ = x.dtype
    d_inner, nh, hp, ds = dims(cfg)
    z = jnp.einsum("bsd,dhp->bshp", x, bp["z_proj"].astype(dt_))
    xh = jnp.einsum("bsd,dhp->bshp", x, bp["x_proj"].astype(dt_))
    bm = jnp.einsum("bsd,dk->bsk", x, bp["b_proj"].astype(dt_))
    cm = jnp.einsum("bsd,dk->bsk", x, bp["c_proj"].astype(dt_))
    dt_raw = jnp.einsum("bsd,dh->bsh", x, bp["dt_proj"].astype(dt_))

    xh = jax.nn.silu(_causal_conv(xh, bp["conv_x"]))
    bm = jax.nn.silu(_causal_conv(bm, bp["conv_b"]))
    cm = jax.nn.silu(_causal_conv(cm, bp["conv_c"]))
    xh = shard_hint(xh, "batch", "act_seq", "act_heads", "head_dim")

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         bp["dt_bias"].astype(jnp.float32))
    y = ssd_forward(xh, bm, cm, dt, bp["a_log"], chunk=cfg.ssm.chunk_size)
    y = y + xh.astype(jnp.float32) * bp["d_skip"].astype(jnp.float32)[None, None, :, None]
    # gated RMSNorm (per head)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + cfg.rms_norm_eps) * bp["norm"].astype(jnp.float32)
    y = y.astype(dt_)
    out = jnp.einsum("bshp,hpd->bsd", y, bp["out_proj"].astype(dt_))
    return shard_hint(out, "batch", "act_seq", "act_embed")


def block_decode(bp, cfg: ModelConfig, state, x):
    """x: [B,1,D]; state: dict(conv_x, conv_b, conv_c, h). Returns (y, state)."""
    dt_ = x.dtype
    W = cfg.ssm.conv_width
    z = jnp.einsum("bsd,dhp->bshp", x, bp["z_proj"].astype(dt_))[:, 0]
    xh = jnp.einsum("bsd,dhp->bshp", x, bp["x_proj"].astype(dt_))[:, 0]
    bm = jnp.einsum("bsd,dk->bsk", x, bp["b_proj"].astype(dt_))[:, 0]
    cm = jnp.einsum("bsd,dk->bsk", x, bp["c_proj"].astype(dt_))[:, 0]
    dt_raw = jnp.einsum("bsd,dh->bsh", x, bp["dt_proj"].astype(dt_))[:, 0]

    def conv_step(cache, new, w):
        # cache: [B, W-1, ...feat]; new: [B, ...feat]
        seq = jnp.concatenate([cache, new[:, None]], axis=1)   # [B, W, feat]
        out = jnp.einsum("bw...,w...->b...", seq, w.astype(new.dtype))
        return jax.nn.silu(out), seq[:, 1:]

    xh, cx = conv_step(state["conv_x"], xh, bp["conv_x"])
    bm, cb = conv_step(state["conv_b"], bm, bp["conv_b"])
    cm, cc = conv_step(state["conv_c"], cm, bp["conv_c"])

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + bp["dt_bias"].astype(jnp.float32))
    y, h = ssd_decode_step(state["h"], xh, bm, cm, dt, bp["a_log"])
    y = y + xh.astype(jnp.float32) * bp["d_skip"].astype(jnp.float32)[None, :, None]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + cfg.rms_norm_eps) * bp["norm"].astype(jnp.float32)
    out = jnp.einsum("bhp,hpd->bd", y.astype(dt_), bp["out_proj"].astype(dt_))
    return out[:, None], {"conv_x": cx, "conv_b": cb, "conv_c": cc, "h": h}


def state_spec(cfg: ModelConfig, layers: int, batch: int):
    d_inner, nh, hp, ds = dims(cfg)
    W = cfg.ssm.conv_width
    NL = layers
    cdt = jnp.dtype(cfg.dtype)
    return {
        "conv_x": L.PSpec((NL, batch, W - 1, nh, hp),
                          ("layers", "cache_batch", None, "act_heads", "head_dim"),
                          init="zeros", dtype=cdt),
        "conv_b": L.PSpec((NL, batch, W - 1, ds),
                          ("layers", "cache_batch", None, "state"), init="zeros", dtype=cdt),
        "conv_c": L.PSpec((NL, batch, W - 1, ds),
                          ("layers", "cache_batch", None, "state"), init="zeros", dtype=cdt),
        "h": L.PSpec((NL, batch, nh, hp, ds),
                     ("layers", "cache_batch", "act_heads", "head_dim", "state"),
                     init="zeros", dtype=jnp.float32),
    }


# ---------------------------------------------------------------------------
# Full LM
# ---------------------------------------------------------------------------

def _remat(fn, cfg):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def forward(params, cfg: ModelConfig, tokens):
    from repro.models.transformer import embed_tokens, unembed
    x = embed_tokens(params, cfg, tokens)

    def body(x, scanned):
        bp, nrm = scanned
        h = L.rmsnorm(x, nrm, cfg.rms_norm_eps)
        return x + block_forward(bp, cfg, h), None

    x, _ = jax.lax.scan(_remat(body, cfg), x,
                        (params["blocks"], params["block_norms"]))
    x = L.rmsnorm(x, params["final_norm"], cfg.rms_norm_eps)
    return unembed(params, cfg, x), jnp.zeros((), jnp.float32)


def cache_spec(cfg: ModelConfig, batch: int, max_seq: int):
    # attention-free: cache is the recurrent state (O(1) in sequence)
    return state_spec(cfg, cfg.num_layers, batch)


def cache_shapes(cfg, batch, max_seq):
    return L.shapes_tree(cache_spec(cfg, batch, max_seq))


def cache_axes(cfg, batch, max_seq):
    return L.axes_tree(cache_spec(cfg, batch, max_seq))


def init_cache(cfg, batch, max_seq):
    return L.init_tree(cache_spec(cfg, batch, max_seq), jax.random.PRNGKey(0))


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, fed=None):
    from repro.models.transformer import unembed
    x, new_state = decode_hidden(params, cfg, cache, tokens, pos, fed)
    return unembed(params, cfg, x), new_state


def decode_hidden(params, cfg: ModelConfig, cache, tokens, pos, fed=None):
    """``fed`` [B] bool: lanes not fed a real token this call keep their
    recurrent state bit-frozen — the state is a running reduction, so a
    batched prefill of one lane must not advance the others (the paged
    path's ``masked_state`` discipline, ported to the slot path)."""
    from repro.models.transformer import embed_tokens
    x = embed_tokens(params, cfg, tokens)

    def body(x, scanned):
        bp, nrm, st = scanned
        h = L.rmsnorm(x, nrm, cfg.rms_norm_eps)
        y, new_st = block_decode(bp, cfg, st, h)
        if fed is not None:
            new_st = masked_state(fed, new_st, st)
        return x + y, new_st

    x, new_state = jax.lax.scan(
        body, x, (params["blocks"], params["block_norms"], cache))
    x = L.rmsnorm(x, params["final_norm"], cfg.rms_norm_eps)
    return x, new_state


def loss_fn(params, cfg: ModelConfig, batch):
    logits, aux = forward(params, cfg, batch["tokens"])
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(lse - gold)
    return nll + aux, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# Paged decode: O(1) recurrent state has no sequence blocks to page —
# the "paged" cache is simply per-lane state.  What the continuous-
# batching engine DOES need from an SSM family is fed-masking: the
# recurrent state is a running reduction, so a lane that is not fed a
# real token this call (idle, or mid-prefill of a different lane) must
# keep its state bit-frozen — attention caches survive garbage at the
# next write position because it is overwritten before the mask exposes
# it, but an SSM update is irreversible.
# ---------------------------------------------------------------------------

PAGED_HAS_BLOCKS = False    # O(1) state: no per-position pool blocks


def paged_cache_spec(cfg: ModelConfig, lanes: int, num_blocks: int,
                     block_size: int):
    return state_spec(cfg, cfg.num_layers, lanes)


def init_paged_cache(cfg: ModelConfig, lanes: int, num_blocks: int,
                     block_size: int):
    return L.init_tree(paged_cache_spec(cfg, lanes, num_blocks, block_size),
                       jax.random.PRNGKey(0))


def reset_paged_lane(cfg: ModelConfig, cache, lane_index: int):
    """Zero one lane's recurrent state (leaves are [NL, lanes, ...]):
    unlike KV blocks, state is never overwritten-before-read, so a
    recycled lane would otherwise leak its previous occupant's state."""
    return jax.tree.map(lambda a: a.at[:, lane_index].set(0), cache)


def reset_cache_lane(cfg: ModelConfig, cache, lane_index: int):
    """Slot-cache lane reset: the slot cache IS the state tree (leaves
    [NL, B, ...]), so a recycled slot must be zeroed exactly like a
    recycled paged lane."""
    return reset_paged_lane(cfg, cache, lane_index)


def masked_state(fed, new_state, old_state):
    """Per-lane select: advanced state where ``fed`` [B], frozen
    elsewhere."""
    def sel(new, old):
        m = fed.reshape((fed.shape[0],) + (1,) * (new.ndim - 1))
        return jnp.where(m, new, old)
    return jax.tree.map(sel, new_state, old_state)


def decode_step_paged(params, cfg: ModelConfig, cache, tokens, pos, tables,
                      fed=None):
    from repro.models.transformer import unembed
    x, new_state = decode_hidden_paged(params, cfg, cache, tokens, pos,
                                       tables, fed)
    return unembed(params, cfg, x), new_state


def decode_hidden_paged(params, cfg: ModelConfig, cache, tokens, pos, tables,
                        fed=None):
    from repro.models.transformer import embed_tokens
    x = embed_tokens(params, cfg, tokens)

    def body(x, scanned):
        bp, nrm, st = scanned
        h = L.rmsnorm(x, nrm, cfg.rms_norm_eps)
        y, new_st = block_decode(bp, cfg, st, h)
        if fed is not None:
            new_st = masked_state(fed, new_st, st)
        return x + y, new_st

    x, new_state = jax.lax.scan(
        body, x, (params["blocks"], params["block_norms"], cache))
    x = L.rmsnorm(x, params["final_norm"], cfg.rms_norm_eps)
    return x, new_state
