"""Uniform model API across families + analytical parameter/FLOP counts."""
from __future__ import annotations

import math
from types import ModuleType

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def module_for(cfg: ModelConfig) -> ModuleType:
    from repro.models import transformer, mamba, hybrid, encdec
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer
    if cfg.family == "ssm":
        return mamba
    if cfg.family == "hybrid":
        return hybrid
    if cfg.family == "audio":
        return encdec
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Uniform entry points
# ---------------------------------------------------------------------------

def loss_fn(params, cfg, batch):
    from repro.models.layers import training_mode
    with training_mode():
        return module_for(cfg).loss_fn(params, cfg, batch)


def forward(params, cfg, batch):
    m = module_for(cfg)
    if cfg.is_encoder_decoder:
        return m.forward(params, cfg, batch["tokens"], batch["encoder_embeds"])
    if cfg.frontend_stub == "vision" and "vision_embeds" in batch:
        return m.forward(params, cfg, batch["tokens"],
                         vision_embeds=batch["vision_embeds"])
    return m.forward(params, cfg, batch["tokens"])


def decode_step(params, cfg, cache, tokens, pos, fed=None):
    """``fed`` [B] bool (optional): lanes not fed a real token this call
    — SSM families freeze their recurrent state (``masked_state``);
    attention-only families ignore it (their KV writes are safe)."""
    return module_for(cfg).decode_step(params, cfg, cache, tokens, pos, fed)


def decode_hidden(params, cfg, cache, tokens, pos, fed=None):
    """Decode up to the final norm (no unembed) — the split point for
    vocab-parallel serving.  Raises for families whose decode step does
    not factor this way (encoder-decoder has a bespoke unembed)."""
    m = module_for(cfg)
    if not hasattr(m, "decode_hidden"):
        raise NotImplementedError(
            f"decode_hidden not supported for family {cfg.family!r}")
    return m.decode_hidden(params, cfg, cache, tokens, pos, fed)


def reset_cache_lane(cfg, cache, lane_index):
    """Zero one lane's per-lane recurrent state in the SLOT cache (SSM
    families) — a recycled slot must not leak its previous occupant's
    state.  No-op for attention-only families (KV rows are
    position-indexed and overwritten before the causal mask exposes
    them)."""
    m = module_for(cfg)
    if hasattr(m, "reset_cache_lane"):
        return m.reset_cache_lane(cfg, cache, lane_index)
    return cache


def unembed_partial(params, cfg, x, vocab_start, vocab_len):
    """Vocab-parallel unembed slice (see transformer.unembed_partial)."""
    from repro.models import transformer
    return transformer.unembed_partial(params, cfg, x, vocab_start,
                                       vocab_len)


def init_params(cfg, rng):
    return module_for(cfg).init_params(cfg, rng)


def param_axes(cfg):
    return module_for(cfg).param_axes(cfg)


def param_shapes(cfg):
    return module_for(cfg).param_shapes(cfg)


def cache_shapes(cfg, batch, max_seq):
    return module_for(cfg).cache_shapes(cfg, batch, max_seq)


def cache_axes(cfg, batch, max_seq):
    return module_for(cfg).cache_axes(cfg, batch, max_seq)


def init_cache(cfg, batch, max_seq):
    return module_for(cfg).init_cache(cfg, batch, max_seq)


# ---------------------------------------------------------------------------
# Paged decode (block-table-indexed KV cache; see serve/kvcache.py)
# ---------------------------------------------------------------------------

def supports_paged(cfg: ModelConfig) -> bool:
    """True iff the family implements the paged decode entry points."""
    return hasattr(module_for(cfg), "decode_step_paged")


def paged_has_blocks(cfg: ModelConfig) -> bool:
    """True iff the paged cache actually pages KV by position (attention
    families).  SSM families keep lane-indexed recurrent state instead —
    the block allocator is bypassed but the lane/fed machinery applies."""
    return bool(getattr(module_for(cfg), "PAGED_HAS_BLOCKS", False))


def init_paged_cache(cfg, lanes, num_blocks, block_size):
    m = module_for(cfg)
    if not hasattr(m, "init_paged_cache"):
        raise NotImplementedError(
            f"paged decode not supported for family {cfg.family!r}")
    return m.init_paged_cache(cfg, lanes, num_blocks, block_size)


def decode_step_paged(params, cfg, cache, tokens, pos, tables, fed=None):
    return module_for(cfg).decode_step_paged(params, cfg, cache, tokens,
                                             pos, tables, fed)


def decode_hidden_paged(params, cfg, cache, tokens, pos, tables, fed=None):
    m = module_for(cfg)
    if not hasattr(m, "decode_hidden_paged"):
        raise NotImplementedError(
            f"decode_hidden_paged not supported for family {cfg.family!r}")
    return m.decode_hidden_paged(params, cfg, cache, tokens, pos, tables, fed)


def reset_paged_lane(cfg, cache, lane_index):
    return module_for(cfg).reset_paged_lane(cfg, cache, lane_index)


# ---------------------------------------------------------------------------
# Analytical counts (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------

def _spec_leaves_with_path(cfg):
    from repro.models import layers as L
    spec = module_for(cfg).param_spec(cfg)
    flat = jax.tree_util.tree_flatten_with_path(
        spec, is_leaf=lambda x: isinstance(x, L.PSpec))[0]
    return [("/".join(str(getattr(k, "key", k)) for k in path), leaf)
            for path, leaf in flat]


def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    total = 0
    for path, leaf in _spec_leaves_with_path(cfg):
        n = int(np.prod(leaf.shape))
        if active_only and cfg.moe is not None and "/moe/" in f"/{path}/" \
                and "router" not in path:
            n = int(n * cfg.moe.top_k / cfg.moe.num_experts)
        total += n
    return total


def non_embedding_param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    total = 0
    for path, leaf in _spec_leaves_with_path(cfg):
        if "embed" in path.split("/")[-1] or "lm_head" in path:
            continue
        n = int(np.prod(leaf.shape))
        if active_only and cfg.moe is not None and "/moe/" in f"/{path}/" \
                and "router" not in path:
            n = int(n * cfg.moe.top_k / cfg.moe.num_experts)
        total += n
    return total


def _encoder_param_count(cfg: ModelConfig) -> int:
    total = 0
    for path, leaf in _spec_leaves_with_path(cfg):
        if path.startswith("encoder/") or "/encoder/" in path:
            total += int(np.prod(leaf.shape))
    return total


def model_flops(cfg: ModelConfig, tokens: int, *, training: bool,
                include_attention: bool = True, seq_len: int = 0,
                decode_cache_len: int = 0) -> float:
    """Canonical 6·N·D (train) / 2·N·D (inference) + attention term.

    N counts *active* parameters (MoE); attention adds the 12·L·d·S
    (kernel) term when seq_len is given. For decode, the attention term
    uses the cache length per produced token.
    """
    n_active = param_count(cfg, active_only=True)
    mult = 6.0 if training else 2.0
    if cfg.is_encoder_decoder and seq_len:
        # encoder params run over `encoder_frames` tokens, not seq_len
        enc = _encoder_param_count(cfg)
        batch = tokens / max(seq_len, 1)
        flops = mult * ((n_active - enc) * tokens
                        + enc * batch * cfg.encoder_frames)
    else:
        flops = mult * n_active * tokens
    if include_attention:
        hd = cfg.resolved_head_dim() if cfg.num_heads else 0
        att_layers = cfg.num_layers + cfg.num_encoder_layers
        if cfg.family == "hybrid":
            att_layers = cfg.num_layers // max(cfg.shared_attn_every, 1)
        if cfg.num_heads and seq_len:
            batch = tokens / max(seq_len, 1)
            k = 3.0 if training else 1.0
            if cfg.is_encoder_decoder:
                F = cfg.encoder_frames
                dec = (2 * 2 * seq_len * seq_len / 2      # causal self
                       + 2 * 2 * seq_len * F)             # cross
                enc = 2 * 2 * F * F
                flops += k * batch * cfg.num_heads * hd * (
                    cfg.num_layers * dec + cfg.num_encoder_layers * enc)
            else:
                # 2·S²·H·hd (scores) + same (values), causal halves it
                per_layer = 2 * 2 * seq_len * seq_len * cfg.num_heads * hd / 2
                flops += k * batch * att_layers * per_layer
        if cfg.num_heads and decode_cache_len:
            per_tok = 2 * 2 * decode_cache_len * cfg.num_heads * hd
            flops += tokens * att_layers * per_tok
        if cfg.ssm is not None and seq_len:
            from repro.models import mamba as M
            d_inner, nh, hp, ds = M.dims(cfg)
            Q = cfg.ssm.chunk_size
            ssm_layers = cfg.num_layers if cfg.family != "hybrid" else cfg.num_layers
            # intra-chunk (2·Q·nh·hp per token) + state/out (4·nh·hp·ds)
            per_tok = 2 * Q * nh * hp + 4 * nh * hp * ds
            flops += (3.0 if training else 1.0) * tokens * ssm_layers * per_tok
    return float(flops)
