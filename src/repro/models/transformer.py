"""Decoder-only transformer LM (dense GQA / MoE / VLM backbone).

Covers: qwen2-0.5b, qwen2.5-3b, smollm-360m, llama3-405b,
granite-moe-3b-a800m, grok-1-314b, pixtral-12b.

Layers are stacked on a leading ``layers`` axis and executed with
``jax.lax.scan`` (keeps the HLO — and therefore compile time at 512
devices — independent of depth).  ``cfg.remat_policy`` wraps the scanned
body in ``jax.checkpoint``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding import shard_hint

VISION_PATCHES = 1024  # stub vision frontend: one 1024-patch image / seq


# ---------------------------------------------------------------------------
# Parameter spec
# ---------------------------------------------------------------------------

def param_spec(cfg: ModelConfig):
    D, V, NL = cfg.d_model, cfg.vocab_size, cfg.num_layers
    layer = {
        "attn": L.attn_spec(cfg, layers=NL),
        "ln1": L.PSpec((NL, D), ("layers", "embed_nofsdp"), init="ones"),
        "ln2": L.PSpec((NL, D), ("layers", "embed_nofsdp"), init="ones"),
    }
    if cfg.moe is not None:
        layer["moe"] = L.moe_spec(cfg, layers=NL)
    else:
        layer["mlp"] = L.mlp_spec(cfg, layers=NL)
    spec = {
        "embed": L.PSpec((V, D), ("vocab", "embed"), init="embed"),
        "layers": layer,
        "final_norm": L.PSpec((D,), ("embed_nofsdp",), init="ones"),
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = L.PSpec((D, V), ("embed", "vocab"), fan_in=D)
    return spec


def init_params(cfg: ModelConfig, rng: jax.Array):
    return L.init_tree(param_spec(cfg), rng, jnp.dtype(cfg.param_dtype))


def param_axes(cfg: ModelConfig):
    return L.axes_tree(param_spec(cfg))


def param_shapes(cfg: ModelConfig):
    return L.shapes_tree(param_spec(cfg), jnp.dtype(cfg.param_dtype))


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _remat(fn, cfg: ModelConfig):
    if cfg.remat_policy in ("none", "subblock", "attn_only"):
        return fn          # sub-layer policies checkpoint inside the layer
    if cfg.remat_policy == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def _layer_fwd(cfg: ModelConfig, x, lp, positions):
    if cfg.remat_policy == "subblock":
        return _layer_fwd_subblock(cfg, x, lp, positions)
    h = L.rmsnorm(x, lp["ln1"], cfg.rms_norm_eps)
    q, k, v = L.attn_qkv(lp["attn"], h, positions, cfg)
    if cfg.remat_policy == "attn_only":
        # recompute ONLY the attention internals in backward: everything
        # else (projections, MLP) keeps its residuals — removes the full
        # forward recompute at ~3GB/device of extra saved activations.
        attn_fn = jax.checkpoint(
            lambda q_, k_, v_: L.attention_dispatch(cfg, q_, k_, v_, causal=True))
        o = attn_fn(q, k, v)
    else:
        o = L.attention_dispatch(cfg, q, k, v, causal=True)
    x = x + L.attn_out(lp["attn"], o)
    h = L.rmsnorm(x, lp["ln2"], cfg.rms_norm_eps)
    if cfg.moe is not None:
        y, aux = L.moe_apply(lp["moe"], h, cfg)
    else:
        y, aux = L.mlp_apply(lp["mlp"], h), jnp.zeros((), jnp.float32)
    x = x + y
    x = shard_hint(x, "batch", "act_seq", "act_embed")
    return x, aux


def _layer_fwd_subblock(cfg: ModelConfig, x, lp, positions):
    """Remat the projection/MLP sub-blocks but NOT the attention op, so a
    custom_vjp ring attention keeps its residuals and its forward ring is
    not replayed during backward (the whole-layer checkpoint would re-run
    it, doubling collective-permute traffic)."""
    def qkv_fn(x_, lp_):
        h = L.rmsnorm(x_, lp_["ln1"], cfg.rms_norm_eps)
        return L.attn_qkv(lp_["attn"], h, positions, cfg)

    q, k, v = jax.checkpoint(qkv_fn)(x, lp)
    o = L.attention_dispatch(cfg, q, k, v, causal=True)

    def rest_fn(x_, o_, lp_):
        x_ = x_ + L.attn_out(lp_["attn"], o_)
        h = L.rmsnorm(x_, lp_["ln2"], cfg.rms_norm_eps)
        if cfg.moe is not None:
            y, aux = L.moe_apply(lp_["moe"], h, cfg)
        else:
            y, aux = L.mlp_apply(lp_["mlp"], h), jnp.zeros((), jnp.float32)
        x_ = x_ + y
        return shard_hint(x_, "batch", "act_seq", "act_embed"), aux

    return jax.checkpoint(rest_fn)(x, o, lp)


def embed_tokens(params, cfg: ModelConfig, tokens, vision_embeds=None):
    dt = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(dt), x], axis=1)
    return shard_hint(x, "batch", "act_seq", "act_embed")


def forward_hidden(params, cfg: ModelConfig, tokens, vision_embeds=None):
    """tokens [B, S_text] -> (final normed hidden [B,S,D], aux_loss)."""
    x = embed_tokens(params, cfg, tokens, vision_embeds)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]

    body = _remat(lambda carry, lp: _scan_body(cfg, carry, lp, positions), cfg)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    return L.rmsnorm(x, params["final_norm"], cfg.rms_norm_eps), aux


def forward(params, cfg: ModelConfig, tokens, vision_embeds=None):
    """tokens [B, S_text] -> (logits [B, S, V], aux_loss)."""
    x, aux = forward_hidden(params, cfg, tokens, vision_embeds)
    return unembed(params, cfg, x), aux


def _scan_body(cfg, carry, lp, positions):
    x, aux = carry
    x, a = _layer_fwd(cfg, x, lp, positions)
    return (x, aux + a), None


def unembed(params, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    logits = L.softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return shard_hint(logits, "batch", "act_seq", "act_vocab")


def unembed_partial(params, cfg: ModelConfig, x, vocab_start, vocab_len: int):
    """Vocab-parallel unembed: logits for ``vocab_len`` vocabulary rows
    starting at (traced) ``vocab_start`` — the tensor-parallel output
    projection.  Inside a model-axis ``shard_map`` each rank computes its
    slice and the full logits are the rank-order concatenation (gathered
    natively in-program, or by a user-space all-gather on the serve
    collective stream).  Softcap is elementwise, so slicing before it is
    exact."""
    if cfg.tie_embeddings:
        w = jax.lax.dynamic_slice_in_dim(params["embed"], vocab_start,
                                         vocab_len, axis=0)
        logits = jnp.einsum("bsd,vd->bsv", x, w.astype(x.dtype))
    else:
        w = jax.lax.dynamic_slice_in_dim(params["lm_head"], vocab_start,
                                         vocab_len, axis=1)
        logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    return L.softcap(logits.astype(jnp.float32), cfg.logit_softcap)


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------

def cache_spec(cfg: ModelConfig, batch: int, max_seq: int):
    NL, KVH = cfg.num_layers, cfg.num_kv_heads
    hd = cfg.resolved_head_dim()
    axes = ("layers", "cache_batch", "cache_seq", "act_kv_heads", "head_dim")
    shape = (NL, batch, max_seq, KVH, hd)
    if cfg.kv_cache_dtype == "int8":
        s_axes = ("layers", "cache_batch", "cache_seq", "act_kv_heads", None)
        s_shape = (NL, batch, max_seq, KVH, 1)
        return {
            "k": L.PSpec(shape, axes, init="zeros", dtype=jnp.int8),
            "v": L.PSpec(shape, axes, init="zeros", dtype=jnp.int8),
            "k_scale": L.PSpec(s_shape, s_axes, init="zeros", dtype=jnp.float32),
            "v_scale": L.PSpec(s_shape, s_axes, init="zeros", dtype=jnp.float32),
        }
    return {
        "k": L.PSpec(shape, axes, init="zeros", dtype=jnp.dtype(cfg.dtype)),
        "v": L.PSpec(shape, axes, init="zeros", dtype=jnp.dtype(cfg.dtype)),
    }


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    return L.init_tree(cache_spec(cfg, batch, max_seq), jax.random.PRNGKey(0))


def cache_axes(cfg: ModelConfig, batch: int, max_seq: int):
    return L.axes_tree(cache_spec(cfg, batch, max_seq))


def cache_shapes(cfg: ModelConfig, batch: int, max_seq: int):
    return L.shapes_tree(cache_spec(cfg, batch, max_seq))


def _quantize_kv(t):
    """t: [B,KVH,hd] -> (int8 [B,KVH,hd], f32 scale [B,KVH,1])."""
    tf = t.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(tf), axis=-1, keepdims=True) / 127.0,
                        1e-12)
    q = jnp.clip(jnp.round(tf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _layer_decode(cfg: ModelConfig, x, lp, kc, vc, pos, ks=None, vs=None):
    """One decoded token through one layer. x: [B,1,D]; kc/vc: [B,S,KVH,hd]
    (int8 with ks/vs scales when cfg.kv_cache_dtype == "int8")."""
    B = x.shape[0]
    h = L.rmsnorm(x, lp["ln1"], cfg.rms_norm_eps)
    q, k_new, v_new = L.attn_qkv(lp["attn"], h, pos[:, None], cfg)
    if ks is not None:
        kq, ksc = _quantize_kv(k_new[:, 0])
        vq, vsc = _quantize_kv(v_new[:, 0])
        kc = kc.at[jnp.arange(B), pos].set(kq)
        vc = vc.at[jnp.arange(B), pos].set(vq)
        ks = ks.at[jnp.arange(B), pos].set(ksc)
        vs = vs.at[jnp.arange(B), pos].set(vsc)
        # dequant fuses into the attention matmul: int8 bytes cross HBM
        k_use = (kc.astype(jnp.float32) * ks).astype(cfg.dtype)
        v_use = (vc.astype(jnp.float32) * vs).astype(cfg.dtype)
    else:
        kc = kc.at[jnp.arange(B), pos].set(k_new[:, 0])
        vc = vc.at[jnp.arange(B), pos].set(v_new[:, 0])
        k_use, v_use = kc, vc
    o = L.decode_attention(q, k_use, v_use, pos, logit_cap=cfg.logit_softcap)
    x = x + L.attn_out(lp["attn"], o)
    h = L.rmsnorm(x, lp["ln2"], cfg.rms_norm_eps)
    if cfg.moe is not None:
        y, _ = L.moe_apply(lp["moe"], h, cfg)
    else:
        y = L.mlp_apply(lp["mlp"], h)
    return x + y, kc, vc, ks, vs


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, fed=None):
    """tokens [B,1], pos [B] -> (logits [B,1,V], updated cache).

    ``fed`` is accepted for API uniformity with the SSM families and
    ignored: attention KV writes land at each lane's own ``pos`` and are
    overwritten before the causal mask can expose them, so a non-fed
    lane's cache row is already safe without masking."""
    x, new_cache = decode_hidden(params, cfg, cache, tokens, pos, fed)
    return unembed(params, cfg, x), new_cache


def decode_hidden(params, cfg: ModelConfig, cache, tokens, pos, fed=None):
    """Decode step up to (and including) the final norm: tokens [B,1],
    pos [B] -> (hidden [B,1,D], updated cache).  The unembed is split
    out so vocab-parallel serving can project per-rank slices.
    ``fed`` is ignored (see ``decode_step``)."""
    del fed
    x = embed_tokens(params, cfg, tokens)
    int8 = cfg.kv_cache_dtype == "int8"

    def body(x, scanned):
        if int8:
            lp, kc, vc, ks, vs = scanned
        else:
            lp, kc, vc = scanned
            ks = vs = None
        x, kc, vc, ks, vs = _layer_decode(cfg, x, lp, kc, vc, pos, ks, vs)
        return x, ((kc, vc, ks, vs) if int8 else (kc, vc))

    if int8:
        x, (k_new, v_new, ks_new, vs_new) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"],
                      cache["k_scale"], cache["v_scale"]))
        new_cache = {"k": k_new, "v": v_new,
                     "k_scale": ks_new, "v_scale": vs_new}
    else:
        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": k_new, "v": v_new}
    x = L.rmsnorm(x, params["final_norm"], cfg.rms_norm_eps)
    return x, new_cache


# ---------------------------------------------------------------------------
# Paged KV cache + decode (block-table-indexed attention)
# ---------------------------------------------------------------------------
#
# The paged layout replaces the per-slot [B, max_seq] cache rows with a
# shared pool of fixed-size blocks [num_blocks, block_size]; each decode
# lane carries a block *table* [max_blocks] of physical pool indices.
# Per step, the new token's K/V is scattered into (table[pos//bs],
# pos%bs) and attention runs over the table-gathered view
# [B, max_blocks*block_size, KVH, hd] through the SAME masked
# decode_attention as the monolithic path — positions > pos are masked
# to exact zeros, so stale bytes in recycled blocks (and the shared
# scratch block 0 behind unallocated table entries) are unreachable and
# the gathered view is value-identical to a monolithic cache row.

PAGED_HAS_BLOCKS = True     # per-position KV: sequences occupy pool blocks


def paged_cache_spec(cfg: ModelConfig, lanes: int, num_blocks: int,
                     block_size: int):
    NL, KVH = cfg.num_layers, cfg.num_kv_heads
    hd = cfg.resolved_head_dim()
    axes = ("layers", None, "cache_seq", "act_kv_heads", "head_dim")
    shape = (NL, num_blocks, block_size, KVH, hd)
    if cfg.kv_cache_dtype == "int8":
        s_axes = ("layers", None, "cache_seq", "act_kv_heads", None)
        s_shape = (NL, num_blocks, block_size, KVH, 1)
        return {
            "k": L.PSpec(shape, axes, init="zeros", dtype=jnp.int8),
            "v": L.PSpec(shape, axes, init="zeros", dtype=jnp.int8),
            "k_scale": L.PSpec(s_shape, s_axes, init="zeros", dtype=jnp.float32),
            "v_scale": L.PSpec(s_shape, s_axes, init="zeros", dtype=jnp.float32),
        }
    return {
        "k": L.PSpec(shape, axes, init="zeros", dtype=jnp.dtype(cfg.dtype)),
        "v": L.PSpec(shape, axes, init="zeros", dtype=jnp.dtype(cfg.dtype)),
    }


def init_paged_cache(cfg: ModelConfig, lanes: int, num_blocks: int,
                     block_size: int):
    return L.init_tree(paged_cache_spec(cfg, lanes, num_blocks, block_size),
                       jax.random.PRNGKey(0))


def reset_paged_lane(cfg: ModelConfig, cache, lane_index: int):
    # nothing lane-indexed to clear: blocks are scatter-overwritten
    # before the masked attention can reach them
    return cache


def paged_scatter(kc, vc, k_new, v_new, tables, pos):
    """Scatter one token's K/V [B, KVH, hd] into the pool at
    (table[pos//bs], pos%bs).  Lanes whose table entry is the scratch
    block (idle lanes) land at physical block 0 — never gathered by a
    live table, so the duplicate writes are harmless."""
    B = k_new.shape[0]
    bs = kc.shape[1]
    phys = tables[jnp.arange(B), pos // bs]
    off = pos % bs
    return kc.at[phys, off].set(k_new), vc.at[phys, off].set(v_new)


def _paged_view(pool, tables):
    """Gather [num_blocks, bs, ...] through tables [B, max_blocks] into
    the per-lane contiguous view [B, max_blocks*bs, ...]."""
    B, nb = tables.shape
    v = pool[tables]
    return v.reshape((B, nb * v.shape[2]) + v.shape[3:])


def _layer_decode_paged(cfg: ModelConfig, x, lp, kc, vc, pos, tables,
                        ks=None, vs=None):
    """One decoded token through one layer against the paged pool.
    x: [B,1,D]; kc/vc: [num_blocks, bs, KVH, hd] (int8 with ks/vs scale
    pools when cfg.kv_cache_dtype == "int8"); tables: [B, max_blocks]."""
    h = L.rmsnorm(x, lp["ln1"], cfg.rms_norm_eps)
    q, k_new, v_new = L.attn_qkv(lp["attn"], h, pos[:, None], cfg)
    if ks is not None:
        kq, ksc = _quantize_kv(k_new[:, 0])
        vq, vsc = _quantize_kv(v_new[:, 0])
        kc, vc = paged_scatter(kc, vc, kq, vq, tables, pos)
        ks, vs = paged_scatter(ks, vs, ksc, vsc, tables, pos)
        k_use = (_paged_view(kc, tables).astype(jnp.float32)
                 * _paged_view(ks, tables)).astype(cfg.dtype)
        v_use = (_paged_view(vc, tables).astype(jnp.float32)
                 * _paged_view(vs, tables)).astype(cfg.dtype)
    else:
        kc, vc = paged_scatter(kc, vc, k_new[:, 0], v_new[:, 0], tables, pos)
        k_use = _paged_view(kc, tables)
        v_use = _paged_view(vc, tables)
    o = L.decode_attention(q, k_use, v_use, pos, logit_cap=cfg.logit_softcap)
    x = x + L.attn_out(lp["attn"], o)
    h = L.rmsnorm(x, lp["ln2"], cfg.rms_norm_eps)
    if cfg.moe is not None:
        y, _ = L.moe_apply(lp["moe"], h, cfg)
    else:
        y = L.mlp_apply(lp["mlp"], h)
    return x + y, kc, vc, ks, vs


def decode_step_paged(params, cfg: ModelConfig, cache, tokens, pos, tables,
                      fed=None):
    """tokens [B,1], pos [B], tables [B,max_blocks] -> (logits [B,1,V],
    updated pool cache).  ``fed`` ([B] bool, which lanes carry a real
    token this call) is unused here: attention KV at a non-fed lane's
    next-write position is overwritten by its next real token before the
    mask ever exposes it."""
    x, new_cache = decode_hidden_paged(params, cfg, cache, tokens, pos,
                                       tables, fed)
    return unembed(params, cfg, x), new_cache


def decode_hidden_paged(params, cfg: ModelConfig, cache, tokens, pos, tables,
                        fed=None):
    """Paged decode step up to (and including) the final norm."""
    x = embed_tokens(params, cfg, tokens)
    int8 = cfg.kv_cache_dtype == "int8"

    def body(x, scanned):
        if int8:
            lp, kc, vc, ks, vs = scanned
        else:
            lp, kc, vc = scanned
            ks = vs = None
        x, kc, vc, ks, vs = _layer_decode_paged(cfg, x, lp, kc, vc, pos,
                                                tables, ks, vs)
        return x, ((kc, vc, ks, vs) if int8 else (kc, vc))

    if int8:
        x, (k_new, v_new, ks_new, vs_new) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"],
                      cache["k_scale"], cache["v_scale"]))
        new_cache = {"k": k_new, "v": v_new,
                     "k_scale": ks_new, "v_scale": vs_new}
    else:
        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": k_new, "v": v_new}
    x = L.rmsnorm(x, params["final_norm"], cfg.rms_norm_eps)
    return x, new_cache


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def loss_fn(params, cfg: ModelConfig, batch):
    labels = batch["labels"]
    if cfg.loss_impl == "chunked_vocab" and not cfg.logit_softcap:
        from repro.train.losses import chunked_vocab_xent
        x, aux = forward_hidden(params, cfg, batch["tokens"],
                                vision_embeds=batch.get("vision_embeds"))
        if x.shape[1] != labels.shape[1]:        # VLM: loss on text positions
            x = x[:, -labels.shape[1]:]
        if cfg.tie_embeddings:
            nll = chunked_vocab_xent(x, params["embed"], labels,
                                     cfg.loss_vocab_chunk, False)
        else:
            nll = chunked_vocab_xent(x, params["lm_head"], labels,
                                     cfg.loss_vocab_chunk, True)
        return nll + aux, {"nll": nll, "aux": aux}
    logits, aux = forward(params, cfg, batch["tokens"],
                          vision_embeds=batch.get("vision_embeds"))
    if logits.shape[1] != labels.shape[1]:       # VLM: loss on text positions
        logits = logits[:, -labels.shape[1]:]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(lse - gold)
    return nll + aux, {"nll": nll, "aux": aux}
