"""Event-driven continuous-batching server on the progress engine.

Serving is the dynamic side of the paper's story: requests arrive at
arbitrary times (the "unexpected message queue" of MPI has no SPMD
analogue — this layer is it).  Since the continuation layer
(repro.core.continuations) landed, the whole request lifecycle is
completion-driven — there is no polling loop anywhere in this file:

* ``submit``            — the *arrival event* schedules a one-shot
  admission task on the admit stream (none is scheduled while idle);
* admission / prefill   — admits arrivals into free KV slots and runs
  token-by-token prefill, then schedules the first decode step;
* decode                — one fused decode step for ALL active slots
  (continuous batching) is dispatched and its device completion watched
  by a one-shot readiness task (``Array.is_ready``, never blocked on)
  that completes a per-step ``Request``;
* detokenize            — a continuation attached to the step request:
  extracts tokens, finishes requests (their ``done_req`` completes,
  firing any client continuations), and *chains the next decode step* —
  each stage's completion schedules the next;
* slot-free event       — finishing requests re-schedules admission, so
  a backlog drains exactly when capacity appears.

Between requests every serve stream is empty: no perpetual task spins,
no idle polling — the paper's event-driven integration claim (§4.6).

The continuation execution policy is a knob (``continuation_policy``):
``INLINE`` runs detokenize on the progress thread that observed decode
completion; ``DEFERRED`` (default) queues it and the owner drains with
``continuation_max_drain`` as bounded backpressure.  With a
``ProgressExecutor`` the serve streams are adopted by its workers and a
deferred queue is drained by them between polls; without one, a cheap
subsystem bridges streams + continuation drain into every
``engine.progress()`` call, so the classic ``while: engine.progress()``
loop still serves traffic.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DEFERRED, DONE, NOPROGRESS, ProgressEngine, Request
from repro.core.continuations import POLICIES, ContinuationQueue
from repro.core.executor import ProgressExecutor
from repro.models import registry
from repro.serve.kvcache import SlotCache


@dataclasses.dataclass
class GenRequest:
    request_id: str
    prompt: np.ndarray               # [prompt_len] int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done_req: Request = dataclasses.field(default_factory=Request)
    slot_index: int = -1
    next_input: int = 0            # next token to feed the fused decode
    submitted_at: float = dataclasses.field(default_factory=time.monotonic)
    first_token_at: float | None = None
    finished_at: float | None = None


class ServeEngine:
    def __init__(self, cfg, params, engine: ProgressEngine,
                 batch_slots: int = 8, max_seq: int = 512,
                 greedy: bool = True,
                 executor: Optional[ProgressExecutor] = None,
                 continuation_policy: str = DEFERRED,
                 continuation_max_drain: int = 64):
        if continuation_policy not in POLICIES:
            raise ValueError(f"continuation_policy must be one of {POLICIES}")
        self.cfg = cfg
        self.params = params
        self.engine = engine
        self.executor = executor
        self.slots = SlotCache(cfg, batch_slots, max_seq)
        self.batch_slots = batch_slots
        self.max_seq = max_seq
        self._arrivals: collections.deque[GenRequest] = collections.deque()
        self._active: dict[int, GenRequest] = {}
        # one lock serialises admission/prefill against detokenize: the
        # stages may run on different executor workers, but KV cache and
        # slot state are shared
        self._lock = threading.Lock()
        self._decode_inflight = None
        self._current_step = None      # the step whose continuation owns state
        self._admit_scheduled = False
        self._stopping = False
        self._closed = False
        self._jit_decode = jax.jit(
            lambda p, c, t, q: registry.decode_step(p, cfg, c, t, q))
        self.admit_stream = engine.stream("serve-admit")
        self.decode_stream = engine.stream("serve-decode")
        # decode completions are delivered through this queue; its
        # detection task lives on the decode stream so INLINE runs
        # detokenize right where completion was observed
        self.continuations = ContinuationQueue(
            engine, self.decode_stream, policy=continuation_policy,
            name="serve-cont")
        self.continuation_max_drain = continuation_max_drain
        self._queue_adopted = False
        if executor is not None:
            executor.adopt(self.admit_stream)
            executor.adopt(self.decode_stream)
            if continuation_policy == DEFERRED:
                executor.adopt_queue(self.continuations)
                self._queue_adopted = True
            self._sub = None
        else:
            # no executor: bridge the serve streams (and the continuation
            # drain) into every engine.progress() call so single-threaded
            # callers still serve
            self._sub = engine.register_subsystem(
                "serve-streams", self._poll_streams, cheap=True, priority=4)
        self.steps = 0
        # bounded: transient device failures on a long-lived server must
        # not accumulate exception objects forever
        self.decode_errors: collections.deque[BaseException] = \
            collections.deque(maxlen=256)

    # -- client API -------------------------------------------------------
    def submit(self, request: GenRequest) -> Request:
        with self._lock:
            if self._stopping:
                raise RuntimeError("serve engine is stopping")
            self._arrivals.append(request)
        self._schedule_admit()               # the arrival event
        return request.done_req

    # -- caller-driven bridge ---------------------------------------------
    def _poll_streams(self) -> bool:
        made = 0
        for s in (self.admit_stream, self.decode_stream):
            try:
                made += s._poll_once()
            except Exception:
                # the broken task is already dropped and recorded on
                # s.task_errors; the bridge must NOT let the exception
                # escape, or the engine's isolation would unregister it
                # and silently halt all serving
                pass
        made += self.continuations.drain(self.continuation_max_drain)
        return made > 0

    # -- admission (event-scheduled, one-shot) ------------------------------
    def _schedule_admit(self) -> None:
        with self._lock:
            if self._admit_scheduled or not self._arrivals:
                return
            self._admit_scheduled = True
        self.engine.async_start(self._admit_task, None, self.admit_stream)

    def _admit_task(self, thing) -> str:
        with self._lock:
            self._admit_scheduled = False
        self._admit()
        self._schedule_decode()
        return DONE                          # one-shot: nothing left to poll

    def _admit(self) -> bool:
        with self._lock:
            # prefill mutates slots.cache, which the in-flight step's
            # continuation will overwrite with the step's output cache —
            # admitting mid-step would silently discard the prompt KV.
            # Defer: _on_step_done admits between steps instead.
            if self._decode_inflight is not None:
                return False
            return self._admit_locked()

    def _admit_locked(self) -> bool:
        """Admit arrivals into free slots; caller holds ``self._lock``
        and guarantees no decode step is in flight."""
        made = False
        while self._arrivals and self.slots.free_slots():
            req = self._arrivals.popleft()
            slot = self.slots.assign(req.request_id)
            req.slot_index = slot.index
            # sequential prefill: feed prompt tokens through decode
            # steps (token-by-token prefill keeps one compiled shape;
            # a chunked prefill path is the serving hillclimb)
            self._prefill(req, slot)
            self._active[slot.index] = req
            made = True
        return made

    def _prefill(self, req: GenRequest, slot) -> None:
        # writes the prompt into the slot's cache; last logits start decode
        cache = self.slots.cache
        for tok in req.prompt[:-1]:
            tokens = self._token_batch(slot.index, int(tok))
            pos = self.slots.positions()
            _, cache = self._jit_decode(self.params, cache, tokens, pos)
            slot.pos += 1
        self.slots.cache = cache
        req.out_tokens = []
        req.next_input = int(req.prompt[-1])

    def _token_batch(self, slot_index: int, token: int):
        toks = np.zeros((self.batch_slots, 1), np.int32)
        toks[slot_index, 0] = token
        return jnp.asarray(toks)

    # -- fused decode (continuation-chained steps) ---------------------------
    def _schedule_decode(self) -> None:
        with self._lock:
            if self._decode_inflight is not None or not self._active:
                return
            step = self._launch_decode_locked()
        self._attach_step(step)

    def _launch_decode_locked(self) -> Request:
        """Dispatch one fused decode step; caller holds ``self._lock``.

        Completion is watched by a one-shot readiness task on the decode
        stream that completes the returned ``step`` request — the only
        place the device is polled.  Dispatch failure fails the request
        instead of wedging the stream (the failure continuation cleans
        up).  The caller attaches the continuation AFTER releasing the
        lock: an already-failed step fires inline immediately, and that
        must not happen while the serve lock is held.
        """
        step = Request(tag="decode-step")
        self._current_step = step
        try:
            toks = np.zeros((self.batch_slots, 1), np.int32)
            for idx, req in self._active.items():
                toks[idx, 0] = req.next_input
            pos = self.slots.positions()
            logits, cache = self._jit_decode(
                self.params, self.slots.cache, jnp.asarray(toks), pos)
        except BaseException as exc:  # noqa: BLE001
            step.fail(exc)
            return step
        self._decode_inflight = (logits, cache)

        def ready_poll(thing, logits=logits, cache=cache, step=step) -> str:
            if not logits.is_ready():        # device still busy — no block
                return NOPROGRESS
            step.complete((logits, cache))
            return DONE

        self.engine.async_start(ready_poll, None, self.decode_stream)
        return step

    def _attach_step(self, step: Request) -> None:
        self.continuations.attach(step, self._on_step_done,
                                  on_error=self._on_step_failed)

    def _on_step_done(self, step: Request) -> None:
        """Detokenize stage (a continuation): harvest the fused step,
        finish/complete requests, and chain the next decode step."""
        logits, cache = step.value()
        try:
            # materialize OUTSIDE the lock: this is where async device
            # errors surface (not at dispatch) — a raise here must take
            # the failure path, not wedge the server with _active full
            # and no task on any stream
            next_ids = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        except BaseException as exc:  # noqa: BLE001
            self._fail_step(step, exc)
            return
        freed = False
        next_step = None
        with self._lock:
            if self._current_step is not step:
                return                         # stale: a newer step owns state
            self._current_step = None
            self._decode_inflight = None
            self.slots.cache = cache
            self.steps += 1
            finished = []
            for idx, req in list(self._active.items()):
                tok = int(next_ids[idx])
                if req.first_token_at is None:
                    req.first_token_at = time.monotonic()
                req.out_tokens.append(tok)
                req.next_input = tok
                self.slots.slots[idx].pos += 1
                if (len(req.out_tokens) >= req.max_new_tokens
                        or self.slots.slots[idx].pos >= self.max_seq - 1):
                    finished.append(idx)
            for idx in finished:
                req = self._active.pop(idx)
                req.finished_at = time.monotonic()
                self.slots.release(self.slots.slots[idx])
                req.done_req.complete(req.out_tokens)
                freed = True
            # admit between steps: arrivals that landed while this step
            # was in flight (their admission was deferred — prefill and
            # an in-flight step must not both write slots.cache) join
            # the batch before the next launch
            self._admit_locked()
            if self._active:
                next_step = self._launch_decode_locked()  # chain the next step
        if next_step is not None:
            self._attach_step(next_step)
        if freed:
            self._schedule_admit()             # the slot-free event

    def _on_step_failed(self, step: Request) -> None:
        """Failure continuation: a decode step that failed fails every
        in-flight request with the step's exception (propagated through
        ``Request.exception``) and frees their slots."""
        self._fail_step(step, step.exception)

    def _fail_step(self, step: Request, exc: BaseException) -> None:
        self.decode_errors.append(exc)
        with self._lock:
            if self._current_step is not step:
                # stale failure (a newer healthy step was launched before
                # this continuation drained): the requests already belong
                # to that step — touching state here would clobber it
                return
            self._current_step = None
            self._decode_inflight = None
            for idx, req in list(self._active.items()):
                self._active.pop(idx)
                req.finished_at = time.monotonic()
                self.slots.release(self.slots.slots[idx])
                req.done_req.fail(exc)
        self._schedule_admit()

    # -- lifecycle ------------------------------------------------------------
    @property
    def idle(self) -> bool:
        with self._lock:
            busy = (self._active or self._arrivals
                    or self._decode_inflight is not None)
        return not busy and self.continuations.ready == 0

    def run_until_idle(self, timeout: float = 120.0) -> None:
        """Serve until the backlog empties.  With an executor the worker
        threads do the progressing and this thread just waits; without one
        it is the classic caller-driven progress loop."""
        t0 = time.monotonic()
        while not self.idle:
            if self.executor is not None and self.executor.running:
                time.sleep(0.0005)
            elif self._sub is not None:
                # bridge polls the streams; pace out when nothing moved
                # (waiting on the device must not burn the core)
                if self.engine.progress() == 0:
                    time.sleep(50e-6)
            else:
                # executor attached but not running (never started, or
                # already shut down): drive the adopted streams inline so
                # waiting can never silently hang
                made = self._poll_streams()
                subs = self.engine.poll_subsystems()
                if not made and not subs:
                    time.sleep(50e-6)       # device wait: don't burn a core
            if time.monotonic() - t0 > timeout:
                raise TimeoutError("serve engine did not drain")

    def stop(self) -> None:
        """Begin shutdown: reject new submissions.  Already-submitted
        work keeps flowing (the event chain runs the backlog down); once
        it finishes no tasks remain, so drains terminate."""
        with self._lock:
            self._stopping = True

    def close(self, timeout: float = 60.0) -> None:
        """Stop, serve the backlog, then deterministically drain: both
        serve streams empty and every pending continuation executed
        (Listing 1.2 finalize, extended to the continuation layer).
        Idempotent: a second close (finally blocks, racing shutdown
        paths) is a no-op."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self.stop()
        self.run_until_idle(timeout=timeout)
        if self.executor is not None and self.executor.running:
            self.executor.drain(timeout)
        else:
            self.engine.drain(self.admit_stream, timeout=timeout)
            self.engine.drain(self.decode_stream, timeout=timeout)
        self.continuations.drain()             # anything still ready
        if self._queue_adopted:
            self.executor.release_queue(self.continuations)
            self._queue_adopted = False
        self.continuations.close()
        if self._sub is not None:
            self.engine.unregister_subsystem(self._sub)
            self._sub = None
        # hand the (drained) streams back to the engine: a process that
        # builds ServeEngines repeatedly must not grow the stream list
        for stream in (self.admit_stream, self.decode_stream):
            if self.executor is not None and self.executor.owns(stream):
                self.executor.release(stream)
            if not stream.pending:
                self.engine.free_stream(stream)
