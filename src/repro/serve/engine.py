"""Event-driven continuous-batching server on the progress engine.

Serving is the dynamic side of the paper's story: requests arrive at
arbitrary times (the "unexpected message queue" of MPI has no SPMD
analogue — this layer is it).  Everything is an async task on one
engine, split across two serial contexts (§4.4):

* admission stream   — perpetual task draining the arrival queue into
  free KV slots (prefill runs here, token-by-token);
* decode stream      — one fused decode step for ALL active slots per
  iteration (continuous batching), polled via ``Array.is_ready``,
  never blocked on;
* completion         — per-request ``Request`` handles; event callbacks
  compose via ``CompletionWatcher`` (paper §4.5).

Progress can be driven two ways: pass a ``ProgressExecutor`` and the
admission/decode streams are adopted by its worker threads (background
progress, §4.4); pass none and a cheap subsystem bridges both streams
into every ``engine.progress()`` call, so the classic
``while: engine.progress()`` loop — or a trainer's overlap window —
still serves traffic.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DONE, NOPROGRESS, ProgressEngine, Request
from repro.core.executor import ProgressExecutor
from repro.models import registry
from repro.serve.kvcache import SlotCache


@dataclasses.dataclass
class GenRequest:
    request_id: str
    prompt: np.ndarray               # [prompt_len] int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done_req: Request = dataclasses.field(default_factory=Request)
    slot_index: int = -1
    next_input: int = 0            # next token to feed the fused decode
    submitted_at: float = dataclasses.field(default_factory=time.monotonic)
    first_token_at: float | None = None
    finished_at: float | None = None


class ServeEngine:
    def __init__(self, cfg, params, engine: ProgressEngine,
                 batch_slots: int = 8, max_seq: int = 512,
                 greedy: bool = True,
                 executor: Optional[ProgressExecutor] = None):
        self.cfg = cfg
        self.params = params
        self.engine = engine
        self.executor = executor
        self.slots = SlotCache(cfg, batch_slots, max_seq)
        self.batch_slots = batch_slots
        self.max_seq = max_seq
        self._arrivals: collections.deque[GenRequest] = collections.deque()
        self._active: dict[int, GenRequest] = {}
        # one lock serialises admission/prefill against decode: the two
        # streams may live on different executor workers, but KV cache and
        # slot state are shared
        self._lock = threading.Lock()
        self._decode_inflight = None
        self._stopping = False
        self._jit_decode = jax.jit(
            lambda p, c, t, q: registry.decode_step(p, cfg, c, t, q))
        self.admit_stream = engine.stream("serve-admit")
        self.decode_stream = engine.stream("serve-decode")
        engine.async_start(self._admit_poll, None, self.admit_stream)
        engine.async_start(self._decode_poll, None, self.decode_stream)
        if executor is not None:
            executor.adopt(self.admit_stream)
            executor.adopt(self.decode_stream)
            self._sub = None
        else:
            # no executor: bridge the serve streams into every
            # engine.progress() call so single-threaded callers still serve
            self._sub = engine.register_subsystem(
                "serve-streams", self._poll_streams, cheap=True, priority=4)
        self.steps = 0

    # -- client API -------------------------------------------------------
    def submit(self, request: GenRequest) -> Request:
        with self._lock:
            if self._stopping:
                raise RuntimeError("serve engine is stopping")
            self._arrivals.append(request)
        return request.done_req

    # -- caller-driven bridge ---------------------------------------------
    def _poll_streams(self) -> bool:
        made = 0
        for s in (self.admit_stream, self.decode_stream):
            try:
                made += s._poll_once()
            except Exception:
                # the broken task is already dropped and recorded on
                # s.task_errors; the bridge must NOT let the exception
                # escape, or the engine's isolation would unregister it
                # and silently halt all serving
                pass
        return made > 0

    # -- admission stream ---------------------------------------------------
    def _admit_poll(self, thing) -> str:
        self._admit()
        with self._lock:
            if self._stopping and not self._arrivals:
                return DONE
        return NOPROGRESS

    def _admit(self) -> bool:
        made = False
        with self._lock:
            while self._arrivals and self.slots.free_slots():
                req = self._arrivals.popleft()
                slot = self.slots.assign(req.request_id)
                req.slot_index = slot.index
                # sequential prefill: feed prompt tokens through decode
                # steps (token-by-token prefill keeps one compiled shape;
                # a chunked prefill path is the serving hillclimb)
                self._prefill(req, slot)
                self._active[slot.index] = req
                made = True
        return made

    def _prefill(self, req: GenRequest, slot) -> None:
        # writes the prompt into the slot's cache; last logits start decode
        cache = self.slots.cache
        for tok in req.prompt[:-1]:
            tokens = self._token_batch(slot.index, int(tok))
            pos = self.slots.positions()
            _, cache = self._jit_decode(self.params, cache, tokens, pos)
            slot.pos += 1
        self.slots.cache = cache
        req.out_tokens = []
        req.next_input = int(req.prompt[-1])

    def _token_batch(self, slot_index: int, token: int):
        toks = np.zeros((self.batch_slots, 1), np.int32)
        toks[slot_index, 0] = token
        return jnp.asarray(toks)

    # -- fused decode stream --------------------------------------------------
    def _decode_poll(self, thing) -> str:
        with self._lock:
            if self._decode_inflight is None:
                if not self._active:
                    if self._stopping and not self._arrivals:
                        return DONE
                    return NOPROGRESS      # idle; keep polling
                toks = np.zeros((self.batch_slots, 1), np.int32)
                for idx, req in self._active.items():
                    toks[idx, 0] = req.next_input
                pos = self.slots.positions()
                logits, cache = self._jit_decode(
                    self.params, self.slots.cache, jnp.asarray(toks), pos)
                self._decode_inflight = (logits, cache)
                return NOPROGRESS
            logits, cache = self._decode_inflight
            if not logits.is_ready():
                return NOPROGRESS          # device still busy — no block
            self._decode_inflight = None
            self.slots.cache = cache
            self.steps += 1
            next_ids = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            finished = []
            for idx, req in list(self._active.items()):
                tok = int(next_ids[idx])
                if req.first_token_at is None:
                    req.first_token_at = time.monotonic()
                req.out_tokens.append(tok)
                req.next_input = tok
                self.slots.slots[idx].pos += 1
                if (len(req.out_tokens) >= req.max_new_tokens
                        or self.slots.slots[idx].pos >= self.max_seq - 1):
                    finished.append(idx)
            for idx in finished:
                req = self._active.pop(idx)
                req.finished_at = time.monotonic()
                self.slots.release(self.slots.slots[idx])
                req.done_req.complete(req.out_tokens)
            return NOPROGRESS              # perpetual while serving
    # -- lifecycle ------------------------------------------------------------
    @property
    def idle(self) -> bool:
        with self._lock:
            return not (self._active or self._arrivals)

    def run_until_idle(self, timeout: float = 120.0) -> None:
        """Serve until the backlog empties.  With an executor the worker
        threads do the progressing and this thread just waits; without one
        it is the classic caller-driven progress loop."""
        t0 = time.monotonic()
        while not self.idle:
            if self.executor is not None and self.executor.running:
                time.sleep(0.0005)
            elif self._sub is not None:
                self.engine.progress()          # bridge polls the streams
            else:
                # executor attached but not running (never started, or
                # already shut down): drive the adopted streams inline so
                # waiting can never silently hang
                self._poll_streams()
                self.engine.poll_subsystems()
            if time.monotonic() - t0 > timeout:
                raise TimeoutError("serve engine did not drain")

    def stop(self) -> None:
        """Begin shutdown: reject new submissions; the perpetual
        admission/decode tasks return DONE once the backlog is served, so
        ``executor.shutdown(drain=True)`` / ``engine.drain`` terminate."""
        with self._lock:
            self._stopping = True

    def close(self, timeout: float = 60.0) -> None:
        """Stop and drain both serve streams (Listing 1.2 finalize)."""
        self.stop()
        if self.executor is not None and self.executor.running:
            self.executor.drain(timeout)
        else:
            self.engine.drain(self.admit_stream, timeout=timeout)
            self.engine.drain(self.decode_stream, timeout=timeout)
        if self._sub is not None:
            self.engine.unregister_subsystem(self._sub)
            self._sub = None
