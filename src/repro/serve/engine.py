"""Event-driven continuous-batching server on the progress engine.

Serving is the dynamic side of the paper's story: requests arrive at
arbitrary times (the "unexpected message queue" of MPI has no SPMD
analogue — this layer is it).  Since the continuation layer
(repro.core.continuations) landed, the whole request lifecycle is
completion-driven — there is no polling loop anywhere in this file:

* ``submit``            — the *arrival event* schedules a one-shot
  admission task on the admit stream (none is scheduled while idle);
* admission / prefill   — admits arrivals into free paged-KV lanes
  (blocks + lane claimed atomically) and runs one chunk of batched
  prefill, then schedules the first decode step;
* decode                — one fused decode step for ALL active slots
  (continuous batching) is dispatched and its device completion watched
  by a one-shot readiness task (``Array.is_ready``, never blocked on)
  that completes a per-step ``Request``;
* detokenize            — a continuation attached to the step request:
  extracts tokens, finishes requests (their ``done_req`` completes,
  firing any client continuations), and *chains the next decode step* —
  each stage's completion schedules the next;
* slot-free event       — finishing requests re-schedules admission, so
  a backlog drains exactly when capacity appears.

Between requests every serve stream is empty: no perpetual task spins,
no idle polling — the paper's event-driven integration claim (§4.6).

The continuation execution policy is a knob (``continuation_policy``):
``INLINE`` runs detokenize on the progress thread that observed decode
completion; ``DEFERRED`` (default) queues it and the owner drains with
``continuation_max_drain`` as bounded backpressure.  With a
``ProgressExecutor`` the serve streams are adopted by its workers and a
deferred queue is drained by them between polls; without one, a cheap
subsystem bridges streams + continuation drain into every
``engine.progress()`` call, so the classic ``while: engine.progress()``
loop still serves traffic.

**Model-axis sharding + serve-side collectives.**  With a ``mesh`` the
decode step is tensor-parallel on the output projection: one shared
``shard_map`` program runs ``decode_hidden`` and each model-axis rank's
vocab-slice unembed (``unembed_partial``), producing the per-rank
partial logits ``[n, B, V/n]`` plus the updated cache.  The full logits
are then the rank-order all-gather of that activation, two ways:

* ``collective_backend="native"`` — a second jitted ``shard_map``
  program with an in-program ``lax.all_gather`` (the GSPMD baseline);
* ``collective_backend="user"``  — a **persistent user-space
  all-gather** (``allgather_init``/``start``) on a dedicated
  serve-collective stream.  Decode's shapes are fixed, so the plan and
  fused round programs compile exactly once at engine construction;
  every step is a ``start(partial)`` re-bind whose completion feeds the
  existing detokenize continuation.  The gather rounds are driven by
  the progress engine while the host stays free for admission/prefill
  of concurrent arrivals — and with an executor the ``start`` itself is
  executor-driven (the worker owning the collective stream dispatches
  round 0, the decode chain pays an enqueue).

Both sharded paths consume bit-identical partial logits from the same
program, so their greedy token streams are identical — the serve-side
analogue of the fig-14 user-vs-native comparison.
"""
from __future__ import annotations

import collections
import dataclasses
import statistics
import threading
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import DEFERRED, DONE, NOPROGRESS, ProgressEngine, Request
from repro.core import debug
from repro.core.continuations import POLICIES, ContinuationQueue
from repro.core.executor import ProgressExecutor
from repro.core.stats import SchedulerStats
from repro.collectives.nonblocking import (CollectiveSpec,
                                           MembershipError,
                                           spec_from_legacy)
from repro.models import registry
from repro.serve.kvcache import PagedKVCache


@dataclasses.dataclass
class GenRequest:
    request_id: str
    prompt: np.ndarray               # [prompt_len] int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done_req: Request = dataclasses.field(default_factory=Request)
    slot_index: int = -1
    next_input: int = 0            # next token to feed the fused decode
    submitted_at: float = dataclasses.field(default_factory=time.monotonic)
    # stamped exactly once, by the detokenize continuation of the first
    # decode step that produced a token for this request; stays None for
    # requests that fail before their first token (TTFT must not count
    # them — see ServeLatencyStats.no_first_token)
    first_token_at: float | None = None
    finished_at: float | None = None
    # -- continuous-batching bookkeeping (paged cache mode) ----------------
    # replay = prompt + generated prefix: what prefill must rebuild in the
    # KV cache.  Set at first admission; recomputed at preemption so a
    # re-admitted request resumes its exact token stream (greedy decode is
    # per-lane deterministic — same replay ⇒ same continuation).
    replay: Optional[np.ndarray] = None
    prefill_pos: int = 0           # replay tokens already fed this residency
    preemptions: int = 0           # times evicted under block pressure
    seq: int = 0                   # submit order; the scheduler never
    #                                preempts the oldest resident
    queued_s: float = 0.0          # total backlog wait across (re)admissions
    last_enqueued_at: float = 0.0
    # membership change: host-side snapshot of the lane's KV prefix +
    # per-lane state (PagedKVCache.checkpoint_lane), carried through the
    # backlog so re-admission on the rebuilt mesh restores instead of
    # replaying the whole prefix; None = replay from tokens
    kv_ckpt: Optional[dict] = None


class _BucketBacklog:
    """Length-bucketed FIFO backlog (power-of-two length buckets).

    Admission drains buckets in order of their oldest member, so requests
    of similar length are admitted together (their prefills retire
    together and lanes churn less — the classic bucket-by-length batching
    idiom), while one bucket's over-long head cannot starve the others:
    ``pop_fitting`` falls through to the next bucket when a head does not
    fit the free pool.  Within a bucket order is by submit ``seq``, so a
    preempted request re-enters ahead of younger arrivals and is retried
    first once blocks free up.
    """

    def __init__(self):
        self._buckets: dict[int, collections.deque] = {}

    @staticmethod
    def bucket_of(length: int) -> int:
        return max(1, int(length)).bit_length()

    def push(self, req: GenRequest) -> None:
        dq = self._buckets.setdefault(self.bucket_of(len(req.replay)),
                                      collections.deque())
        if not dq or req.seq >= dq[-1].seq:
            dq.append(req)
        elif req.seq <= dq[0].seq:
            dq.appendleft(req)
        else:                       # rare: mid-deque re-admission
            items = sorted([*dq, req], key=lambda r: r.seq)
            dq.clear()
            dq.extend(items)

    def pop_fitting(self, fits):
        """First (oldest-bucket-first) request for which ``fits(req)``
        returns a lane; ``(None, None)`` when nothing fits."""
        order = sorted((dq for dq in self._buckets.values() if dq),
                       key=lambda dq: dq[0].seq)
        for dq in order:
            lane = fits(dq[0])
            if lane is not None:
                return dq.popleft(), lane
        return None, None

    def drain(self) -> list:
        out = []
        for dq in self._buckets.values():
            out.extend(dq)
            dq.clear()
        out.sort(key=lambda r: r.seq)
        return out

    def __len__(self) -> int:
        return sum(len(dq) for dq in self._buckets.values())


def _quantiles(samples_ms: list[float]) -> tuple[float, float, float]:
    mean = statistics.fmean(samples_ms)
    s = sorted(samples_ms)
    p50 = s[len(s) // 2]
    p99 = s[min(int(0.99 * len(s)), len(s) - 1)]
    return mean, p50, p99


@dataclasses.dataclass
class ServeLatencyStats:
    """Request-latency snapshot (``ServeEngine.latency_snapshot``).

    TTFT aggregates cover only requests that produced a first token;
    ``no_first_token`` counts the ones that finished (failed) without —
    they are excluded from TTFT rather than silently dropped from the
    ledger.  Latency aggregates cover every finished request.  Queue-time
    aggregates cover time spent waiting in the backlog (summed across
    re-admissions for preempted requests); ``preempted``/``preemptions``
    count requests evicted under block pressure and total evictions."""
    submitted: int = 0
    completed: int = 0
    failed: int = 0
    no_first_token: int = 0          # finished without a first token
    preempted: int = 0               # finished requests evicted >= once
    preemptions: int = 0             # total evictions over those requests
    ttft_ms_mean: float | None = None
    ttft_ms_p50: float | None = None
    ttft_ms_p99: float | None = None
    latency_ms_mean: float | None = None
    latency_ms_p50: float | None = None
    latency_ms_p99: float | None = None
    queued_ms_mean: float | None = None
    queued_ms_p50: float | None = None
    queued_ms_p99: float | None = None

    def format(self) -> str:
        def f(v):
            return f"{v:.1f}" if v is not None else "n/a"
        return (f"requests: {self.submitted} submitted, "
                f"{self.completed} completed, {self.failed} failed "
                f"({self.no_first_token} without first token, "
                f"{self.preempted} preempted {self.preemptions}x); "
                f"TTFT ms mean/p50/p99 {f(self.ttft_ms_mean)}/"
                f"{f(self.ttft_ms_p50)}/{f(self.ttft_ms_p99)}; "
                f"latency ms mean/p50/p99 {f(self.latency_ms_mean)}/"
                f"{f(self.latency_ms_p50)}/{f(self.latency_ms_p99)}; "
                f"queued ms mean/p50/p99 {f(self.queued_ms_mean)}/"
                f"{f(self.queued_ms_p50)}/{f(self.queued_ms_p99)}")


class ServeEngine:
    def __init__(self, cfg, params, engine: ProgressEngine,
                 batch_slots: int = 8, max_seq: int = 512,
                 greedy: bool = True,
                 executor: Optional[ProgressExecutor] = None,
                 continuation_policy: str = DEFERRED,
                 continuation_max_drain: int = 64,
                 mesh=None, model_axis: str = "model",
                 collective_spec: CollectiveSpec | None = None,
                 collective_backend: str | None = None,
                 collective_chunks: int | None = None,
                 collective_round_batch: int | None = None,
                 cache_mode: str = "paged",
                 kv_block_size: int = 16,
                 kv_blocks: int | None = None,
                 prefill_chunk: int = 8,
                 epoch=None):
        if continuation_policy not in POLICIES:
            raise ValueError(f"continuation_policy must be one of {POLICIES}")
        spec = spec_from_legacy(collective_spec, surface="ServeEngine",
                                backend=collective_backend,
                                chunks=collective_chunks,
                                round_batch=collective_round_batch)
        if spec.user and mesh is None:
            # silently serving the plain native path while the operator
            # believes they exercised user-space collectives is worse
            # than an eager error
            raise ValueError("collective backend 'user' requires a mesh "
                             "(model-axis-sharded decode)")
        if cache_mode == "slots":
            raise ValueError(
                "cache_mode='slots' was retired: the fixed-slot cache is "
                "gone (paged is strictly more capable — same bytes, block "
                "granularity).  Drop the kwarg, or size the pool with "
                "kv_block_size/kv_blocks to mimic fixed lanes "
                "(kv_blocks = batch_slots * max_seq // kv_block_size + 1)")
        if cache_mode != "paged":
            raise ValueError("cache_mode must be 'paged'")
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got "
                             f"{prefill_chunk}")
        self.cfg = cfg
        self.params = params
        self.engine = engine
        self.executor = executor
        self.mesh = mesh
        self.model_axis = model_axis
        self.collective_spec = spec
        self.collective_backend = spec.backend   # read-compat mirror
        self._sharded = mesh is not None
        self.paged = True                        # retained for callers
        self.slots = PagedKVCache(cfg, batch_slots, max_seq,
                                  block_size=kv_block_size,
                                  num_blocks=kv_blocks, mesh=mesh)
        self.batch_slots = batch_slots
        self.max_seq = max_seq
        self.prefill_chunk = prefill_chunk
        # retained for elastic rebuilds (_rebuild_for_survivors)
        self._kv_block_size = kv_block_size
        self._kv_blocks = kv_blocks
        self._arrivals: collections.deque[GenRequest] = collections.deque()
        self._active: dict[int, GenRequest] = {}
        # paged continuous batching: requests waiting for blocks/lanes,
        # and lanes whose prompt replay is mid-prefill (chunked — prefill
        # interleaves with decode steps instead of blocking them)
        self._backlog = _BucketBacklog()
        self._prefilling: dict[int, GenRequest] = {}
        self._seq = 0                  # submit-order stamp (preemption policy)
        self.sched = SchedulerStats()
        # one lock serialises admission/prefill against detokenize: the
        # stages may run on different executor workers, but KV cache and
        # slot state are shared.  Prefill itself runs OUTSIDE the lock
        # (staged cache, published atomically) so submit() and the
        # detokenize path never block behind a token-by-token prompt loop.
        self._lock = debug.make_lock("ServeEngine._lock")
        self._decode_inflight = None
        self._current_step = None      # the step whose continuation owns state
        self._admit_scheduled = False
        self._prefill_active = False
        self._stopping = False
        self._closed = False
        # membership (fault tolerance): the epoch's invalidation listener
        # only RECORDS the change — it may run inside whatever subsystem
        # poll fired the invalidation (often an executor worker), where a
        # drain/rebuild would self-deadlock.  The heavy work happens on
        # the admit path (_apply_membership_change).
        self.epoch = epoch
        self._membership_exc = None
        self._remeshing = False
        self.remeshes = 0
        # finished-request ledger for latency_snapshot (bounded: a
        # long-lived server must not grow per-request records forever)
        self._submitted = 0
        self._finished: collections.deque[tuple] = collections.deque(
            maxlen=4096)
        if self._sharded:
            self._build_sharded_decode()
        else:
            self.coll = None
            self._ag_handle = None
            self._jit_gather = None
            self._jit_decode = jax.jit(
                lambda p, c, t, q, bt, fd: registry.decode_step_paged(
                    p, cfg, c, t, q, bt, fd))
        self.admit_stream = engine.stream("serve-admit")
        self.decode_stream = engine.stream("serve-decode")
        # decode completions are delivered through this queue; its
        # detection task lives on the decode stream so INLINE runs
        # detokenize right where completion was observed
        self.continuations = ContinuationQueue(
            engine, self.decode_stream, policy=continuation_policy,
            name="serve-cont")
        self.continuation_max_drain = continuation_max_drain
        self._queue_adopted = False
        # streams the caller-driven bridge must poll: the serve pair
        # plus (user backend) the collective stream — without an
        # executor nobody else progresses the all-gather rounds, and
        # with one that is NOT running the run_until_idle fallback
        # drives these same streams inline (a running executor never
        # routes through _poll_streams, so there is no contention)
        self._bridge_streams = [self.admit_stream, self.decode_stream]
        if self.coll is not None:
            self._bridge_streams.append(self.coll.stream)
        if executor is not None:
            executor.adopt(self.admit_stream)
            executor.adopt(self.decode_stream)
            if continuation_policy == DEFERRED:
                executor.adopt_queue(self.continuations)
                self._queue_adopted = True
            self._sub = None
        else:
            # no executor: bridge the serve streams (and the continuation
            # drain) into every engine.progress() call so single-threaded
            # callers still serve
            self._sub = engine.register_subsystem(
                "serve-streams", self._poll_streams, cheap=True, priority=4)
        self.steps = 0
        # bounded: transient device failures on a long-lived server must
        # not accumulate exception objects forever
        self.decode_errors: collections.deque[BaseException] = \
            collections.deque(maxlen=256)
        if epoch is not None:
            epoch.subscribe(self._on_epoch_invalidate)

    # -- sharded decode construction --------------------------------------
    def _build_sharded_decode(self) -> None:
        """Compile the model-axis decode pair: ONE shared partial-logits
        program (hidden + per-rank vocab-slice unembed) and the gather —
        in-program ``all_gather`` (native) or a persistent user-space
        ``allgather_init`` handle on a dedicated serve-collective stream
        (built and warmed once: decode shapes are fixed)."""
        cfg, mesh, axis = self.cfg, self.mesh, self.model_axis
        from repro.collectives import nonblocking as NB
        if axis not in dict(mesh.shape):
            raise ValueError(f"mesh has no axis {axis!r}: {dict(mesh.shape)}")
        n = dict(mesh.shape)[axis]
        V = cfg.vocab_size
        if V % n:
            raise ValueError(
                f"sharded serving needs vocab_size ({V}) divisible by the "
                f"{axis!r} axis size ({n})")
        vloc = V // n
        self._model_shards = n
        if not hasattr(registry.module_for(cfg), "decode_hidden_paged"):
            raise ValueError(
                f"sharded serving not supported for family {cfg.family!r}")

        def local_step(params, cache, toks, pos, tables, fed):
            hid, new_cache = registry.decode_hidden_paged(
                params, cfg, cache, toks, pos, tables, fed)
            r = jax.lax.axis_index(axis)
            # [B, 1, vloc] -> [1, B, vloc]: leading dim carries the
            # rank (the user-collective payload layout)
            part = registry.unembed_partial(params, cfg, hid,
                                            r * vloc, vloc)
            return part[:, 0][None], new_cache

        self._jit_decode = jax.jit(compat.shard_map(
            local_step, mesh=mesh,
            in_specs=(P(), P(), P(), P(), P(), P()),
            out_specs=(P(axis), P())))

        def local_gather(part):                  # local [1, B, vloc]
            return jax.lax.all_gather(part, axis, axis=2, tiled=True)

        if not self.collective_spec.user:
            self._jit_gather = jax.jit(compat.shard_map(
                local_gather, mesh=mesh, in_specs=P(axis),
                out_specs=P(axis)))              # global [n, B, V]
            self.coll = None
            self._ag_handle = None
        else:
            self._jit_gather = None
            self.coll = NB.UserCollectives(self.engine,
                                           executor=self.executor,
                                           name="serve-coll",
                                           epoch=self.epoch)
            self._ag_handle = self.coll.allgather_init(
                jax.ShapeDtypeStruct((n, self.batch_slots, vloc),
                                     jnp.float32),
                mesh, axis, spec=self.collective_spec, warmup=True)

    # -- client API -------------------------------------------------------
    def submit(self, request: GenRequest) -> Request:
        with self._lock:
            if self._stopping:
                raise RuntimeError("serve engine is stopping")
            request.seq = self._seq
            self._seq += 1
            request.last_enqueued_at = time.monotonic()
            self._arrivals.append(request)
            self._submitted += 1
            waiting = len(self._arrivals) + len(self._backlog)
            self.sched.peak_backlog = max(self.sched.peak_backlog, waiting)
        self._schedule_admit()               # the arrival event
        return request.done_req

    # -- caller-driven bridge ---------------------------------------------
    def _poll_streams(self) -> bool:
        made = 0
        for s in self._bridge_streams:
            try:
                made += s._poll_once()
            except Exception:
                # the broken task is already dropped and recorded on
                # s.task_errors; the bridge must NOT let the exception
                # escape, or the engine's isolation would unregister it
                # and silently halt all serving
                pass
        made += self.continuations.drain(self.continuation_max_drain)
        coll = self.coll                 # close() nulls the attr concurrently
        if coll is not None:
            made += coll.queue.drain(self.continuation_max_drain)
        return made > 0

    # -- admission (event-scheduled, one-shot) ------------------------------
    def _schedule_admit(self) -> None:
        with self._lock:
            pending = (self._arrivals or self._backlog or self._prefilling
                       or self._membership_exc is not None)
            if self._admit_scheduled or not pending:
                return
            self._admit_scheduled = True
        self.engine.async_start(self._admit_task, None, self.admit_stream)

    def _admit_task(self, thing) -> str:
        with self._lock:
            self._admit_scheduled = False
        self._admit()
        self._schedule_decode()
        return DONE                          # one-shot: nothing left to poll

    def _admit(self) -> bool:
        """Admission + one prefill chunk (staged cache writes outside
        the lock, published atomically).

        A pending membership change is applied first — nothing may be
        admitted onto the old mesh.  The unlocked reads are benign: the
        flag is set under the lock, and an invalidation racing past the
        check is caught by the decode gate and the next admit pass."""
        if self._membership_exc is not None:
            self._apply_membership_change()
            if self._membership_exc is not None:
                return False         # in-flight work must drain first
        return self._admit_paged()

    def _admit_paged(self) -> bool:
        """Continuous-batching admission: drain arrivals into the
        length-bucketed backlog, admit whatever fits the free lanes AND
        free blocks (lane + prefill blocks claimed atomically), then run
        ONE chunk of batched prefill — at most ``prefill_chunk`` fused
        calls, each feeding EVERY mid-prefill lane its next replay token.
        Long prompts therefore interleave with decode steps instead of
        blocking them: the caller (admit task / detokenize continuation)
        re-schedules until every replay is rebuilt.

        Runs the chunk on a STAGED cache outside the lock (no decode
        step is in flight and ``_prefill_active`` excludes concurrent
        admissions)."""
        with self._lock:
            if self._decode_inflight is not None or self._prefill_active:
                return False
            now = time.monotonic()
            while self._arrivals:
                req = self._arrivals.popleft()
                if req.replay is None:
                    req.replay = np.asarray(req.prompt, np.int32)
                self._backlog.push(req)

            def fits(req):
                return self.slots.assign(req.request_id,
                                         seq_len=len(req.replay))

            admitted = []
            while self.slots.free_count:
                req, lane = self._backlog.pop_fitting(fits)
                if req is None:
                    break
                req.slot_index = lane.index
                req.prefill_pos = 0
                req.queued_s += now - req.last_enqueued_at
                self._prefilling[lane.index] = req
                admitted.append(req)
                self.sched.admitted += 1
            if not self._prefilling:
                return False
            self.sched.peak_resident = max(
                self.sched.peak_resident,
                len(self._active) + len(self._prefilling))
            self._prefill_active = True
            cache = self.slots.cache
        try:
            for req in admitted:
                idx = req.slot_index
                # recycled lane: zero per-lane recurrent state (SSM) so
                # the previous occupant cannot leak into this request
                cache = self.slots.reset_lane(cache, idx)
                if req.kv_ckpt is not None:
                    # migrated lane (membership change): restore the KV
                    # prefix + per-lane state checkpointed off the old
                    # mesh instead of replaying the whole prefix
                    cache = self.slots.restore_lane(cache, idx, req.kv_ckpt)
                    req.prefill_pos = len(req.replay) - 1
                    req.kv_ckpt = None
            cache, completed = self._prefill_chunk(cache)
        except BaseException as exc:  # noqa: BLE001
            # chunk failure: the staged cache is NOT published, so every
            # mid-prefill replay is lost — fail those requests exactly
            # once, return their lanes and blocks to the free lists
            self.decode_errors.append(exc)
            with self._lock:
                self._prefill_active = False
                for idx, req in list(self._prefilling.items()):
                    self._prefilling.pop(idx)
                    self.slots.release(self.slots.slots[idx])
                    req.finished_at = time.monotonic()
                    self._record_locked(req, failed=True)
                    req.done_req.fail(exc)
            self._schedule_admit()           # backlog remainder, if any
            return False
        with self._lock:
            self._prefill_active = False
            self.slots.cache = cache
            for idx in completed:
                self._active[idx] = self._prefilling.pop(idx)
        return True

    def _prefill_chunk(self, cache):
        """Up to ``prefill_chunk`` fused paged calls over the staged
        cache; logits are discarded (and in sharded mode no gather is
        started) — prefill only needs the KV side effect.  Lanes not
        being fed freeze their SSM state via the ``fed`` mask; their
        attention scratch writes are overwritten before the causal mask
        can expose them (see models/transformer.py).  Returns the staged
        cache and the lanes whose replay completed."""
        for _ in range(self.prefill_chunk):
            feeding = [(idx, req) for idx, req in self._prefilling.items()
                       if req.prefill_pos < len(req.replay) - 1]
            if not feeding:
                break
            toks = np.zeros((self.batch_slots, 1), np.int32)
            fed = np.zeros((self.batch_slots,), bool)
            for idx, req in feeding:
                toks[idx, 0] = int(req.replay[req.prefill_pos])
                fed[idx] = True
            _, cache = self._jit_decode(
                self.params, cache, jnp.asarray(toks),
                self.slots.positions(), self.slots.block_tables(),
                jnp.asarray(fed))
            for idx, req in feeding:
                req.prefill_pos += 1
                self.slots.slots[idx].pos += 1
            self.sched.prefill_calls += 1
        completed = []
        for idx, req in self._prefilling.items():
            if req.prefill_pos >= len(req.replay) - 1:
                req.next_input = int(req.replay[-1])
                completed.append(idx)
        return cache, completed

    # -- fused decode (continuation-chained steps) ---------------------------
    def _schedule_decode(self) -> None:
        with self._lock:
            # defer while a prefill is staging: a step launched off the
            # pre-prefill cache would have its continuation overwrite the
            # published prompt KV.  The admitting thread always calls
            # _schedule_decode after publishing, so nothing starves.
            busy = (self._decode_inflight is not None
                    or self._prefill_active)
            # membership pending: nothing launches on the old mesh — the
            # admit path applies the change first.  With a step still in
            # flight its own continuation funnels there; re-scheduling
            # here too would spin the admit stream against it.
            blocked = self._membership_exc is not None
            launched = not busy and not blocked and bool(self._active)
            if launched:
                step, agreq, cache = self._launch_decode_locked()
            # paged: prompts may still be mid-replay with no lane decoding
            # yet — keep the prefill chain alive (the admit task runs the
            # next chunk; _admit_scheduled bounds this to one outstanding
            # task)
            reschedule = (not busy and not blocked
                          and not self._active and bool(self._prefilling))
        if launched:
            self._attach_step(step, agreq, cache)
        elif reschedule or (blocked and not busy):
            self._schedule_admit()

    def _launch_decode_locked(self):
        """Dispatch one fused decode step; caller holds ``self._lock``.
        Returns ``(step, agreq, cache)``.

        Unsharded / native-sharded: completion is watched by a one-shot
        readiness task on the decode stream that completes ``step`` —
        the only place the device is polled.  User backend: the step's
        partial logits are re-bound into the persistent all-gather
        (``start``), and ``agreq``'s completion (bridged by a
        continuation) completes ``step`` with the gathered logits — the
        engine drives the gather rounds while the device runs.

        Dispatch failure fails the request instead of wedging the stream
        (the failure continuation cleans up).  The caller attaches the
        continuations AFTER releasing the lock: an already-failed step
        fires inline immediately, and that must not happen while the
        serve lock is held.
        """
        step = Request(tag="decode-step")
        self._current_step = step
        try:
            self._ensure_capacity_locked()
            toks = np.zeros((self.batch_slots, 1), np.int32)
            for idx, req in self._active.items():
                toks[idx, 0] = req.next_input
            pos = self.slots.positions()
            fed = np.zeros((self.batch_slots,), bool)
            for idx in self._active:
                fed[idx] = True
            out, cache = self._jit_decode(
                self.params, self.slots.cache, jnp.asarray(toks), pos,
                self.slots.block_tables(), jnp.asarray(fed))
            if self._jit_gather is not None:     # native-sharded gather
                out = self._jit_gather(out)
            agreq = None
            if self._ag_handle is not None:      # user-space gather
                agreq = self._ag_handle.start(out)
        except BaseException as exc:  # noqa: BLE001
            step.fail(exc)
            return step, None, None
        self._decode_inflight = (out, cache)
        if agreq is None:
            def ready_poll(thing, out=out, cache=cache, step=step) -> str:
                if not out.is_ready():       # device still busy — no block
                    return NOPROGRESS
                step.complete((out, cache))
                return DONE

            self.engine.async_start(ready_poll, None, self.decode_stream)
        return step, agreq, cache

    # -- block pressure: preemption / re-admission (paged mode) -------------
    def _ensure_capacity_locked(self) -> None:
        """Grow every decoding lane's block table to cover its next write
        position, preempting victims under block pressure.  Caller holds
        ``self._lock``.

        Policy: the oldest resident (smallest submit ``seq``, across
        decoding AND prefilling lanes) is never preempted, so it always
        runs to completion — every preemption strictly reduces the set of
        requests younger than it, which bounds total preemptions for a
        finite workload (no livelock).  Victims are evicted
        youngest-first; a lane may evict itself (it re-enters the backlog
        ahead of younger arrivals and is retried once blocks free)."""
        for idx in sorted(self._active, key=lambda i: self._active[i].seq):
            while idx in self._active:
                if self.slots.ensure(idx, self.slots.slots[idx].pos):
                    break
                victim = self._pick_victim_locked()
                if victim is None:
                    # sole resident: PagedKVCache guarantees the pool
                    # holds one max_seq request, so ensure cannot fail
                    raise RuntimeError(
                        "block pool exhausted with no preemptible victim")
                self._preempt_locked(victim)

    def _pick_victim_locked(self) -> Optional[int]:
        """Lane of the youngest resident, never the oldest; ``None`` when
        fewer than two requests are resident."""
        residents = {**self._prefilling, **self._active}
        if len(residents) < 2:
            return None
        return max(residents, key=lambda i: residents[i].seq)

    def _preempt_locked(self, idx: int) -> None:
        """Evict one resident lane: return its blocks to the free list
        and re-queue the request with its generated prefix folded into
        ``replay``.  Greedy decode is per-lane deterministic, so the
        rebuilt KV continues the exact same token stream — preemption is
        invisible in the output."""
        req = self._active.pop(idx, None)
        if req is None:
            req = self._prefilling.pop(idx)
        self.slots.release(self.slots.slots[idx])
        req.preemptions += 1
        self.sched.preemptions += 1
        req.replay = np.concatenate([
            np.asarray(req.prompt, np.int32),
            np.asarray(req.out_tokens, np.int32)])
        req.prefill_pos = 0
        req.slot_index = -1
        req.last_enqueued_at = time.monotonic()
        self._backlog.push(req)

    def _attach_step(self, step: Request, agreq=None, cache=None) -> None:
        if agreq is not None:
            # bridge the persistent all-gather into the step request:
            # detokenize (below) stays identical across backends
            self.continuations.attach(
                agreq,
                lambda rq, step=step, cache=cache:
                    step.complete((rq.value(), cache)),
                on_error=lambda rq, step=step: step.fail(
                    rq.exception
                    or RuntimeError("serve all-gather failed")))
        self.continuations.attach(step, self._on_step_done,
                                  on_error=self._on_step_failed)

    def _next_ids(self, logits) -> np.ndarray:
        """Greedy ids [B] from the step output: unsharded logits are
        [B, 1, V]; sharded (gathered) logits are [n, B, V] with every
        row the full vocab in rank order — row 0 is the whole answer."""
        if self._sharded:
            return np.asarray(jnp.argmax(logits[0], axis=-1))
        return np.asarray(jnp.argmax(logits[:, -1], axis=-1))

    def _on_step_done(self, step: Request) -> None:
        """Detokenize stage (a continuation): harvest the fused step,
        finish/complete requests, and chain the next decode step."""
        logits, cache = step.value()
        try:
            # materialize OUTSIDE the lock: this is where async device
            # errors surface (not at dispatch) — a raise here must take
            # the failure path, not wedge the server with _active full
            # and no task on any stream
            next_ids = self._next_ids(logits)
        except BaseException as exc:  # noqa: BLE001
            self._fail_step(step, exc)
            return
        freed = False
        with self._lock:
            if self._current_step is not step:
                return                         # stale: a newer step owns state
            self._current_step = None
            self._decode_inflight = None
            self.slots.cache = cache
            self.steps += 1
            finished = []
            for idx, req in list(self._active.items()):
                tok = int(next_ids[idx])
                if req.first_token_at is None:
                    # TTFT stamp: exactly once, on the first produced token
                    req.first_token_at = time.monotonic()
                req.out_tokens.append(tok)
                req.next_input = tok
                self.slots.slots[idx].pos += 1
                if (len(req.out_tokens) >= req.max_new_tokens
                        or self.slots.slots[idx].pos >= self.max_seq - 1):
                    finished.append(idx)
            for idx in finished:
                req = self._active.pop(idx)
                req.finished_at = time.monotonic()
                self.slots.release(self.slots.slots[idx])
                self._record_locked(req, failed=False)
                req.done_req.complete(req.out_tokens)
                freed = True
        # admit between steps: arrivals that landed while this step was
        # in flight (their admission was deferred — prefill and an
        # in-flight step must not both write slots.cache) join the batch
        # before the next launch.  Prefill stages outside the lock, so
        # releasing it first keeps submit() responsive during admission.
        self._admit()
        self._schedule_decode()                # chain the next step
        if freed:
            self._schedule_admit()             # the slot-free event

    def _on_step_failed(self, step: Request) -> None:
        """Failure continuation: a decode step that failed fails every
        in-flight request with the step's exception (propagated through
        ``Request.exception``) and frees their slots."""
        self._fail_step(step, step.exception)

    def _fail_step(self, step: Request, exc: BaseException) -> None:
        self.decode_errors.append(exc)
        with self._lock:
            if self._current_step is not step:
                # stale failure (a newer healthy step was launched before
                # this continuation drained): the requests already belong
                # to that step — touching state here would clobber it
                return
            self._current_step = None
            self._decode_inflight = None
            if (isinstance(exc, MembershipError)
                    or self._membership_exc is not None):
                # membership change killed the STEP, not the requests:
                # the failed step never published its cache, so every
                # resident lane is still pre-step-consistent — checkpoint
                # + requeue them for re-admission on the rebuilt mesh
                # instead of failing them (no in-flight request is lost)
                if self._membership_exc is None:
                    self._membership_exc = exc
                self._requeue_residents_locked()
            else:
                for idx, req in list(self._active.items()):
                    self._active.pop(idx)
                    # first_token_at stays as-is: a request that failed
                    # before its first token keeps None (null-propagated
                    # — counted by the snapshot, never faked into TTFT)
                    req.finished_at = time.monotonic()
                    self.slots.release(self.slots.slots[idx])
                    self._record_locked(req, failed=True)
                    req.done_req.fail(exc)
        self._schedule_admit()

    # -- membership changes (elastic fault tolerance) -----------------------
    def _on_epoch_invalidate(self, epoch, exc) -> None:
        """Epoch listener — runs inside whatever subsystem poll fired the
        invalidation (often an executor worker), so it only records the
        change and pokes the admit path; draining or rebuilding here
        could deadlock the worker against its own stream."""
        with self._lock:
            if self._closed:
                return
            self._membership_exc = exc
        self._schedule_admit()

    def _requeue_residents_locked(self) -> int:
        """Move every resident request (decoding or mid-prefill) back to
        the queue for re-admission on the rebuilt mesh.  Decoding paged
        lanes checkpoint their KV prefix + per-lane state to host memory
        (block-table walk) so restore skips the replay; mid-prefill lanes
        just replay.  Caller holds ``self._lock``; any in-flight step was
        failed WITHOUT publishing, so lane state is pre-step-consistent
        and ``replay = prompt + out_tokens`` resumes the exact stream."""
        now = time.monotonic()
        moved = []
        for idx, req in list(self._active.items()):
            self._active.pop(idx)
            lane = self.slots.slots[idx]
            if lane.pos > 0:
                try:
                    req.kv_ckpt = self.slots.checkpoint_lane(idx)
                except Exception as ckpt_exc:   # fall back to full replay
                    self.decode_errors.append(ckpt_exc)
                    req.kv_ckpt = None
            self.slots.release(lane)
            moved.append(req)
        for idx, req in list(self._prefilling.items()):
            self._prefilling.pop(idx)
            req.kv_ckpt = None                  # partial prefix: replay
            self.slots.release(self.slots.slots[idx])
            moved.append(req)
        for req in moved:
            req.replay = np.concatenate([
                np.asarray(req.prompt, np.int32),
                np.asarray(req.out_tokens, np.int32)])
            req.prefill_pos = 0
            req.slot_index = -1
            req.last_enqueued_at = now
        for req in moved:
            self._backlog.push(req)
        return len(moved)

    def _apply_membership_change(self) -> None:
        """Drain + rebuild after an epoch invalidation (admit path, no
        lock held).  Residents are checkpointed and requeued under the
        lock; the rebuild — survivors' mesh, fresh KV pool, recompiled
        decode/gather programs, new persistent all-gather — runs outside
        it.  Bails while a step or prefill is in flight: their
        completion/failure continuations funnel back here."""
        with self._lock:
            exc = self._membership_exc
            if exc is None or self._remeshing:
                return
            if self._decode_inflight is not None or self._prefill_active:
                return
            self._remeshing = True
            moved = self._requeue_residents_locked()
        try:
            self._rebuild_for_survivors(exc)
        except BaseException:
            # keep _membership_exc set: the next admit pass retries the
            # rebuild (residents are already requeued — idempotent)
            with self._lock:
                self._remeshing = False
            raise
        with self._lock:
            self._remeshing = False
            if self._membership_exc is exc:     # a FRESH invalidation
                self._membership_exc = None     # during rebuild stays
            self.remeshes += 1
        if moved:
            self._schedule_admit()

    def _rebuild_for_survivors(self, exc) -> None:
        """Rebuild every mesh-dependent piece on the survivors: the mesh
        (model axis shrunk to what survives — capped by the old degree
        and the vocab divisibility rule), KV pool, decode and gather
        programs, and the persistent all-gather handle.  Nothing resident
        survives in device memory: requeued requests carry their prefix
        as a host checkpoint or as replay tokens."""
        from repro.distributed import elastic
        from repro.launch.mesh import make_mesh
        old_handle, old_coll = self._ag_handle, self.coll
        self._ag_handle = None
        self.coll = None
        # stop bridging the old collective stream BEFORE draining it
        self._bridge_streams = [self.admit_stream, self.decode_stream]
        if old_handle is not None:
            old_handle.close()
        if old_coll is not None:
            old_coll.close()
        if self._sharded:
            survivors = getattr(exc, "survivors", None)
            if survivors is None:
                survivors = self._model_shards
            # plan_mesh validates survivors >= 1 and keeps the model
            # degree when it still fits; vocab divisibility caps it below
            shape, _axes = elastic.plan_mesh(
                survivors, prefer_model=self._model_shards)
            m = shape[1]
            while m > 1 and self.cfg.vocab_size % m:
                m //= 2
            if m > 1:
                self.mesh = make_mesh((m,), (self.model_axis,))
            else:
                # a lone survivor serves unsharded — there is nothing
                # left to gather
                self.mesh = None
                self._sharded = False
        self.slots = PagedKVCache(self.cfg, self.batch_slots,
                                  self.max_seq,
                                  block_size=self._kv_block_size,
                                  num_blocks=self._kv_blocks,
                                  mesh=self.mesh)
        if self.mesh is not None:
            self.params = jax.device_put(
                self.params, jax.sharding.NamedSharding(self.mesh, P()))
        else:
            self.params = jax.device_put(self.params, jax.devices()[0])
        if self._sharded:
            self._build_sharded_decode()
            if self.coll is not None:
                self._bridge_streams = [self.admit_stream,
                                        self.decode_stream, self.coll.stream]
        else:
            cfg = self.cfg
            self._jit_gather = None
            self._jit_decode = jax.jit(
                lambda p, c, t, q, bt, fd: registry.decode_step_paged(
                    p, cfg, c, t, q, bt, fd))

    # -- latency accounting ------------------------------------------------
    def _record_locked(self, req: GenRequest, failed: bool) -> None:
        """Append one finished request to the ledger (caller holds the
        serve lock — or owns the request exclusively, as prefill does)."""
        self._finished.append((req.submitted_at, req.first_token_at,
                               req.finished_at, failed, req.queued_s,
                               req.preemptions))

    def latency_snapshot(self) -> ServeLatencyStats:
        """TTFT / completion-latency aggregates over the (bounded) ledger
        of finished requests.  Requests that failed before producing a
        first token are counted (``no_first_token``) and excluded from
        the TTFT aggregates instead of silently skewing them."""
        with self._lock:
            records = list(self._finished)
            submitted = self._submitted
        snap = ServeLatencyStats(submitted=submitted)
        ttfts, lats, queued = [], [], []
        for sub, first, fin, failed, q_s, npre in records:
            if failed:
                snap.failed += 1
            else:
                snap.completed += 1
            if first is None:
                snap.no_first_token += 1
            else:
                ttfts.append((first - sub) * 1e3)
            if fin is not None:
                lats.append((fin - sub) * 1e3)
            queued.append(q_s * 1e3)
            if npre:
                snap.preempted += 1
                snap.preemptions += npre
        if ttfts:
            (snap.ttft_ms_mean, snap.ttft_ms_p50,
             snap.ttft_ms_p99) = _quantiles(ttfts)
        if lats:
            (snap.latency_ms_mean, snap.latency_ms_p50,
             snap.latency_ms_p99) = _quantiles(lats)
        if queued:
            (snap.queued_ms_mean, snap.queued_ms_p50,
             snap.queued_ms_p99) = _quantiles(queued)
        return snap

    def scheduler_snapshot(self) -> SchedulerStats:
        """Copy of the continuous-batching scheduler counters."""
        with self._lock:
            return dataclasses.replace(self.sched)

    # -- lifecycle ------------------------------------------------------------
    @property
    def idle(self) -> bool:
        with self._lock:
            busy = (self._active or self._arrivals or self._prefill_active
                    or self._prefilling or len(self._backlog)
                    or self._decode_inflight is not None
                    or self._membership_exc is not None)
        return not busy and self.continuations.ready == 0

    def run_until_idle(self, timeout: float = 120.0) -> None:
        """Serve until the backlog empties.  With an executor the worker
        threads do the progressing and this thread just waits; without one
        it is the classic caller-driven progress loop."""
        t0 = time.monotonic()
        while not self.idle:
            if self.executor is not None and self.executor.running:
                time.sleep(0.0005)
            elif self._sub is not None:
                # bridge polls the streams; pace out when nothing moved
                # (waiting on the device must not burn the core)
                if self.engine.progress() == 0:
                    time.sleep(50e-6)
            else:
                # executor attached but not running (never started, or
                # already shut down): drive the adopted streams inline so
                # waiting can never silently hang
                made = self._poll_streams()
                subs = self.engine.poll_subsystems()
                if not made and not subs:
                    time.sleep(50e-6)       # device wait: don't burn a core
            if time.monotonic() - t0 > timeout:
                raise TimeoutError("serve engine did not drain")

    def stop(self) -> None:
        """Begin shutdown: reject new submissions.  Already-submitted
        work keeps flowing (the event chain runs the backlog down); once
        it finishes no tasks remain, so drains terminate."""
        with self._lock:
            self._stopping = True

    def close(self, timeout: float = 60.0) -> None:
        """Stop, serve the backlog, then deterministically drain: both
        serve streams empty and every pending continuation executed
        (Listing 1.2 finalize, extended to the continuation layer).
        Idempotent: a second close (finally blocks, racing shutdown
        paths) is a no-op."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self.stop()
        self.run_until_idle(timeout=timeout)
        if self.executor is not None and self.executor.running:
            self.executor.drain(timeout)
        else:
            self.engine.drain(self.admit_stream, timeout=timeout)
            self.engine.drain(self.decode_stream, timeout=timeout)
        self.continuations.drain()             # anything still ready
        if self._queue_adopted:
            self.executor.release_queue(self.continuations)
            self._queue_adopted = False
        self.continuations.close()
        if self._ag_handle is not None:
            self._ag_handle.close()
            self._ag_handle = None
        if self.coll is not None:
            # drains the serve-collective stream and hands it back
            self.coll.close(timeout=timeout)
            self.coll = None
            self._bridge_streams = [self.admit_stream, self.decode_stream]
        if self._sub is not None:
            self.engine.unregister_subsystem(self._sub)
            self._sub = None
        # hand the (drained) streams back to the engine: a process that
        # builds ServeEngines repeatedly must not grow the stream list
        for stream in (self.admit_stream, self.decode_stream):
            if self.executor is not None and self.executor.owns(stream):
                self.executor.release(stream)
            if not stream.pending:
                self.engine.free_stream(stream)
