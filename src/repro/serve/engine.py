"""Event-driven continuous-batching server on the progress engine.

Serving is the dynamic side of the paper's story: requests arrive at
arbitrary times (the "unexpected message queue" of MPI has no SPMD
analogue — this layer is it).  Everything is an async task on one
engine:

* request admission  — a subsystem hook draining the arrival queue into
  free KV slots (prefill enqueued);
* prefill            — device task polled via ``Array.is_ready``;
* decode loop        — one fused decode step for ALL active slots per
  iteration (continuous batching), again polled, never blocked on;
* completion         — per-request events fired through
  ``CompletionWatcher`` (paper §4.5).

``serve_forever``-style progress is just ``engine.progress()`` in a
loop — or embedded into a trainer's overlap window for online serving.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DONE, NOPROGRESS, ProgressEngine, Request
from repro.models import registry
from repro.serve.kvcache import SlotCache


@dataclasses.dataclass
class GenRequest:
    request_id: str
    prompt: np.ndarray               # [prompt_len] int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done_req: Request = dataclasses.field(default_factory=Request)
    slot_index: int = -1
    next_input: int = 0            # next token to feed the fused decode
    submitted_at: float = dataclasses.field(default_factory=time.monotonic)
    first_token_at: float | None = None
    finished_at: float | None = None


class ServeEngine:
    def __init__(self, cfg, params, engine: ProgressEngine,
                 batch_slots: int = 8, max_seq: int = 512,
                 greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.engine = engine
        self.slots = SlotCache(cfg, batch_slots, max_seq)
        self.batch_slots = batch_slots
        self.max_seq = max_seq
        self._arrivals: collections.deque[GenRequest] = collections.deque()
        self._active: dict[int, GenRequest] = {}
        self._lock = threading.Lock()
        self._decode_inflight = None
        self._jit_decode = jax.jit(
            lambda p, c, t, q: registry.decode_step(p, cfg, c, t, q))
        self.engine.register_subsystem("serve-admit", self._admit, cheap=True,
                                       priority=4)
        self.engine.async_start(self._decode_poll, None)
        self.steps = 0

    # -- client API -------------------------------------------------------
    def submit(self, request: GenRequest) -> Request:
        with self._lock:
            self._arrivals.append(request)
        return request.done_req

    # -- admission subsystem -----------------------------------------------
    def _admit(self) -> bool:
        made = False
        with self._lock:
            while self._arrivals and self.slots.free_slots():
                req = self._arrivals.popleft()
                slot = self.slots.assign(req.request_id)
                req.slot_index = slot.index
                # sequential prefill: feed prompt tokens through decode
                # steps (token-by-token prefill keeps one compiled shape;
                # a chunked prefill path is the serving hillclimb)
                self._prefill(req, slot)
                self._active[slot.index] = req
                made = True
        return made

    def _prefill(self, req: GenRequest, slot) -> None:
        # writes the prompt into the slot's cache; last logits start decode
        cache = self.slots.cache
        for tok in req.prompt[:-1]:
            tokens = self._token_batch(slot.index, int(tok))
            pos = self.slots.positions()
            _, cache = self._jit_decode(self.params, cache, tokens, pos)
            slot.pos += 1
        self.slots.cache = cache
        req.out_tokens = []
        req.next_input = int(req.prompt[-1])

    def _token_batch(self, slot_index: int, token: int):
        toks = np.zeros((self.batch_slots, 1), np.int32)
        toks[slot_index, 0] = token
        return jnp.asarray(toks)

    # -- fused decode loop ---------------------------------------------------
    def _decode_poll(self, thing) -> str:
        if self._decode_inflight is None:
            if not self._active:
                return NOPROGRESS          # idle; keep polling
            toks = np.zeros((self.batch_slots, 1), np.int32)
            for idx, req in self._active.items():
                toks[idx, 0] = req.next_input
            pos = self.slots.positions()
            logits, cache = self._jit_decode(
                self.params, self.slots.cache, jnp.asarray(toks), pos)
            self._decode_inflight = (logits, cache)
            return NOPROGRESS
        logits, cache = self._decode_inflight
        if not logits.is_ready():
            return NOPROGRESS              # device still busy — no block
        self._decode_inflight = None
        self.slots.cache = cache
        self.steps += 1
        next_ids = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        finished = []
        for idx, req in list(self._active.items()):
            tok = int(next_ids[idx])
            if req.first_token_at is None:
                req.first_token_at = time.monotonic()
            req.out_tokens.append(tok)
            req.next_input = tok
            self.slots.slots[idx].pos += 1
            if (len(req.out_tokens) >= req.max_new_tokens
                    or self.slots.slots[idx].pos >= self.max_seq - 1):
                finished.append(idx)
        for idx in finished:
            req = self._active.pop(idx)
            req.finished_at = time.monotonic()
            self.slots.release(self.slots.slots[idx])
            req.done_req.complete(req.out_tokens)
        return NOPROGRESS                  # perpetual task

    # -- convenience ---------------------------------------------------------
    def run_until_idle(self, timeout: float = 120.0) -> None:
        t0 = time.monotonic()
        while self._active or self._arrivals:
            self.engine.progress()
            if time.monotonic() - t0 > timeout:
                raise TimeoutError("serve engine did not drain")
