"""Paged slot-based KV cache for continuous batching.

Fixed pool of B slots, each a row of the model cache (batch dim).  The
serving engine assigns arriving requests to free slots; decode steps run
over all active slots with per-slot positions (ragged lengths handled by
the masked decode attention).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry


@dataclasses.dataclass
class Slot:
    index: int
    request_id: Optional[str] = None
    pos: int = 0              # next write position == #valid tokens
    done: bool = True


class SlotCache:
    def __init__(self, cfg, batch_slots: int, max_seq: int):
        self.cfg = cfg
        self.max_seq = max_seq
        self.cache = registry.init_cache(cfg, batch_slots, max_seq)
        self.slots = [Slot(i) for i in range(batch_slots)]

    def free_slots(self) -> list[Slot]:
        return [s for s in self.slots if s.done]

    def assign(self, request_id: str) -> Optional[Slot]:
        free = self.free_slots()
        if not free:
            return None
        slot = free[0]
        slot.request_id = request_id
        slot.pos = 0
        slot.done = False
        return slot

    def release(self, slot: Slot) -> None:
        slot.request_id = None
        slot.done = True
        slot.pos = 0

    def positions(self) -> jnp.ndarray:
        return jnp.asarray([s.pos for s in self.slots], jnp.int32)

    def active_mask(self) -> np.ndarray:
        return np.array([not s.done for s in self.slots])
