"""KV cache backends for the serving engine: fixed slots and paged blocks.

Two cache disciplines share one lane-oriented interface (``slots``,
``assign``/``release``, ``positions``, ``cache``):

* ``SlotCache`` — the fixed-slot baseline: B monolithic rows of the
  model cache, one request per row.  Memory for a request is ``max_seq``
  positions regardless of its actual length, so concurrency is capped at
  B *and* every admitted request pays the worst case.
* ``PagedKVCache`` — a fixed pool of fixed-size KV *blocks* plus a
  free-list ``BlockAllocator``.  A request owns only the blocks its
  sequence actually touches (its *block table* maps logical block k to a
  physical pool index), so the same bytes admit far more concurrent
  requests; the serve engine preempts under block pressure instead of
  rejecting at admission.

Physical block 0 is reserved as a scratch block: idle decode lanes point
their whole table at it, so the fused decode step's unconditional
scatter-at-``pos`` lands somewhere harmless.  The masked decode
attention never reads a position ``> pos``, and sequential writes mean a
freshly extended block is only ever read at offsets that were just
written — stale bytes in recycled blocks are unreachable.

With a ``mesh`` the pool is placed replicated across the mesh devices at
init (model-axis-sharded serving): every decode step donates and returns
the pool in place, keeping the steady state free of per-step host→device
transfers and resharding.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import registry


@dataclasses.dataclass
class Slot:
    index: int
    request_id: Optional[str] = None
    pos: int = 0              # next write position == #valid tokens
    done: bool = True


class SlotCache:
    """Fixed-slot cache: one monolithic ``max_seq`` row per request.

    Free slots are tracked in a min-heap (``assign`` is O(log B), not a
    linear scan) and live request ids in a dict, so assigning an id that
    is already resident raises instead of silently occupying two slots
    with the same stream (the duplicate would shadow the first at
    detokenize and leak its slot forever).
    """

    def __init__(self, cfg, batch_slots: int, max_seq: int, mesh=None):
        self.cfg = cfg
        self.max_seq = max_seq
        self.mesh = mesh
        self.cache = registry.init_cache(cfg, batch_slots, max_seq)
        if mesh is not None:
            self.cache = jax.device_put(self.cache,
                                        NamedSharding(mesh, P()))
        self.slots = [Slot(i) for i in range(batch_slots)]
        self._free_heap = list(range(batch_slots))   # already sorted
        self._by_request: dict[str, Slot] = {}

    @property
    def free_count(self) -> int:
        return len(self._free_heap)

    def free_slots(self) -> list[Slot]:
        return [self.slots[i] for i in sorted(self._free_heap)]

    def assign(self, request_id: str) -> Optional[Slot]:
        if request_id in self._by_request:
            raise ValueError(
                f"request_id {request_id!r} is already assigned to slot "
                f"{self._by_request[request_id].index}")
        if not self._free_heap:
            return None
        slot = self.slots[heapq.heappop(self._free_heap)]
        slot.request_id = request_id
        slot.pos = 0
        slot.done = False
        self._by_request[request_id] = slot
        return slot

    def release(self, slot: Slot) -> None:
        if slot.request_id is not None:
            self._by_request.pop(slot.request_id, None)
        slot.request_id = None
        slot.done = True
        slot.pos = 0
        heapq.heappush(self._free_heap, slot.index)

    def positions(self) -> jnp.ndarray:
        return jnp.asarray([s.pos for s in self.slots], jnp.int32)

    def active_mask(self) -> np.ndarray:
        return np.array([not s.done for s in self.slots])

    def active_count(self) -> int:
        return len(self.slots) - len(self._free_heap)


class BlockAllocationError(RuntimeError):
    """Misuse of the allocator (double alloc, freeing foreign blocks)."""


class BlockAllocator:
    """Free-list allocator over a fixed pool of KV blocks.

    Blocks are identified by their physical pool index; index 0 is
    reserved (the scratch block) and never handed out.  Each owner
    (request id) holds an ordered list of blocks — its block table.

    Invariants (property-tested in tests/test_paged_kvcache.py):
      * a physical block is owned by at most one request at a time;
      * ``len(free) + sum(owned) == num_blocks - 1`` always;
      * block tables of live requests never alias;
      * allocating for an id that already owns blocks raises (the
        SlotCache duplicate-request invariant, carried over).

    Out-of-memory is a *signal*, not an error: ``alloc``/``extend``
    return ``None`` when the pool cannot satisfy the request, and the
    caller (the serve scheduler) reacts — defer admission, or preempt a
    victim and retry.
    """

    RESERVED = 1        # physical block 0 = scratch

    def __init__(self, num_blocks: int):
        if num_blocks < self.RESERVED + 1:
            raise ValueError(f"need at least {self.RESERVED + 1} blocks, "
                             f"got {num_blocks}")
        self.num_blocks = num_blocks
        self._free = list(range(self.RESERVED, num_blocks))  # min-heap
        heapq.heapify(self._free)
        self._owned: dict[str, list[int]] = {}

    # -- introspection ----------------------------------------------------
    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def usable_blocks(self) -> int:
        return self.num_blocks - self.RESERVED

    def owners(self) -> list[str]:
        return list(self._owned)

    def blocks_of(self, request_id: str) -> list[int]:
        return list(self._owned.get(request_id, ()))

    # -- alloc / extend / free --------------------------------------------
    def alloc(self, request_id: str, n: int) -> Optional[list[int]]:
        """Allocate ``n`` blocks for a new owner; ``None`` if the pool
        cannot satisfy it (nothing is allocated partially)."""
        if request_id in self._owned:
            raise BlockAllocationError(
                f"{request_id!r} already owns {len(self._owned[request_id])} "
                f"blocks — free before re-allocating")
        if n < 1:
            raise ValueError(f"alloc needs n >= 1, got {n}")
        if n > len(self._free):
            return None
        blocks = [heapq.heappop(self._free) for _ in range(n)]
        self._owned[request_id] = blocks
        return list(blocks)

    def extend(self, request_id: str, n: int = 1) -> Optional[list[int]]:
        """Append ``n`` more blocks to an existing owner's table;
        ``None`` on OOM (the preemption trigger)."""
        if request_id not in self._owned:
            raise BlockAllocationError(f"{request_id!r} owns no blocks")
        if n < 1:
            raise ValueError(f"extend needs n >= 1, got {n}")
        if n > len(self._free):
            return None
        blocks = [heapq.heappop(self._free) for _ in range(n)]
        self._owned[request_id].extend(blocks)
        return list(blocks)

    def free(self, request_id: str) -> int:
        """Return ALL of an owner's blocks to the free list."""
        blocks = self._owned.pop(request_id, None)
        if blocks is None:
            raise BlockAllocationError(f"{request_id!r} owns no blocks")
        for b in blocks:
            heapq.heappush(self._free, b)
        return len(blocks)


@dataclasses.dataclass
class Lane:
    """One row of the fused decode batch.  A lane is compute residency
    (a seat in the [B, ...] decode step); KV memory residency is the
    block table behind it."""
    index: int
    request_id: Optional[str] = None
    pos: int = 0
    done: bool = True


class PagedKVCache:
    """Paged KV pool + decode-lane bookkeeping.

    Mirrors the ``SlotCache`` surface the serve engine consumes
    (``slots``/``cache``/``positions``/``release``/``free_slots``) and
    adds the paged pieces: per-request block tables
    (``block_tables()`` → ``[lanes, max_blocks]`` int32, scratch-0 for
    unallocated entries), ``assign(request_id, seq_len)`` which reserves
    the blocks the sequence's prefill will touch, and
    ``ensure(lane_index, pos)`` which lazily extends the table one block
    at a time as decode advances (``False`` = pool exhausted: the
    caller's preemption trigger).

    Families without positional KV (pure SSM) have ``has_blocks=False``:
    their cache is O(1) per lane, every block op is a no-op, and
    ``reset_lane`` zeroes the recurrent state at assignment instead.
    """

    def __init__(self, cfg, lanes: int, max_seq: int, *,
                 block_size: int = 16, num_blocks: int | None = None,
                 mesh=None):
        if not registry.supports_paged(cfg):
            raise ValueError(
                f"paged serving not supported for family {cfg.family!r}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.cfg = cfg
        self.max_seq = max_seq
        self.block_size = block_size
        self.max_blocks = -(-max_seq // block_size)       # ceil
        self.has_blocks = registry.paged_has_blocks(cfg)
        if num_blocks is None:
            # full backing: every lane can hold max_seq (same capacity as
            # SlotCache; pressure — and preemption — require an explicit
            # smaller pool)
            num_blocks = lanes * self.max_blocks + BlockAllocator.RESERVED
        self.num_blocks = num_blocks
        self.allocator = BlockAllocator(num_blocks)
        if self.has_blocks and self.allocator.usable_blocks < self.max_blocks:
            raise ValueError(
                f"pool of {num_blocks} blocks cannot hold one max_seq="
                f"{max_seq} request ({self.max_blocks} blocks of "
                f"{block_size}) — a lone request would deadlock")
        self.mesh = mesh
        self.cache = registry.init_paged_cache(cfg, lanes, num_blocks,
                                               block_size)
        if mesh is not None:
            self.cache = jax.device_put(self.cache,
                                        NamedSharding(mesh, P()))
        self.slots = [Lane(i) for i in range(lanes)]
        self._free_heap = list(range(lanes))
        self._by_request: dict[str, Lane] = {}
        self._tables = np.zeros((lanes, self.max_blocks), np.int32)

    # -- lane surface (SlotCache-compatible) -------------------------------
    @property
    def free_count(self) -> int:
        return len(self._free_heap)

    def free_slots(self) -> list[Lane]:
        return [self.slots[i] for i in sorted(self._free_heap)]

    def positions(self) -> jnp.ndarray:
        return jnp.asarray([s.pos for s in self.slots], jnp.int32)

    def active_mask(self) -> np.ndarray:
        return np.array([not s.done for s in self.slots])

    def active_count(self) -> int:
        return len(self.slots) - len(self._free_heap)

    # -- paged assignment --------------------------------------------------
    def blocks_for(self, seq_len: int) -> int:
        """Blocks the prefill of a ``seq_len``-token sequence (plus the
        first decode write at position seq_len-1) will touch."""
        if not self.has_blocks:
            return 0
        return max(1, -(-seq_len // self.block_size))

    def assign(self, request_id: str, seq_len: int = 1) -> Optional[Lane]:
        """Claim a lane AND the blocks its prefill needs; ``None`` if
        either is unavailable (nothing is claimed partially)."""
        if request_id in self._by_request:
            raise ValueError(
                f"request_id {request_id!r} is already assigned to lane "
                f"{self._by_request[request_id].index}")
        if seq_len > self.max_seq:
            raise ValueError(f"seq_len {seq_len} exceeds max_seq "
                             f"{self.max_seq}")
        if not self._free_heap:
            return None
        if self.has_blocks:
            blocks = self.allocator.alloc(request_id,
                                          self.blocks_for(seq_len))
            if blocks is None:
                return None
        else:
            blocks = []
        lane = self.slots[heapq.heappop(self._free_heap)]
        lane.request_id = request_id
        lane.pos = 0
        lane.done = False
        self._by_request[request_id] = lane
        self._tables[lane.index, :] = 0
        for k, b in enumerate(blocks):
            self._tables[lane.index, k] = b
        return lane

    def ensure(self, lane_index: int, pos: int) -> bool:
        """Make sure the block holding position ``pos`` is allocated for
        the lane's request; ``False`` = pool exhausted (preempt or
        stall).  Decode advances one position at a time, so at most one
        new block is needed per call."""
        if not self.has_blocks:
            return True
        lane = self.slots[lane_index]
        if lane.done:
            raise BlockAllocationError(f"lane {lane_index} is free")
        need = pos // self.block_size
        owned = self.allocator.blocks_of(lane.request_id)
        if need < len(owned):
            return True
        if need >= self.max_blocks:
            raise BlockAllocationError(
                f"position {pos} exceeds lane capacity "
                f"{self.max_blocks * self.block_size}")
        new = self.allocator.extend(lane.request_id, need - len(owned) + 1)
        if new is None:
            return False
        for k, b in enumerate(new):
            self._tables[lane_index, len(owned) + k] = b
        return True

    def release(self, lane: Lane) -> None:
        """Free the lane and every block behind it (the preemption /
        completion / failure path all route through here, so blocks can
        never leak)."""
        if lane.request_id is not None:
            self._by_request.pop(lane.request_id, None)
            if self.has_blocks and self.allocator.blocks_of(lane.request_id):
                self.allocator.free(lane.request_id)
        lane.request_id = None
        lane.done = True
        lane.pos = 0
        self._tables[lane.index, :] = 0
        heapq.heappush(self._free_heap, lane.index)

    def block_tables(self) -> jnp.ndarray:
        """Current tables as a device array [lanes, max_blocks] int32 —
        one argument of the fused paged decode step."""
        return jnp.asarray(self._tables)

    def reset_lane(self, cache, lane_index: int):
        """Zero a lane's per-lane (non-block) state in ``cache`` before
        prefill — recurrent SSM state survives release (there are no
        blocks to recycle), so a recycled lane must not leak its previous
        occupant's state into the next request."""
        return registry.reset_paged_lane(self.cfg, cache, lane_index)

    # -- per-lane checkpoint / restore (KV migration) ----------------------
    # Leaf classification is by shape against the pool geometry: a leaf
    # whose dims 1/2 are (num_blocks, block_size) is block-pooled KV
    # (transformer k/v + scales, hybrid attn_k/attn_v); a leaf whose dim 1
    # is the lane count is lane-indexed recurrent state (mamba/hybrid
    # ssm).  Block leaves are checked first so a coincidental
    # lanes == num_blocks match cannot misfile pooled KV.

    def _is_block_leaf(self, leaf) -> bool:
        return (self.has_blocks and leaf.ndim >= 3
                and leaf.shape[1] == self.num_blocks
                and leaf.shape[2] == self.block_size)

    def _is_lane_leaf(self, leaf) -> bool:
        return leaf.ndim >= 2 and leaf.shape[1] == len(self.slots)

    def checkpoint_lane(self, lane_index: int) -> dict:
        """Snapshot one lane's KV prefix + per-lane state to host memory.

        Walks the block table: for pooled leaves, gathers the lane's
        owned physical blocks (positions ``0..pos-1`` live in the first
        ``ceil(pos/block_size)`` table entries); for lane-indexed leaves,
        captures the lane's row.  The result is mesh-independent (plain
        numpy) so a membership change can carry a decoding request's KV
        onto a cache rebuilt for the surviving mesh instead of replaying
        its whole prefix."""
        lane = self.slots[lane_index]
        if lane.done:
            raise BlockAllocationError(f"lane {lane_index} is free")
        pos = lane.pos
        used = -(-pos // self.block_size) if (self.has_blocks and pos) else 0
        table = self._tables[lane_index, :used].copy()
        blocks: dict[str, np.ndarray] = {}
        state: dict[str, np.ndarray] = {}
        for path, leaf in jax.tree_util.tree_flatten_with_path(self.cache)[0]:
            key = jax.tree_util.keystr(path)
            if self._is_block_leaf(leaf):
                if used:
                    blocks[key] = np.asarray(leaf[:, table])
            elif self._is_lane_leaf(leaf):
                state[key] = np.asarray(leaf[:, lane_index])
        return {"pos": pos, "blocks": blocks, "state": state}

    def restore_lane(self, cache, lane_index: int, ckpt: dict):
        """Write a ``checkpoint_lane`` snapshot into this pool's ``cache``
        for an already-``assign``ed lane (whose table must cover
        ``ckpt['pos']`` positions — ``assign(request_id, seq_len=pos+1)``
        guarantees that).  Returns the updated cache; the caller owns
        publishing it and setting engine-side positions."""
        lane = self.slots[lane_index]
        if lane.done:
            raise BlockAllocationError(f"lane {lane_index} is free")
        pos = int(ckpt["pos"])
        used = -(-pos // self.block_size) if (self.has_blocks and pos) else 0
        if used and used > len(self.allocator.blocks_of(lane.request_id)):
            raise BlockAllocationError(
                f"lane {lane_index} owns too few blocks to restore "
                f"{pos} positions")
        table = jnp.asarray(self._tables[lane_index, :used]) if used else None
        leaves, treedef = jax.tree_util.tree_flatten_with_path(cache)
        out = []
        for path, leaf in leaves:
            key = jax.tree_util.keystr(path)
            if used and key in ckpt["blocks"]:
                leaf = leaf.at[:, table].set(
                    jnp.asarray(ckpt["blocks"][key], leaf.dtype))
            elif key in ckpt["state"]:
                leaf = leaf.at[:, lane_index].set(
                    jnp.asarray(ckpt["state"][key], leaf.dtype))
            out.append(leaf)
        lane.pos = pos
        return jax.tree_util.tree_unflatten(treedef, out)
