"""Paged slot-based KV cache for continuous batching.

Fixed pool of B slots, each a row of the model cache (batch dim).  The
serving engine assigns arriving requests to free slots; decode steps run
over all active slots with per-slot positions (ragged lengths handled by
the masked decode attention).

With a ``mesh`` the cache is placed replicated across the mesh devices
at init (model-axis-sharded serving): every decode step donates and
returns the cache in place, so fixing the layout once keeps the steady
state free of per-step host→device transfers and resharding.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import registry


@dataclasses.dataclass
class Slot:
    index: int
    request_id: Optional[str] = None
    pos: int = 0              # next write position == #valid tokens
    done: bool = True


class SlotCache:
    def __init__(self, cfg, batch_slots: int, max_seq: int, mesh=None):
        self.cfg = cfg
        self.max_seq = max_seq
        self.mesh = mesh
        self.cache = registry.init_cache(cfg, batch_slots, max_seq)
        if mesh is not None:
            self.cache = jax.device_put(self.cache,
                                        NamedSharding(mesh, P()))
        self.slots = [Slot(i) for i in range(batch_slots)]

    def free_slots(self) -> list[Slot]:
        return [s for s in self.slots if s.done]

    def assign(self, request_id: str) -> Optional[Slot]:
        free = self.free_slots()
        if not free:
            return None
        slot = free[0]
        slot.request_id = request_id
        slot.pos = 0
        slot.done = False
        return slot

    def release(self, slot: Slot) -> None:
        slot.request_id = None
        slot.done = True
        slot.pos = 0

    def positions(self) -> jnp.ndarray:
        return jnp.asarray([s.pos for s in self.slots], jnp.int32)

    def active_mask(self) -> np.ndarray:
        return np.array([not s.done for s in self.slots])

    def active_count(self) -> int:
        return sum(1 for s in self.slots if not s.done)
