"""int8 weight quantization for serving.

Decode is weight-read-bound (EXPERIMENTS §Perf C): per token step every
parameter is streamed from HBM once.  Storing the large 2-D+ weight
matrices as per-output-channel int8 with f32 scales halves that stream
vs bf16 (and ×4 vs f32) — XLA fuses the dequantizing convert into the
consuming matmul, so the int8 bytes are what cross HBM.

``quantize_tree`` walks a parameter pytree and replaces eligible leaves
(float, ndim ≥ 2, above a size threshold) with ``QuantizedTensor``
(itself a pytree); ``dequantize_tree`` restores bf16 weights at use.
Quantization error is ~0.4% relative per weight (symmetric 127-level,
per last-axis channel) — standard for serving.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class QuantizedTensor(NamedTuple):
    q: jax.Array          # int8, original shape
    scale: jax.Array      # f32, shape = original with last dim = 1


def quantize_array(w: jax.Array) -> QuantizedTensor:
    wf = w.astype(jnp.float32)
    scale = jnp.max(jnp.abs(wf), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    return QuantizedTensor(q, scale)


def dequantize_array(t: QuantizedTensor, dtype=jnp.bfloat16) -> jax.Array:
    return (t.q.astype(jnp.float32) * t.scale).astype(dtype)


def _eligible(leaf, min_size: int) -> bool:
    return (hasattr(leaf, "dtype") and hasattr(leaf, "ndim")
            and jnp.issubdtype(leaf.dtype, jnp.floating)
            and leaf.ndim >= 2 and leaf.size >= min_size)


def quantize_tree(params: Any, min_size: int = 1 << 16) -> Any:
    """Replace large float matrices with QuantizedTensor leaves."""
    return jax.tree.map(
        lambda p: quantize_array(p) if _eligible(p, min_size) else p, params)


def dequantize_tree(params: Any, dtype=jnp.bfloat16) -> Any:
    return jax.tree.map(
        lambda p: dequantize_array(p, dtype) if isinstance(p, QuantizedTensor) else p,
        params, is_leaf=lambda x: isinstance(x, QuantizedTensor))


def quantized_bytes(params: Any) -> int:
    total = 0
    for leaf in jax.tree.leaves(params):
        total += leaf.size * leaf.dtype.itemsize
    return total


def quantized_shapes(param_shapes: Any, min_size: int = 1 << 16) -> Any:
    """ShapeDtypeStruct version for the dry-run (no allocation)."""
    def one(p):
        if _eligible(p, min_size):
            return QuantizedTensor(
                jax.ShapeDtypeStruct(p.shape, jnp.int8),
                jax.ShapeDtypeStruct(p.shape[:-1] + (1,), jnp.float32))
        return p
    return jax.tree.map(one, param_shapes)


def quantized_axes(param_axes: Any, param_shapes: Any, min_size: int = 1 << 16) -> Any:
    """Logical-axes tree matching quantize_tree's structure."""
    def one(axes, p):
        if _eligible(p, min_size):
            return QuantizedTensor(axes, axes[:-1] + (None,))
        return axes
    return jax.tree.map(
        one, param_axes, param_shapes,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(a, (str, type(None))) for a in x))
