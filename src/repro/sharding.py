"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Model code annotates every parameter and key activation with *logical*
axis names (``"embed"``, ``"heads"``, ``"vocab"`` …).  A rule table maps
each logical axis to an ordered list of candidate mesh-axis assignments;
at resolution time the first candidate whose mesh-axis-size product
divides the actual dimension is chosen, otherwise the dim is replicated.

This is what lets a single model definition serve a 1-device smoke test,
a 256-chip pod, and a 512-chip multi-pod mesh without edits: a 14-head
attention block simply degrades to replicated heads on a 16-way tensor
axis, while the 128-head block shards 8-ways.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

# Candidate mesh assignments per logical axis, in priority order.  Each
# candidate is a tuple of mesh axis names (composed axes) or () for
# "replicate".  "fsdp" axes shard parameters/optimizer state ZeRO-style.
MeshAxes = tuple[str, ...]
Rules = Mapping[str, Sequence[MeshAxes]]

# Default production rules for a ("pod", "data", "model") mesh.
DEFAULT_RULES: Rules = {
    # --- parameter / activation axes ---
    "embed":      (("pod", "data"), ("data",), ()),   # FSDP shard dim
    "embed_nofsdp": ((),),                             # replicated variant
    "mlp":        (("model",), ()),
    "heads":      (("model",), ()),
    "kv_heads":   (("model",), ()),
    "head_dim":   ((),),
    "qkv":        (("model",), ()),
    "vocab":      (("model",), ()),
    "experts":    (("model",), ()),
    "expert_mlp": (("model",), ()),
    "state":      ((),),                               # SSM state dim
    "conv":       ((),),
    "layers":     ((),),                               # scan axis
    # --- batch/sequence activation axes ---
    "batch":      (("pod", "data"), ("data",), ()),
    "act_seq":    ((),),                               # sequence (activations)
    "cache_seq":  (("model",), ()),                    # KV-cache sequence
    "cache_batch": (("pod", "data"), ("data",), ()),   # KV-cache batch rows
    "act_embed":  ((),),
    "act_heads":  (("model",), ()),
    "act_kv_heads": (("model",), ()),
    "act_mlp":    (("model",), ()),
    "act_vocab":  (("model",), ()),
    "act_experts": (("model",), ()),
    "expert_cap": (("model",), ()),                    # MoE capacity dim
    "act_expert_mlp": (("model",), ()),
    "moe_groups": (("pod", "data"), ("data",), ()),    # MoE token groups
    "frames":     ((),),                               # audio/vision frontend
}

_local = threading.local()


def current_rules() -> Rules:
    return getattr(_local, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def axis_rules(rules: Rules):
    """Override the logical→mesh rule table within a scope."""
    prev = getattr(_local, "rules", DEFAULT_RULES)
    _local.rules = rules
    try:
        yield
    finally:
        _local.rules = prev


def merged_rules(overrides: Mapping[str, Sequence[MeshAxes]] | None) -> Rules:
    if not overrides:
        return dict(DEFAULT_RULES)
    out = dict(DEFAULT_RULES)
    out.update(overrides)
    return out


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------

def _mesh_axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def resolve_spec(
    logical_axes: Sequence[str | None],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Rules | None = None,
) -> P:
    """Resolve logical axes for a concrete shape into a PartitionSpec.

    Falls back to replication for any dim the preferred mesh axes do not
    divide, and never assigns the same mesh axis to two dims.
    """
    rules = rules or current_rules()
    shape = tuple(getattr(shape, "shape", shape))
    assert len(logical_axes) == len(shape), (logical_axes, shape)
    used: set[str] = set()
    parts: list = []
    for name, dim in zip(logical_axes, shape):
        if name is None:
            parts.append(None)
            continue
        candidates = rules.get(name)
        if candidates is None:
            raise KeyError(f"no sharding rule for logical axis {name!r}")
        chosen: MeshAxes = ()
        for cand in candidates:
            if any(a in used for a in cand):
                continue
            if any(a not in mesh.shape for a in cand):
                continue
            size = _mesh_axis_size(mesh, cand)
            if size == 1 or (dim % size == 0 and size > 1):
                chosen = cand
                break
        if chosen:
            used.update(chosen)
            parts.append(chosen if len(chosen) > 1 else chosen[0])
        else:
            parts.append(None)
    # trim trailing Nones (canonical form)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def logical_sharding(
    logical_axes: Sequence[str | None],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Rules | None = None,
) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(logical_axes, shape, mesh, rules))


def shard_hint(x: jax.Array, *logical_axes: str | None):
    """Apply a with_sharding_constraint for logical axes, if a mesh is set.

    Outside a ``jax.set_mesh`` context (e.g. plain CPU unit tests) this is
    a no-op, so model code can be written once.
    """
    mesh = _abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = resolve_spec(logical_axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, spec)


def _abstract_mesh():
    try:
        from repro import compat
        return compat.current_mesh()
    except Exception:
        return None


# ---------------------------------------------------------------------------
# Pytree helpers
# ---------------------------------------------------------------------------

def tree_shardings(tree_axes, tree_shapes, mesh: Mesh, rules: Rules | None = None):
    """Map a pytree of logical-axis tuples + a matching pytree of shapes
    to a pytree of NamedShardings."""
    return jax.tree.map(
        lambda axes, shape: logical_sharding(axes, shape, mesh, rules),
        tree_axes,
        tree_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )


def spec_tree(tree_axes, tree_shapes, mesh: Mesh, rules: Rules | None = None):
    return jax.tree.map(
        lambda axes, shape: resolve_spec(axes, shape, mesh, rules),
        tree_axes,
        tree_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )
