"""Asynchronous checkpointing driven by the progress engine.

A checkpoint save is EXACTLY the paper's Figure 1(c) multi-wait-block
task: (1) device→host copy (wait on the runtime), (2) serialize+write
(wait on storage I/O), (3) fsync+atomic-commit rename (wait again).
Without progress between the stages, stage 2 would not launch until
someone blocks on the checkpoint — the paper's "missed overlap".  Here
every stage advances from the engine's poll loop while training computes.

Fault-tolerance contract:
* writes go to ``step_N.tmp/`` and are atomically renamed to ``step_N/``
  only after every shard file is fsynced — a crash mid-save never
  corrupts the latest checkpoint;
* ``latest_step`` only ever sees committed directories;
* ``restore`` can reshard onto a different mesh (elastic restart) since
  files store the full (unsharded) arrays.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np

from repro.core.engine import DONE, NOPROGRESS, ProgressEngine, Stream
from repro.core.futures import io_pool
from repro.core.request import Request


def _flat_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out


class AsyncCheckpointer:
    """Engine-driven async checkpoint save/restore."""

    def __init__(self, directory: str, engine: ProgressEngine,
                 stream: Optional[Stream] = None, keep: int = 3):
        self.dir = directory
        self.engine = engine
        self.stream = stream
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save_async(self, step: int, tree: Any) -> Request:
        """Returns a Request completing at atomic commit."""
        req = Request(tag=f"ckpt-{step}")
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        leaves = _flat_with_paths(tree)
        state = {"phase": "d2h", "futs": None, "copied": None}

        # stage 1 launch: start non-blocking device→host copies
        for _, leaf in leaves:
            if hasattr(leaf, "copy_to_host_async"):
                leaf.copy_to_host_async()

        def poll(thing) -> str:
            if state["phase"] == "d2h":
                if all(not hasattr(leaf, "is_ready") or leaf.is_ready()
                       for _, leaf in leaves):
                    def write():
                        os.makedirs(tmp, exist_ok=True)
                        manifest = {}
                        for name, leaf in leaves:
                            arr = np.asarray(leaf)
                            fname = name.replace("/", "__") + ".npy"
                            with open(os.path.join(tmp, fname), "wb") as f:
                                np.save(f, arr)
                                f.flush()
                                os.fsync(f.fileno())
                            manifest[name] = fname
                        with open(os.path.join(tmp, "manifest.json"), "w") as f:
                            json.dump({"step": step, "leaves": manifest}, f)
                            f.flush()
                            os.fsync(f.fileno())
                    state["futs"] = io_pool().submit(write)
                    state["phase"] = "write"
                return NOPROGRESS
            if state["phase"] == "write":
                if state["futs"].done():
                    exc = state["futs"].exception()
                    if exc is not None:
                        req.fail(exc)
                        return DONE
                    # stage 3: atomic commit
                    if os.path.exists(final):
                        shutil.rmtree(final)
                    os.rename(tmp, final)
                    self._gc()
                    req.complete(step)
                    return DONE
                return NOPROGRESS
            return NOPROGRESS

        self.engine.async_start(poll, None, self.stream)
        return req

    def save_blocking(self, step: int, tree: Any) -> int:
        req = self.save_async(step, tree)
        return self.engine.wait(req, self.stream, timeout=600)

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp") \
                    and os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore onto the current device set; `shardings` (optional
        pytree of NamedSharding) reshards for elastic restart."""
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)["leaves"]
        names = [name for name, _ in _flat_with_paths(like)]
        leaves_like, treedef = jax.tree.flatten(like)
        shard_flat = (jax.tree.leaves(shardings)
                      if shardings is not None else [None] * len(leaves_like))
        out = []
        for name, leaf_like, sh in zip(names, leaves_like, shard_flat):
            arr = np.load(os.path.join(path, manifest[name].replace("/", "__")))
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.device_put(arr.astype(leaf_like.dtype)))
        return jax.tree.unflatten(treedef, out)

    def _gc(self):
        steps = sorted(s for s in (self.latest_step(),) if s is not None)
        all_steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in all_steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)
