"""Loss functions, including the vocab-chunked cross entropy.

The naive LM loss materializes f32 logits [B, S, V] (for qwen2's 152k
vocab at B·S = 64k tokens/device that is 39 GB).  The chunked form scans
over vocab blocks computing a running (max, sum-exp, gold-logit) triple —
the online-softmax trick applied to the unembedding — so peak memory is
[B, S, V_chunk].  Backward recomputes per chunk (custom VJP), trading
~1 extra unembed matmul for the 1/n_chunks activation footprint.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def plain_xent(logits, labels):
    """logits [B,S,V] f32; labels [B,S] -> mean nll."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def chunked_vocab_xent(x, table, labels, chunk: int = 8192,
                       transpose_table: bool = False):
    """mean nll of softmax(x @ table) without materializing full logits.

    x: [B,S,D] (final hidden states, any float dtype);
    table: [V,D] (tied embeddings) or [D,V] if transpose_table;
    labels: [B,S] int32.
    """
    nll, _, _ = _chunk_forward(x, table, labels, chunk, transpose_table)
    return nll


def _vchunks(table, chunk, transpose_table):
    V = table.shape[-1] if transpose_table else table.shape[0]
    chunk = min(chunk, V)
    n = (V + chunk - 1) // chunk
    return V, chunk, n


def _pad_table(table, chunk, n, V, transpose_table):
    """Pad the vocab dim to n·chunk so dynamic_slice never clamps."""
    pad = n * chunk - V
    if pad == 0:
        return table
    cfgpad = [(0, 0), (0, pad)] if transpose_table else [(0, pad), (0, 0)]
    return jnp.pad(table, cfgpad)


def _logits_chunk(x, table, start, chunk, transpose_table):
    if transpose_table:
        t = jax.lax.dynamic_slice_in_dim(table, start, chunk, axis=1)
        return jnp.einsum("bsd,dv->bsv", x, t.astype(x.dtype)).astype(jnp.float32)
    t = jax.lax.dynamic_slice_in_dim(table, start, chunk, axis=0)
    return jnp.einsum("bsd,vd->bsv", x, t.astype(x.dtype)).astype(jnp.float32)


def _chunk_forward(x, table, labels, chunk, transpose_table):
    V, chunk, n = _vchunks(table, chunk, transpose_table)
    table = _pad_table(table, chunk, n, V, transpose_table)
    B, S, _ = x.shape

    def body(carry, i):
        m, s, gold = carry
        start = i * chunk
        lg = _logits_chunk(x, table, start, chunk, transpose_table)
        # mask out-of-range rows of the (possibly padded) final chunk
        vids = start + jnp.arange(chunk)
        lg = jnp.where(vids[None, None, :] < V, lg, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(lg, axis=-1))
        s = s * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(lg - m_new[..., None]), axis=-1)
        in_chunk = jnp.logical_and(labels >= start, labels < start + chunk)
        idx = jnp.clip(labels - start, 0, chunk - 1)
        g = jnp.take_along_axis(lg, idx[..., None], axis=-1)[..., 0]
        gold = jnp.where(in_chunk, g, gold)
        return (m_new, s, gold), None

    m0 = jnp.full((B, S), -jnp.inf, jnp.float32)
    s0 = jnp.zeros((B, S), jnp.float32)
    g0 = jnp.zeros((B, S), jnp.float32)
    (m, s, gold), _ = jax.lax.scan(body, (m0, s0, g0), jnp.arange(n))
    lse = m + jnp.log(s)
    nll = jnp.mean(lse - gold)
    return nll, lse, (m, s)


def _fwd(x, table, labels, chunk, transpose_table):
    nll, lse, _ = _chunk_forward(x, table, labels, chunk, transpose_table)
    return nll, (x, table, labels, lse)


def _bwd(chunk, transpose_table, res, dnll):
    x, table, labels, lse = res
    V, chunk_, n = _vchunks(table, chunk, transpose_table)
    orig_shape = table.shape
    table = _pad_table(table, chunk_, n, V, transpose_table)
    B, S, _ = x.shape
    scale = dnll / (B * S)

    def body(carry, i):
        dx, dt = carry
        start = i * chunk_
        lg = _logits_chunk(x, table, start, chunk_, transpose_table)
        vids = start + jnp.arange(chunk_)
        p = jnp.exp(lg - lse[..., None])
        p = jnp.where(vids[None, None, :] < V, p, 0.0)
        onehot = (labels[..., None] == vids[None, None, :]).astype(jnp.float32)
        dlg = (p - onehot) * scale                      # [B,S,chunk]
        if transpose_table:
            t = jax.lax.dynamic_slice_in_dim(table, start, chunk_, axis=1)
            dx = dx + jnp.einsum("bsv,dv->bsd", dlg, t.astype(jnp.float32))
            dt_blk = jnp.einsum("bsd,bsv->dv", x.astype(jnp.float32), dlg)
            dt = jax.lax.dynamic_update_slice_in_dim(
                dt, dt_blk.astype(dt.dtype), start, axis=1)
        else:
            t = jax.lax.dynamic_slice_in_dim(table, start, chunk_, axis=0)
            dx = dx + jnp.einsum("bsv,vd->bsd", dlg, t.astype(jnp.float32))
            dt_blk = jnp.einsum("bsv,bsd->vd", dlg, x.astype(jnp.float32))
            dt = jax.lax.dynamic_update_slice_in_dim(
                dt, dt_blk.astype(dt.dtype), start, axis=0)
        return (dx, dt), None

    dx0 = jnp.zeros(x.shape, jnp.float32)
    dt0 = jnp.zeros(table.shape, jnp.float32)
    (dx, dt), _ = jax.lax.scan(body, (dx0, dt0), jnp.arange(n))
    dt = (dt[:, :orig_shape[1]] if transpose_table
          else dt[:orig_shape[0]])                   # drop padding rows
    return dx.astype(x.dtype), dt.astype(table.dtype), None


chunked_vocab_xent.defvjp(_fwd, _bwd)
