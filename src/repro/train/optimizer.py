"""Sharded AdamW (+ cosine schedule, global-norm clipping).

Optimizer moments are sharded exactly like the parameters (the rule table
puts them on the FSDP axes), which is what makes ZeRO-style training of
the 405B config possible: params+moments are distributed over all chips.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def state_axes(param_axes_tree):
    """Sharding axes for the optimizer state given the param axes tree."""
    return AdamWState(step=(), mu=param_axes_tree, nu=param_axes_tree)


def state_shapes(param_shapes_tree):
    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=param_shapes_tree,
        nu=param_shapes_tree,
    )


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def init_shards(shards) -> AdamWState:
    """Optimizer state over FSDP flat shard buckets: mu/nu are lists
    shaped like the shard stacks (ZeRO — each rank holds moments only
    for the block it owns)."""
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=[jnp.zeros_like(s) for s in shards],
        nu=[jnp.zeros_like(s) for s in shards],
    )


def apply_shards(cfg: AdamWConfig, state: AdamWState, shards, grad_shards,
                 *, axis: str | None = None, grad_scale: float = 1.0):
    """One AdamW step over flat shard buckets (the ZeRO step: each rank
    updates only the parameter block it owns).

    ``shards``/``grad_shards`` are lists of same-shaped local shard
    arrays (under ``shard_map`` each rank sees its own ``[1, W/n]``
    row).  AdamW is elementwise, so flat-bucket math equals per-leaf
    math given the same clip scale and schedule; the one cross-rank
    quantity is the global grad norm, assembled from local
    sum-of-squares with a ``psum`` over ``axis`` (pass None when the
    stacks are resident unsharded).  ``grad_scale`` folds the
    data-parallel mean into the step (reduce-scatter delivers sums).
    Zero-padded bucket tails stay zero: grad 0 keeps mu/nu 0 and weight
    decay multiplies a zero param.

    Returns ``(new_shards, new_state, metrics)``.
    """
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32) * grad_scale))
             for g in grad_shards)
    if axis is not None:
        sq = jax.lax.psum(sq, axis)
    gnorm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * grad_scale * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = [upd(p, g, m, v) for p, g, m, v in
           zip(shards, grad_shards, state.mu, state.nu)]
    new_shards = [o[0] for o in out]
    new_state = AdamWState(step, [o[1] for o in out], [o[2] for o in out])
    return new_shards, new_state, {"grad_norm": gnorm, "lr": lr}


def apply(cfg: AdamWConfig, state: AdamWState, params, grads):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
