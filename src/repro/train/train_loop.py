"""Training loop — every async subsystem hangs off ONE progress engine.

The loop body is the paper's Figure 4(b) pattern, deliberately:

    dispatch step N+1 (nonblocking: jit returns immediately)
    ── while the device runs ──
    engine.progress():  data prefetch fills, checkpoint stages advance,
                        heartbeats/watchdog checked, metrics flush
    block on step N's loss only when needed (jax_future completion)

``jax_future`` + ``Request.is_complete`` replace blocking
``block_until_ready`` calls, so the host never idles inside a wait loop
while there is progress to be made — the computation/communication
overlap story, at the host level.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core import ProgressEngine, ProgressExecutor, global_engine, \
    jax_future
from repro.collectives.nonblocking import CollectiveSpec, MembershipError, \
    spec_from_legacy
from repro.core.request import Request
from repro.distributed.fault_tolerance import StepWatchdog, StragglerDetector
from repro.train import optimizer as opt_mod
from repro.train.checkpoint import AsyncCheckpointer


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    watchdog_limit_s: float = 600.0
    resume: bool = True
    # >0: that many background progress workers drive prefetch/checkpoint/
    # watchdog tasks (§4.4); 0: the overlap window self-progresses as before
    progress_workers: int = 0
    # gradient-reduction configuration: ONE CollectiveSpec covers
    # backend ("native" keeps the reduction inside the jitted step,
    # "user" runs nonblocking user-space collectives on the progress
    # engine — requires a split step, see ``UserCollectiveStep``/
    # ``FsdpStep``), algorithm, chunk count, and round batching.  The
    # collective_* fields below are the deprecated spelling: still
    # accepted for one release (a DeprecationWarning fires once), still
    # readable afterwards (mirrored from the resolved spec).
    collective_spec: "CollectiveSpec | None" = None
    collective_backend: "str | None" = None
    collective_algorithm: "str | None" = None
    collective_chunks: "int | None" = None
    collective_round_batch: "int | None" = None
    # pipeline-parallel schedule this loop runs under ("none", "gpipe",
    # "1f1b") — a record field like collective_spec.backend: the
    # launcher carries the machinery (PipelineSchedule per data row),
    # the config is what logs/stats report
    pipeline: str = "none"

    _DEFAULT_SPEC = CollectiveSpec(backend="native", algorithm="ring",
                                   chunks=4, round_batch=0)

    def __post_init__(self):
        spec = self.collective_spec
        legacy = (("backend", self.collective_backend),
                  ("algorithm", self.collective_algorithm),
                  ("chunks", self.collective_chunks),
                  ("round_batch", self.collective_round_batch))
        if spec is not None:
            # mirrored legacy fields (from a previous resolve, or a
            # dataclasses.replace round-trip) must agree with the spec;
            # a *conflicting* explicit legacy kwarg is a config bug
            for name, val in legacy:
                if val is not None and val != getattr(spec, name):
                    raise ValueError(
                        f"TrainLoopConfig: collective_spec.{name}="
                        f"{getattr(spec, name)!r} conflicts with legacy "
                        f"collective_{name}={val!r}; pass one, not both")
        else:
            spec = spec_from_legacy(
                None, surface="TrainLoopConfig",
                backend=self.collective_backend,
                algorithm=self.collective_algorithm,
                chunks=self.collective_chunks,
                round_batch=self.collective_round_batch,
                default=self._DEFAULT_SPEC)
        self.collective_spec = spec
        self.collective_backend = spec.backend
        self.collective_algorithm = spec.algorithm
        self.collective_chunks = spec.chunks
        self.collective_round_batch = spec.round_batch


@dataclasses.dataclass
class UserCollectiveStep:
    """Split train step for the engine-driven collective backend.

    ``grad_fn(params, batch) -> (stacked_metrics, stacked_grads)`` —
    per-device losses/metrics and gradients stacked on a leading
    axis-size dim (``shard_map`` local grads); ``reducer`` (an
    ``EngineGradReducer``) allreduces the grads on the collective
    stream while the engine also progresses prefetch/checkpoint tasks;
    ``apply_fn(params, opt_state, grads, stacked_metrics) -> (params,
    opt_state, metrics)`` finishes the step.  ``spec`` (a
    :class:`~repro.collectives.nonblocking.CollectiveSpec`) records the
    reduction configuration the reducer was built with — the same
    config object every other surface takes."""
    grad_fn: Callable
    apply_fn: Callable
    reducer: Any
    spec: "CollectiveSpec | None" = None

    def __post_init__(self):
        if self.spec is not None and not isinstance(self.spec,
                                                    CollectiveSpec):
            raise TypeError(
                f"spec must be a CollectiveSpec, got "
                f"{type(self.spec).__name__} (legacy kwargs belong on "
                f"TrainLoopConfig)")


@dataclasses.dataclass
class FsdpStep:
    """Split train step for ZeRO-style FSDP on the user backend.

    Parameters live as *flat shard stacks* (``FsdpLayout.shard_params``
    — one ``[n, W/n]`` array per bucket, rank ``r`` owning row ``r``):

    * ``grad_fn(gathered_flats, batch) -> (stacked_metrics,
      flat_grads)`` — takes the all-gathered full flat buckets
      ``[n, W]``, unflattens *inside* the jitted program, and returns
      per-device metrics plus stacked flat grad buckets ``[n, W]``;
    * ``reducer`` (an :class:`~repro.collectives.overlap.FsdpReducer`)
      reduce-scatters the grad buckets — each rank receives only its
      own block — and prefetches the next step's params via
      continuation-chained persistent all-gathers;
    * ``apply_fn(shards, opt_state, grad_shards, stacked_metrics) ->
      (shards, opt_state, metrics)`` — the sharded optimizer step.

    ``spec`` as in :class:`UserCollectiveStep`."""
    grad_fn: Callable
    apply_fn: Callable
    reducer: Any
    spec: "CollectiveSpec | None" = None

    def __post_init__(self):
        if self.spec is not None and not isinstance(self.spec,
                                                    CollectiveSpec):
            raise TypeError(
                f"spec must be a CollectiveSpec, got "
                f"{type(self.spec).__name__} (legacy kwargs belong on "
                f"TrainLoopConfig)")


class Trainer:
    def __init__(self, step_fn: Callable, params, opt_state,
                 pipeline, cfg: TrainLoopConfig,
                 engine: Optional[ProgressEngine] = None,
                 hooks: list[Callable[[int, dict], None]] | None = None,
                 split_step: "UserCollectiveStep | None" = None,
                 epoch=None,
                 remesh_fn: Callable | None = None):
        # epoch: a collectives MembershipEpoch shared with the reducer's
        # persistent handles.  The watchdog invalidates it when a step
        # hangs, so the in-flight reduction fails retryably instead of
        # deadlocking the loop.  remesh_fn(exc, params, opt_state) ->
        # (split_step, params, opt_state) rebuilds the split step on the
        # survivors' mesh (new shard_map programs, re-placed state, a
        # remeshed reducer); with it set, a MembershipError surfacing
        # from grad dispatch or the reduction wait is recovered
        # *within the same step*: rebuild, then retry the step's batch.
        # keep the config's collective_backend and the split_step argument
        # consistent: the config is the record (stats/logs), the split_step
        # carries the machinery — they must agree or the caller gets the
        # wrong backend silently
        if split_step is not None and cfg.collective_backend != "user":
            cfg = dataclasses.replace(
                cfg,
                collective_spec=dataclasses.replace(cfg.collective_spec,
                                                    backend="user"),
                collective_backend="user")
        elif split_step is None and cfg.collective_backend == "user":
            raise ValueError(
                "collective_backend='user' requires a split_step "
                "(UserCollectiveStep or FsdpStep with "
                "grad_fn/apply_fn/reducer)")
        self.step_fn = step_fn
        self.split_step = split_step
        self.params = params
        self.opt_state = opt_state
        self.pipeline = pipeline
        self.cfg = cfg
        self.engine = engine or global_engine()
        self.hooks = hooks or []
        self.ckpt = AsyncCheckpointer(cfg.checkpoint_dir, self.engine)
        self.straggler = StragglerDetector()
        self.epoch = epoch
        self.remesh_fn = remesh_fn
        self.watchdog = StepWatchdog(self.engine, cfg.watchdog_limit_s,
                                     on_hang=self._on_hang, epoch=epoch)
        self.start_step = 0
        self.recoveries = 0
        self.metrics_log: list[dict] = []
        self._pending_ckpt: Request | None = None
        self._pending_gather = None     # FsdpStep: chained param prefetch
        self._hung = False

    # ------------------------------------------------------------------
    def _on_hang(self):
        self._hung = True

    def _reduced_grads(self, batch):
        """Split-step grad dispatch + engine-driven bucketed reduction."""
        stacked_metrics, grads = self.split_step.grad_fn(self.params, batch)
        reduction = self.split_step.reducer.iallreduce_tree(grads)
        return stacked_metrics, \
            reduction.wait(timeout=self.cfg.watchdog_limit_s)

    def _split_step_once(self, batch):
        """One split-backend step; sets params/opt_state, returns metrics.
        Raises MembershipError retryably (params not yet updated)."""
        limit = self.cfg.watchdog_limit_s
        if isinstance(self.split_step, FsdpStep):
            ss = self.split_step
            if self._pending_gather is None:
                # cold start (or post-remesh): no prefetch in flight —
                # issue the continuation-chained gather and wait it here
                self._pending_gather = ss.reducer.igather(self.params)
            flats = self._pending_gather.wait(timeout=limit)
            self._pending_gather = None
            stacked_metrics, flat_grads = ss.grad_fn(flats, batch)
            grad_shards = ss.reducer.ireduce_scatter(flat_grads) \
                .wait(timeout=limit)
            self.params, self.opt_state, metrics = ss.apply_fn(
                self.params, self.opt_state, grad_shards, stacked_metrics)
            # prefetch the next step's full params NOW: each bucket's
            # persistent all-gather start is chained off that bucket's
            # compute future, so gather rounds progress on the collective
            # stream behind the optimizer tail, the metrics wait, host
            # logging and the next batch fetch (§4.6 continuations)
            self._pending_gather = ss.reducer.igather(
                self.params, after=[ss.reducer.future(s)
                                    for s in self.params])
            return metrics
        stacked_metrics, grads = self._reduced_grads(batch)
        self.params, self.opt_state, metrics = self.split_step.apply_fn(
            self.params, self.opt_state, grads, stacked_metrics)
        return metrics

    def maybe_resume(self):
        if not self.cfg.resume:
            return
        latest = self.ckpt.latest_step()
        if latest is not None:
            state = self.ckpt.restore(latest, {"params": self.params,
                                               "opt_state": self.opt_state})
            self.params = state["params"]
            self.opt_state = state["opt_state"]
            self.start_step = latest + 1

    # ------------------------------------------------------------------
    def run(self) -> list[dict]:
        executor = None
        if self.cfg.progress_workers > 0:
            # background progress (§4.4): workers own the default stream's
            # async tasks (prefetch fills, checkpoint stages, futures) plus
            # the subsystem hooks; the overlap window below then *waits*
            # (engine.wait yields to the executor) instead of polling
            executor = ProgressExecutor(self.engine,
                                        self.cfg.progress_workers)
            executor.adopt(self.engine.default_stream)
            executor.start()
        try:
            return self._run_loop()
        finally:
            if executor is not None:
                executor.shutdown(drain=True, timeout=600)

    def _run_loop(self) -> list[dict]:
        self.maybe_resume()
        loss_req: Request | None = None
        metrics = None
        for step in range(self.start_step, self.cfg.total_steps):
            batch = self.pipeline.next_batch()     # warm path: no block
            t0 = time.monotonic()
            self.watchdog.arm()
            if self.split_step is not None:
                # engine-driven collective backend: dispatch local grads,
                # issue the nonblocking bucketed allreduce, and let the
                # engine overlap the reduction with prefetch/checkpoint
                # progress (and the tail of backward, still in flight)
                try:
                    metrics = self._split_step_once(batch)
                except MembershipError as exc:
                    if self.remesh_fn is None:
                        raise
                    # membership changed mid-step (dead peer or hung
                    # collective): rebuild the split step on survivors
                    # and retry THIS step's batch.  Params were not yet
                    # updated, so the retried step computes exactly what
                    # a from-checkpoint restart at this step would.  An
                    # in-flight FSDP prefetch died with the old epoch —
                    # drop it; the retry re-gathers on the new mesh.
                    self._pending_gather = None
                    self.split_step, self.params, self.opt_state = \
                        self.remesh_fn(exc, self.params, self.opt_state)
                    self.recoveries += 1
                    self._hung = False
                    self.watchdog.arm()
                    metrics = self._split_step_once(batch)
            else:
                # nonblocking dispatch — jit returns before device finishes
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch)
            loss_req = jax_future(self.engine, metrics)

            # overlap window: drive collated progress until device done
            # (with progress workers attached, wait yields to them instead)
            self.engine.wait(loss_req)
            self.watchdog.disarm()
            dur = time.monotonic() - t0
            self.straggler.record("self", dur)

            if (step + 1) % self.cfg.checkpoint_every == 0 \
                    or step == self.cfg.total_steps - 1:
                # async save: stages progress inside future loop iterations
                self._pending_ckpt = self.ckpt.save_async(
                    step, {"params": self.params, "opt_state": self.opt_state})

            if step % self.cfg.log_every == 0 or step == self.cfg.total_steps - 1:
                m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                m["step"] = step
                m["step_time_s"] = dur
                self.metrics_log.append(m)
                for hook in self.hooks:
                    hook(step, m)
            if self._hung:
                raise RuntimeError("watchdog: step exceeded wall-clock limit")
        # finalize: drain pending checkpoint I/O (paper Listing 1.2 note:
        # finalize spins progress until all async tasks complete)
        if self._pending_ckpt is not None:
            self.engine.wait(self._pending_ckpt, timeout=600)
        return self.metrics_log
