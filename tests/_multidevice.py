"""Run a snippet in a subprocess with N forced host devices.

JAX locks the device count at first init, so multi-device tests (which
must not pollute the 1-device smoke environment) execute in children.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

REPO_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Execute `code` with n host devices; raises on nonzero exit.
    The snippet should print its assertions/outputs; stdout is returned."""
    preamble = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_devices}"
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", preamble + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode}):\n"
            f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-4000:]}")
    return proc.stdout
