"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests see 1 device;
multi-device tests spawn subprocesses (see _multidevice.py)."""
import jax
import pytest

from repro.configs import get_config


def reduce_cfg(cfg, **extra):
    """Family-preserving reduced config for CPU smoke tests."""
    kw = dict(num_layers=2, d_model=64, d_ff=128, vocab_size=256,
              remat_policy="none")
    if cfg.num_heads:
        kw.update(num_heads=4, num_kv_heads=2, head_dim=16)
    if cfg.moe:
        kw["moe"] = cfg.moe.__class__(num_experts=4, top_k=2, expert_d_ff=64,
                                      group_size=64)
    if cfg.ssm:
        kw["ssm"] = cfg.ssm.__class__(d_state=16, expand=2, head_dim=16,
                                      chunk_size=8)
    if cfg.shared_attn_every:
        kw.update(num_layers=5, shared_attn_every=2, shared_attn_lora_rank=4)
    if cfg.is_encoder_decoder:
        kw.update(num_encoder_layers=2, encoder_frames=12,
                  max_position_embeddings=128)
    kw.update(extra)
    return cfg.with_overrides(**kw)


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)
