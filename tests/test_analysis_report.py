"""Analysis/report layer: formatting, table generation, roofline math."""
import json

import pytest

from repro.analysis import hlo
from repro.analysis.report import dryrun_table, fmt_bytes, fmt_s, roofline_table
from repro.analysis.roofline import Roofline, analytical_bytes
from repro.configs import get_config
from repro.configs.shapes import get_shape


class TestFormatting:
    @pytest.mark.parametrize("b,expect", [
        (1.5e12, "1.50TB"), (2.5e9, "2.50GB"), (3.2e6, "3.2MB"),
        (900, "1KB"), (None, "-")])
    def test_fmt_bytes(self, b, expect):
        assert fmt_bytes(b) == expect

    @pytest.mark.parametrize("s,expect", [
        (2.5, "2.50s"), (0.0032, "3.20ms"), (5e-6, "5µs")])
    def test_fmt_s(self, s, expect):
        assert fmt_s(s) == expect


def _fake_record(arch="a", shape="train_4k", mesh="16x16", status="ok"):
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "status": status,
        "compile_s": 1.0,
        "memory": {"bytes_in_use_per_device": 1e9},
        "roofline": {
            "dominant": "compute", "compute_s": 0.5, "memory_s": 0.1,
            "collective_s": 0.2, "roofline_fraction": 0.5,
            "useful_ratio": 0.6, "flops_per_device": 1e12,
            "coll_by_type": {"all-reduce": 1e9},
        },
    }


class TestTables:
    def test_roofline_table_renders(self):
        out = roofline_table([_fake_record()], "16x16")
        assert "| a | train_4k | **compute**" in out

    def test_dryrun_table_handles_skips_and_errors(self):
        rows = [_fake_record(),
                {"arch": "b", "shape": "long_500k", "mesh": "16x16",
                 "status": "skipped", "reason": "full attention quad"},
                {"arch": "c", "shape": "train_4k", "mesh": "16x16",
                 "status": "error", "error": "boom"}]
        out = dryrun_table(rows)
        assert "SKIP" in out and "ERROR" in out


class TestRooflineMath:
    def test_bound_and_fraction(self):
        r = Roofline(arch="x", shape="train_4k", mesh="16x16", chips=256,
                     flops_per_device=1e12, bytes_per_device=1e9,
                     coll_bytes_per_device=1e9, coll_by_type={},
                     compute_s=0.5, memory_s=0.1, collective_s=0.2,
                     dominant="compute", model_flops=0.5 * 256 * 197e12 * 0.5,
                     hlo_flops_global=1e15, useful_ratio=0.5)
        assert r.bound_s() == 0.5
        assert abs(r.roofline_fraction() - 0.5) < 1e-9

    def test_analytical_bytes_decode_scales_with_weight_bytes(self):
        cfg = get_config("llama3-405b")
        shape = get_shape("decode_32k")
        mesh_shape = {"data": 16, "model": 16}
        b2 = analytical_bytes(cfg, shape, 256, mesh_shape, weight_bytes=2.0)
        b1 = analytical_bytes(cfg, shape, 256, mesh_shape, weight_bytes=1.0)
        n_local = 405.8e9 / 256
        assert abs((b2 - b1) - n_local) / n_local < 0.05

    def test_analytical_bytes_train_dominated_by_optimizer_stream(self):
        cfg = get_config("llama3-405b")
        shape = get_shape("train_4k")
        b = analytical_bytes(cfg, shape, 256, {"data": 16, "model": 16})
        assert b > 405e9 / 256 * 32 * 0.9   # ≥ the parameter/optimizer term


class TestHLOAnalyzerEdges:
    def test_empty_module(self):
        res = hlo.analyze("HloModule empty\n")
        assert res["flops"] == 0

    def test_unknown_trip_count_flagged(self):
        txt = """HloModule m

%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]) parameter(0)
  ROOT %t = (s32[], f32[4]) tuple(%p)
}

%cond (p: (s32[], f32[4])) -> pred[] {
  %p = (s32[], f32[4]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main (a: f32[4]) -> f32[4] {
  %a = f32[4] parameter(0)
  %c = s32[] constant(0)
  %tup = (s32[], f32[4]) tuple(%c, %a)
  %w = (s32[], f32[4]) while(%tup), condition=%cond, body=%body
  ROOT %out = f32[4] get-tuple-element(%w), index=1
}
"""
        res = hlo.analyze(txt)
        assert res["dynamic_while"] is True

    def test_collective_types_separated(self):
        txt = """HloModule m

ENTRY %main (a: f32[128]) -> f32[128] {
  %a = f32[128] parameter(0)
  %ag = f32[256] all-gather(%a), dimensions={0}
  %ar = f32[128] all-reduce(%a), to_apply=%add
  ROOT %cp = f32[128] collective-permute(%a), source_target_pairs={{0,1}}
}
"""
        res = hlo.analyze(txt)
        cb = res["collective_bytes"]
        assert cb["all-gather"] == 512
        assert cb["all-reduce"] == 512
        assert cb["collective-permute"] == 512
