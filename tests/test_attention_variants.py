"""Attention implementation variants: blockskip + ring (fwd & custom bwd)
against the reference oracle, incl. multi-device subprocess checks."""
import jax
from repro import compat
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import flash_attention_ref
from repro.models import layers as L
from tests._multidevice import run_with_devices

KEY = jax.random.PRNGKey(3)


class TestBlockskip:
    @pytest.mark.parametrize("S,chunk,H,KVH", [
        (256, 64, 4, 2), (384, 128, 6, 3), (512, 128, 5, 5)])
    def test_matches_reference(self, S, chunk, H, KVH):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (2, S, H, 32))
        k = jax.random.normal(ks[1], (2, S, KVH, 32))
        v = jax.random.normal(ks[2], (2, S, KVH, 32))
        out = L.attention_blockskip(q, k, v, chunk=chunk)
        ref = flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)

    def test_halves_block_count(self):
        """The scan trip count must be nc(nc+1)/2 — the FLOP saving."""
        S, chunk = 512, 128
        nc = S // chunk
        q = jnp.ones((1, S, 2, 16))
        k = v = jnp.ones((1, S, 2, 16))
        txt = jax.jit(lambda q, k, v: L.attention_blockskip(
            q, k, v, chunk=chunk)).lower(q, k, v).compile().as_text()
        import re
        trips = [int(m) for m in re.findall(r'"known_trip_count":\{"n":"(\d+)"\}', txt)]
        assert nc * (nc + 1) // 2 in trips, trips

    def test_gradients_match_reference(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 256, 4, 32))
        k = jax.random.normal(ks[1], (1, 256, 2, 32))
        v = jax.random.normal(ks[2], (1, 256, 2, 32))
        g = jax.grad(lambda q, k, v: jnp.sum(
            L.attention_blockskip(q, k, v, chunk=64) ** 2), argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(lambda q, k, v: jnp.sum(
            flash_attention_ref(q, k, v, causal=True) ** 2), argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-4, rtol=2e-4)


class TestRingAttention:
    def test_fallback_no_mesh(self):
        """Without a mesh context, ring falls back to chunked attention."""
        from repro.collectives.ring_attention import ring_attention
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 128, 4, 32))
        k = jax.random.normal(ks[1], (1, 128, 2, 32))
        v = jax.random.normal(ks[2], (1, 128, 2, 32))
        out = ring_attention(q, k, v, causal=True)
        ref = flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)

    def test_multidevice_fwd_and_custom_bwd(self):
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro import compat
            from repro.collectives.ring_attention import ring_attention
            from repro.kernels.ref import flash_attention_ref
            mesh = compat.make_mesh((2, 4), ("data", "model"))
            ks = jax.random.split(jax.random.PRNGKey(0), 3)
            q = jax.random.normal(ks[0], (2, 128, 6, 32))
            k = jax.random.normal(ks[1], (2, 128, 2, 32))
            v = jax.random.normal(ks[2], (2, 128, 2, 32))
            with compat.set_mesh(mesh):
                out = jax.jit(lambda q, k, v: ring_attention(q, k, v, causal=True))(q, k, v)
                g = jax.jit(jax.grad(lambda q, k, v: jnp.sum(
                    ring_attention(q, k, v, causal=True) ** 2),
                    argnums=(0, 1, 2)))(q, k, v)
            ref = flash_attention_ref(q, k, v, causal=True)
            gr = jax.grad(lambda q, k, v: jnp.sum(
                flash_attention_ref(q, k, v, causal=True) ** 2),
                argnums=(0, 1, 2))(q, k, v)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)
            for a, b in zip(g, gr):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)
            print("RING_OK")
        """)
        assert "RING_OK" in out


class TestMoECustomVJP:
    def test_multidevice_matches_fallback_autodiff(self):
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro import compat
            from repro.configs import get_config
            from repro.models import layers as L
            base = get_config("grok-1-314b")
            cfg = base.with_overrides(num_layers=1, d_model=64, num_heads=4,
                num_kv_heads=2, head_dim=16, vocab_size=128,
                moe=base.moe.__class__(num_experts=4, top_k=2,
                                       expert_d_ff=32, group_size=32))
            p = L.init_tree(L.moe_spec(cfg), jax.random.PRNGKey(0))
            x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64))
            def loss(p, x):
                y, aux = L.moe_apply(p, x, cfg)
                return jnp.sum(y ** 2) + aux
            l0, g0 = jax.value_and_grad(loss)(p, x)       # no-mesh fallback
            mesh = compat.make_mesh((2, 4), ("data", "model"))
            with compat.set_mesh(mesh):
                l1, g1 = jax.jit(jax.value_and_grad(loss))(p, x)
            assert abs(float(l0 - l1)) < 1e-3, (l0, l1)
            errs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), g0, g1)
            assert max(jax.tree.leaves(errs)) < 1e-3, errs
            print("MOE_VJP_OK")
        """)
        assert "MOE_VJP_OK" in out
