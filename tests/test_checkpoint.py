"""Async checkpointing: engine-driven multi-stage save, atomic commit,
crash safety, restore, GC."""
import json
import os
import shutil

import jax
from repro import compat
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ProgressEngine
from repro.train.checkpoint import AsyncCheckpointer


@pytest.fixture
def tree(rng):
    k1, k2 = jax.random.split(rng)
    return {"params": {"w": jax.random.normal(k1, (8, 8)),
                       "b": jnp.zeros((8,))},
            "opt": {"mu": jax.random.normal(k2, (8, 8))}}


def test_async_save_restore(tmp_path, tree):
    eng = ProgressEngine()
    ck = AsyncCheckpointer(str(tmp_path), eng)
    req = ck.save_async(7, tree)
    assert not req.is_complete          # stages run via progress, not inline
    eng.wait(req, timeout=60)
    assert ck.latest_step() == 7
    restored = ck.restore(7, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_atomic_commit_no_partial_visible(tmp_path, tree):
    """A .tmp dir must never be treated as a checkpoint."""
    eng = ProgressEngine()
    ck = AsyncCheckpointer(str(tmp_path), eng)
    ck.save_blocking(3, tree)
    # simulate crash mid-save: a stale tmp dir with partial contents
    os.makedirs(tmp_path / "step_9.tmp")
    (tmp_path / "step_9.tmp" / "garbage.npy").write_bytes(b"xx")
    assert ck.latest_step() == 3        # tmp dir invisible
    restored = ck.restore(3, tree)
    assert restored is not None


def test_corrupt_uncommitted_dir_ignored(tmp_path, tree):
    """Committed dir requires manifest.json: half-renamed dirs ignored."""
    eng = ProgressEngine()
    ck = AsyncCheckpointer(str(tmp_path), eng)
    ck.save_blocking(1, tree)
    os.makedirs(tmp_path / "step_5")    # committed-looking but no manifest
    assert ck.latest_step() == 1


def test_gc_keeps_latest(tmp_path, tree):
    eng = ProgressEngine()
    ck = AsyncCheckpointer(str(tmp_path), eng, keep=2)
    for s in (1, 2, 3, 4):
        ck.save_blocking(s, tree)
    kept = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert kept == ["step_3", "step_4"]


def test_restore_resharded_roundtrip(tmp_path, tree):
    """Restore with explicit shardings (1-device degenerate elastic)."""
    eng = ProgressEngine()
    ck = AsyncCheckpointer(str(tmp_path), eng)
    ck.save_blocking(2, tree)
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    sh = jax.tree.map(
        lambda _: jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        tree)
    restored = ck.restore(2, tree, sh)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_save_overlaps_with_host_work(tmp_path, tree):
    """The engine can interleave other tasks while a save is in flight."""
    eng = ProgressEngine()
    ck = AsyncCheckpointer(str(tmp_path), eng)
    ticks = []
    eng.register_subsystem("ticker", lambda: (ticks.append(1), False)[1])
    req = ck.save_async(11, tree)
    eng.wait(req, timeout=60)
    assert len(ticks) > 0               # other progress ran during the save
