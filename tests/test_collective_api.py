"""The unified CollectiveSpec config surface.

One frozen record — ``CollectiveSpec(backend, algorithm, chunks,
round_batch)`` — is accepted by every configuration surface
(``ServeEngine``, ``TrainLoopConfig``, ``UserCollectiveStep`` /
``FsdpStep``, the module-level handle factories, the p2p family), with:

* eager validation at construction (bad values never reach tracing);
* a one-release deprecation shim: the legacy ``collective_*`` kwargs
  keep working but emit exactly ONE ``DeprecationWarning`` per surface
  per process, and mixing spec + legacy raises;
* a canonical import surface at ``repro.collectives``.
"""
import warnings

import pytest

from repro.collectives import nonblocking as NB
from repro.collectives.nonblocking import CollectiveSpec, spec_from_legacy


@pytest.fixture(autouse=True)
def _reset_warn_once():
    """Each test sees the warn-once latch fresh (it is per-process)."""
    saved = set(NB._legacy_kwargs_warned)
    NB._legacy_kwargs_warned.clear()
    yield
    NB._legacy_kwargs_warned.clear()
    NB._legacy_kwargs_warned.update(saved)


# ---------------------------------------------------------------------------
# The record itself
# ---------------------------------------------------------------------------

class TestCollectiveSpec:
    def test_defaults(self):
        spec = CollectiveSpec()
        assert (spec.backend, spec.algorithm, spec.chunks,
                spec.round_batch) == ("native", "ring", 1, None)
        assert not spec.user
        assert CollectiveSpec(backend="user").user

    def test_eager_validation(self):
        with pytest.raises(ValueError, match="backend"):
            CollectiveSpec(backend="bogus")
        with pytest.raises(ValueError, match="algorithm"):
            CollectiveSpec(algorithm="bogus")
        with pytest.raises(ValueError, match="chunks"):
            CollectiveSpec(chunks=0)
        with pytest.raises(ValueError, match="round_batch"):
            CollectiveSpec(round_batch=-1)

    def test_frozen_and_hashable(self):
        spec = CollectiveSpec()
        with pytest.raises(Exception):
            spec.backend = "user"
        assert len({CollectiveSpec(), CollectiveSpec(),
                    CollectiveSpec(chunks=2)}) == 2

    def test_resolve_pow2_fallback(self):
        spec = CollectiveSpec(algorithm="halving_doubling")
        assert spec.resolve(4) is spec
        with pytest.warns(RuntimeWarning, match="power-of-two"):
            assert spec.resolve(3).algorithm == "ring"


# ---------------------------------------------------------------------------
# The deprecation shim
# ---------------------------------------------------------------------------

class TestSpecFromLegacy:
    def test_spec_passthrough(self):
        spec = CollectiveSpec(backend="user", chunks=3)
        assert spec_from_legacy(spec, surface="T") is spec

    def test_legacy_kwargs_warn_once_per_surface(self):
        with pytest.warns(DeprecationWarning, match="deprecated"):
            got = spec_from_legacy(None, surface="T", backend="user",
                                   chunks=2)
        assert got == CollectiveSpec(backend="user", chunks=2)
        # second use of the SAME surface: silent
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            spec_from_legacy(None, surface="T", backend="native")
        # a DIFFERENT surface still warns
        with pytest.warns(DeprecationWarning):
            spec_from_legacy(None, surface="U", chunks=4)

    def test_no_legacy_no_warning(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            assert spec_from_legacy(None, surface="T") == CollectiveSpec()

    def test_mixing_spec_and_legacy_raises(self):
        with pytest.raises(ValueError, match="not both"):
            spec_from_legacy(CollectiveSpec(), surface="T", chunks=2)

    def test_default_base(self):
        base = CollectiveSpec(chunks=4, round_batch=0)
        assert spec_from_legacy(None, surface="T", default=base) is base
        with pytest.warns(DeprecationWarning):
            got = spec_from_legacy(None, surface="T", backend="user",
                                   default=base)
        # legacy kwargs override the default base fieldwise
        assert got == CollectiveSpec(backend="user", chunks=4,
                                     round_batch=0)


# ---------------------------------------------------------------------------
# The four config surfaces
# ---------------------------------------------------------------------------

class TestSurfaces:
    def test_train_loop_config_accepts_spec(self):
        from repro.train.train_loop import TrainLoopConfig
        spec = CollectiveSpec(backend="user", chunks=2)
        cfg = TrainLoopConfig(total_steps=1, collective_spec=spec)
        assert cfg.collective_spec is spec
        # the mirrored legacy fields resolve FROM the spec
        assert cfg.collective_backend == "user"
        assert cfg.collective_chunks == 2

    def test_train_loop_config_legacy_warns_once(self):
        from repro.train.train_loop import TrainLoopConfig
        with pytest.warns(DeprecationWarning):
            cfg = TrainLoopConfig(total_steps=1,
                                  collective_backend="user")
        assert cfg.collective_spec.user
        # chunks/round_batch keep the loop's tuned defaults
        assert cfg.collective_spec.chunks == 4
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            TrainLoopConfig(total_steps=1, collective_backend="native")

    def test_train_loop_config_conflict_raises(self):
        from repro.train.train_loop import TrainLoopConfig
        with pytest.raises(ValueError, match="conflicts"):
            TrainLoopConfig(total_steps=1,
                            collective_spec=CollectiveSpec(backend="user"),
                            collective_backend="native")

    def test_train_loop_config_replace_roundtrip(self):
        import dataclasses

        from repro.train.train_loop import TrainLoopConfig
        cfg = TrainLoopConfig(total_steps=2,
                              collective_spec=CollectiveSpec(chunks=2))
        # replace() re-runs __post_init__ with the mirrored legacy
        # fields populated — they agree with the spec, so no raise
        cfg2 = dataclasses.replace(cfg, total_steps=5)
        assert cfg2.collective_spec == cfg.collective_spec

    def test_step_records_reject_non_spec(self):
        from repro.train.train_loop import FsdpStep, UserCollectiveStep
        with pytest.raises(TypeError, match="CollectiveSpec"):
            UserCollectiveStep(lambda: 0, lambda: 0, None, spec="user")
        with pytest.raises(TypeError, match="CollectiveSpec"):
            FsdpStep(lambda: 0, lambda: 0, None, spec="user")

    def test_serve_engine_legacy_warns_and_spec_conflict(self):
        import jax

        from repro.configs import get_config
        from repro.core import ProgressEngine
        from repro.models import registry
        from repro.serve.engine import ServeEngine
        from conftest import reduce_cfg
        cfg = reduce_cfg(get_config("qwen2-0.5b"))
        params = registry.init_params(cfg, jax.random.PRNGKey(0))
        with pytest.warns(DeprecationWarning):
            srv = ServeEngine(cfg, params, ProgressEngine(),
                              batch_slots=2, max_seq=32,
                              collective_chunks=2)
        assert srv.collective_spec.chunks == 2
        srv.close(timeout=60)
        with pytest.raises(ValueError, match="not both"):
            ServeEngine(cfg, params, ProgressEngine(), batch_slots=2,
                        max_seq=32, collective_spec=CollectiveSpec(),
                        collective_backend="native")

    def test_serve_engine_slots_mode_retired(self):
        import jax

        from repro.configs import get_config
        from repro.core import ProgressEngine
        from repro.models import registry
        from repro.serve.engine import ServeEngine
        from conftest import reduce_cfg
        cfg = reduce_cfg(get_config("qwen2-0.5b"))
        params = registry.init_params(cfg, jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="retired"):
            ServeEngine(cfg, params, ProgressEngine(), batch_slots=2,
                        max_seq=32, cache_mode="slots")


# ---------------------------------------------------------------------------
# p2p: spec=/partition= split
# ---------------------------------------------------------------------------

class TestP2PSpecShim:
    def test_partition_via_spec_warns_and_works(self):
        from jax.sharding import PartitionSpec as P

        from repro.collectives.p2p import _resolve_spec_partition
        with pytest.warns(DeprecationWarning, match="partition"):
            spec, part = _resolve_spec_partition(P("x"), None)
        assert spec is None and part == P("x")
        # warn-once: second call is silent
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            _resolve_spec_partition(P("y"), None)

    def test_native_collective_spec_rejected(self):
        from repro.collectives.p2p import _resolve_spec_partition
        with pytest.raises(ValueError, match="user backend"):
            _resolve_spec_partition(CollectiveSpec(backend="native"), None)

    def test_user_spec_accepted(self):
        from repro.collectives.p2p import _resolve_spec_partition
        spec = CollectiveSpec(backend="user")
        got, part = _resolve_spec_partition(spec, None)
        assert got is spec and part is None


# ---------------------------------------------------------------------------
# The canonical import surface
# ---------------------------------------------------------------------------

def test_collectives_import_surface():
    import repro.collectives as C
    for name in C.__all__:
        assert getattr(C, name) is not None, name
    # the one-shot + persistent families all present, one naming shape
    for op in ("iallreduce", "ireduce_scatter", "iallgather", "ialltoall"):
        assert callable(getattr(C, op))
    for fac in ("allreduce_init", "reduce_scatter_init", "allgather_init",
                "alltoall_init", "channel_init", "send_init", "recv_init"):
        assert callable(getattr(C, fac))
    # spec/overlap machinery re-exported
    assert C.CollectiveSpec is CollectiveSpec
    assert C.S is __import__("repro.collectives.schedules",
                             fromlist=["x"])


def test_factories_accept_spec_kwarg():
    import inspect

    import repro.collectives as C
    for fac in (C.iallreduce, C.ireduce_scatter, C.iallgather,
                C.ialltoall, C.allreduce_init, C.reduce_scatter_init,
                C.allgather_init, C.alltoall_init, C.channel_init,
                C.send_init, C.recv_init):
        params = inspect.signature(fac).parameters
        assert "spec" in params, fac.__name__
        assert params["spec"].kind is inspect.Parameter.KEYWORD_ONLY, \
            fac.__name__
    for fac in (C.allreduce_init, C.reduce_scatter_init,
                C.allgather_init, C.alltoall_init, C.channel_init,
                C.send_init, C.recv_init):
        params = inspect.signature(fac).parameters
        for kw in ("epoch", "stream", "engine"):
            assert kw in params, (fac.__name__, kw)
